//! Fig. 4 — accuracy vs pruning start layer on AVHBench subtasks (vl2sim).
//!
//! Runs the full FastAV plan with the global stage moved to each layer
//! boundary (frontsplit artifacts). Paper shape: pruning in early layers
//! degrades AV hallucination; from the middle layer on, accuracy is
//! preserved or improved.
//!
//! ```sh
//! cargo run --release --example fig4_layer_sweep [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use fastav::avsynth::Dataset;
use fastav::eval::evaluate;

fn main() {
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let dataset = std::env::args()
        .nth(2)
        .and_then(|s| fastav::avsynth::Dataset::parse(&s))
        .unwrap_or(Dataset::AvhBench);
    let mut engine = common::load_engine("vl2sim");
    let calib = common::load_or_calibrate(&mut engine, 50);
    let n_layers = engine.cfg.n_layers;
    println!(
        "Fig 4 — pruning start-layer sweep (vl2sim, avhbench, n={}, mid={})",
        n, engine.cfg.mid_layer
    );
    println!(
        "{:>11} {:>6} {:>8} {:>8} {:>8}",
        "start layer", "FLOPs", "hall%", "match%", "acc%"
    );

    for g in 1..n_layers {
        let mut plan = calib.plan(20.0);
        plan.global_layer = Some(g);
        let report = match evaluate(&mut engine, dataset, n, 1234, &plan, 4) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("layer {}: {:#}", g, e);
                continue;
            }
        };
        let hall = report.subtask_accuracy("hallucination").unwrap_or(0.0);
        let mat = report.subtask_accuracy("matching").unwrap_or(0.0);
        println!(
            "{:>11} {:>6.1} {:>8.1} {:>8.1} {:>8.1}",
            g,
            report.mean_rel_flops,
            hall,
            mat,
            report.accuracy()
        );
    }
}
