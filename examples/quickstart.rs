//! Quickstart: load a model, answer one audio-visual question with and
//! without FastAV pruning, and print the efficiency delta.
//!
//! ```sh
//! cargo run --release --example quickstart [model]
//! ```

#[path = "common/mod.rs"]
mod common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::model::{GenerateOptions, PruningPlan, RequestInput};
use fastav::tokens::render_answer;

fn main() {
    let model = common::model_arg();
    let mut engine = common::load_engine(&model);
    let calib = common::load_or_calibrate(&mut engine, 20);
    engine.warmup().expect("warmup"); // compile artifacts up front
    let layout = engine.cfg.layout.clone();

    let sample = gen_sample(&layout, Dataset::Avqa, 0, 1234);
    println!(
        "question: {}  (scene {}, sound {})",
        sample.subtask.name(),
        sample.scene,
        sample.sound
    );
    println!("prompt: {} tokens ({} visual, {} audio)", sample.prompt.len(),
        layout.vis_tokens(), layout.audio_tokens());

    for (name, plan) in [
        ("vanilla", PruningPlan::vanilla()),
        ("fastav ", calib.plan(20.0)),
    ] {
        let res = engine
            .generate(
                &RequestInput::from_sample(&sample),
                &GenerateOptions { plan, max_gen: 4, ..Default::default() },
            )
            .expect("generate");
        println!(
            "{}: answer '{}' (expect '{}')  flops {:>5.1}  prefill {:>6.1}ms  kv {:.2}MB",
            name,
            render_answer(&res.tokens),
            render_answer(&sample.answer),
            res.relative_flops,
            res.prefill_seconds * 1e3,
            res.peak_kv_bytes as f64 / 1e6,
        );
    }
}
