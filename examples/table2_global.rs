//! Table 2 — global-pruning strategy ablation on AVHBench (vl2sim), no
//! fine pruning, all strategies at the same AV-token keep budget
//! (equal FLOPs).
//!
//! Paper shape: Low informative (ours) > Low attentive ≈ Vanilla >
//! Random > Top attentive > Top informative.
//!
//! ```sh
//! cargo run --release --example table2_global [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use fastav::avsynth::Dataset;
use fastav::eval::evaluate;
use fastav::model::PruningPlan;
use fastav::pruning::{FineStrategy, GlobalStrategy};

fn main() {
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let dataset = std::env::args()
        .nth(2)
        .and_then(|s| fastav::avsynth::Dataset::parse(&s))
        .unwrap_or(Dataset::AvhBench);
    let mut engine = common::load_engine("vl2sim");
    engine.warmup().ok();
    let calib = common::load_or_calibrate(&mut engine, 50);
    println!(
        "Table 2 — global pruning strategies (vl2sim, avhbench, n={}, budget={} AV tokens)",
        n, calib.budget
    );
    println!(
        "{:<26} {:>6} {:>8} {:>8} {:>8}",
        "strategy", "FLOPs", "hall%", "match%", "acc%"
    );

    let rows: Vec<(&str, PruningPlan)> = vec![
        ("Vanilla", PruningPlan::vanilla()),
        (
            "Random",
            calib.ablation_plan(GlobalStrategy::Random, FineStrategy::None, 0.0),
        ),
        (
            "Top attentive",
            calib.ablation_plan(GlobalStrategy::TopAttentive, FineStrategy::None, 0.0),
        ),
        (
            "Low attentive",
            calib.ablation_plan(GlobalStrategy::LowAttentive, FineStrategy::None, 0.0),
        ),
        (
            "Top informative",
            calib.ablation_plan(GlobalStrategy::TopInformative, FineStrategy::None, 0.0),
        ),
        ("Low informative (Ours)", calib.global_only_plan()),
    ];

    for (name, plan) in rows {
        let report = evaluate(&mut engine, dataset, n, 1234, &plan, 4).expect("eval");
        let hall = report.subtask_accuracy("hallucination").unwrap_or(0.0);
        let mat = report.subtask_accuracy("matching").unwrap_or(0.0);
        println!(
            "{:<26} {:>6.1} {:>8.1} {:>8.1} {:>8.1}",
            name,
            report.mean_rel_flops,
            hall,
            mat,
            report.accuracy()
        );
    }
}
