//! End-to-end serving benchmark: boots the full stack — HTTP server →
//! coordinator → replica pool → step schedulers → engines → PJRT — and
//! drives a mixed short/long workload over real sockets, once against a
//! single replica and once against a pool of four. Reports sustained
//! throughput and per-class latency (the pool's step scheduler should
//! keep short-request p95 bounded even when mixed with long
//! generations), and records the numbers in `BENCH_serving.json`.
//!
//! A third phase drives the **repeated-prefix** workload the AV-prefix
//! cache targets: N different questions per sample (same AV prefix,
//! varying question suffix via the `question` body field). It reports
//! prefix-cache hit/miss/eviction counts from `GET /v1/pool` plus the
//! total front-half prefill tokens skipped (summed from each response's
//! `prefix_tokens_reused`), and records them in `BENCH_prefix.json`.
//!
//! A fourth phase drives the **saturated-decode** workload batched
//! decode targets: one replica, N concurrent long generations, so every
//! quantum past prefill is a fused `decode_batch` dispatch. It measures
//! generated tokens/s at pool occupancy 1/4/8 with batching enabled
//! (`max_decode_batch: 0`) vs forced single-step (`1`), reports the mean
//! batch occupancy from the pool's `decode_batch` stats, and records
//! everything in `BENCH_batch.json`.
//!
//! A fifth phase drives the **mixed-profile** workload the per-request
//! policy API targets: one pool serving `/v2/generate` traffic that
//! alternates between the `quality` and `aggressive` built-in profiles
//! (different pruning-config hashes ⇒ isolated prefix-cache configs).
//! It reports per-profile completion counts, latency, and mean
//! `relative_flops` (the quality/latency tier split), plus the
//! per-config prefix-cache rows from `GET /v1/pool`, and records
//! everything in `BENCH_policy.json`.
//!
//! A sixth phase is a **chaos soak**: the same engines wrapped in the
//! seeded [`ChaosEngine`] fault injector (transient step errors, engine
//! panics, begin-latency spikes from a fixed `FaultPlan`), driven with
//! direct pool submissions. Every request must still reach exactly one
//! terminal event: the phase reports completed/retried/failed counts,
//! replica restarts/panics, and the final conservation ledger, and
//! records them in `BENCH_chaos.json`.
//!
//! A seventh phase is a **mesh-overhead microbench**: dispatch-only
//! no-op jobs through the persistent per-device worker queues at
//! tp ∈ {1, 2, 4} (single-worker round trip and the enqueue-all /
//! recv-all barrier `execute_sharded` uses), then full decode quanta
//! with pipelined execution on vs `--pipeline off` (upload of layer
//! l+1 overlapped with layer l's dispatch vs strict ordering). Records
//! everything in `BENCH_mesh.json`.
//!
//! An eighth phase drives the **tiered KV spill** (docs/TIERED_KV.md):
//! 4 distinct warm AV prefixes round-robin against a device prefix
//! budget that holds exactly one of them, tier on vs off — comparing
//! warm-hit rate, full re-prefills after warmup, and p50 resume
//! latency (promotion vs re-prefill). Records `BENCH_tiered.json`.
//!
//! A ninth phase compares **streamed vs buffered delivery** of the
//! same long workload over `POST /v2/generate`: SSE time-to-first-token
//! against the buffered full-response latency, plus the pool's KV
//! high-water in each mode. Records `BENCH_streaming.json`.
//!
//! ```sh
//! cargo run --release --example serve_load [model] [n_requests]
//! ```

#[path = "common/mod.rs"]
mod common;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fastav::avsynth::QuestionKind;
use fastav::coordinator::Coordinator;
use fastav::http::{api::make_handler, request, request_streaming, Server};
use fastav::metrics::Registry;
use fastav::model::{ModelEngine, PruningPlan};
use fastav::policy::{PolicyRegistry, PruningSpec};
use fastav::runtime::{DeviceWorker, JobOutcome};
use fastav::serving::{
    ChaosEngine, FaultKind, FaultPlan, FaultRule, FaultSite, FaultState, FaultWhen,
    PoolConfig, ReplicaPool,
};
use fastav::tokens::Layout;
use fastav::util::bench::{stats_from, BenchStats};
use fastav::util::json::Json;
use fastav::util::threadpool::ThreadPool;

/// Registry serving `plan` as the default `balanced` profile (plus the
/// built-in `off`) — the pre-profile serving behavior.
fn plan_registry(plan: &PruningPlan) -> Arc<PolicyRegistry> {
    Arc::new(PolicyRegistry::with_default_spec(
        "balanced",
        PruningSpec::from_plan(plan.clone()).expect("calibrated plan is valid"),
    ))
}

/// Short requests: an answer-length generation (≤ 8 tokens).
const SHORT_MAX_GEN: usize = 2;
/// Long requests: a captioning-length generation.
const LONG_MAX_GEN: usize = 16;
/// Every 4th request is long.
const LONG_EVERY: usize = 4;
/// Saturated-decode (phase 4) generation length per request.
const BATCH_MAX_GEN: usize = 24;

struct RunResult {
    name: &'static str,
    replicas: usize,
    wall: f64,
    ok: usize,
    rejected: usize,
    short: BenchStats,
    long: BenchStats,
}

impl RunResult {
    fn throughput(&self) -> f64 {
        self.ok as f64 / self.wall
    }

    fn to_json(&self) -> Json {
        let lat = |s: &BenchStats| {
            Json::obj(vec![
                ("mean_s", Json::num(s.mean)),
                ("p50_s", Json::num(s.p50)),
                ("p95_s", Json::num(s.p95)),
                ("max_s", Json::num(s.max)),
            ])
        };
        Json::obj(vec![
            ("replicas", Json::num(self.replicas as f64)),
            ("completed", Json::num(self.ok as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("wall_s", Json::num(self.wall)),
            ("throughput_rps", Json::num(self.throughput())),
            ("short_latency", lat(&self.short)),
            ("long_latency", lat(&self.long)),
        ])
    }

    fn report(&self) {
        println!(
            "\n[{}] {} replica(s): {} ok / {} rejected in {:.2}s — {:.2} req/s",
            self.name, self.replicas, self.ok, self.rejected, self.wall, self.throughput()
        );
        self.short.report();
        self.long.report();
    }
}

fn drive(
    name: &'static str,
    replicas: usize,
    model: &str,
    n_requests: usize,
    plan: PruningPlan,
    layout: Layout,
) -> RunResult {
    let cfg = PoolConfig {
        replicas,
        queue_cap: 256,
        max_inflight: 4,
        warmup: true,
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start_pool(common::artifact_root(), model.to_string(), cfg)
            .expect("start pool"),
    );
    // The handler cap is the long-request length; each request asks for
    // its own max_gen below it.
    let handler =
        make_handler(Arc::clone(&coord), layout, plan_registry(&plan), LONG_MAX_GEN, 1234);
    let server = Server::bind("127.0.0.1:0", 8, handler).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let server_thread = std::thread::spawn(move || server.serve());

    let datasets = ["avqa", "musicavqa", "avhbench"];
    let short_lat = Arc::new(Mutex::new(Vec::new()));
    let long_lat = Arc::new(Mutex::new(Vec::new()));
    let ok = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let clients = ThreadPool::new(8);
    let t0 = Instant::now();
    for i in 0..n_requests {
        let addr = addr.clone();
        let short_lat = Arc::clone(&short_lat);
        let long_lat = Arc::clone(&long_lat);
        let ok = Arc::clone(&ok);
        let rejected = Arc::clone(&rejected);
        let ds = datasets[i % datasets.len()];
        let is_long = i % LONG_EVERY == LONG_EVERY - 1;
        clients.execute(move || {
            let max_gen = if is_long { LONG_MAX_GEN } else { SHORT_MAX_GEN };
            let body = format!(
                r#"{{"dataset": "{}", "index": {}, "max_gen": {}}}"#,
                ds, i, max_gen
            );
            let t = Instant::now();
            match request(&addr, "POST", "/v1/generate", body.as_bytes()) {
                Ok((200, _)) => {
                    ok.fetch_add(1, Ordering::Relaxed);
                    let sink = if is_long { &long_lat } else { &short_lat };
                    sink.lock().unwrap().push(t.elapsed().as_secs_f64());
                }
                Ok((429, _)) => {
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
                Ok((code, resp)) => {
                    eprintln!("request {} -> {}: {}", i, code, String::from_utf8_lossy(&resp))
                }
                Err(e) => eprintln!("request {} failed: {}", i, e),
            }
        });
    }
    clients.wait_idle();
    let wall = t0.elapsed().as_secs_f64();

    for r in coord.pool_status() {
        println!(
            "  replica {}: {} completed, {} steps, peak-ish kv {} bytes",
            r.id, r.completed, r.steps_total, r.kv_bytes
        );
    }
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();

    let short = short_lat.lock().unwrap().clone();
    let long = long_lat.lock().unwrap().clone();
    let ok = ok.load(Ordering::Relaxed);
    if ok == 0 {
        eprintln!(
            "no request succeeded against {} — is the engine backend available? \
             (vendored xla stub cannot execute artifacts)",
            name
        );
        std::process::exit(1);
    }
    RunResult {
        name,
        replicas,
        wall,
        ok,
        rejected: rejected.load(Ordering::Relaxed),
        short: lat_stats(&format!("{} short (max_gen {})", name, SHORT_MAX_GEN), short),
        long: lat_stats(&format!("{} long  (max_gen {})", name, LONG_MAX_GEN), long),
    }
}

/// `stats_from` that tolerates an empty class (e.g. every long request
/// rejected) instead of panicking after the workload ran.
fn lat_stats(name: &str, samples: Vec<f64>) -> BenchStats {
    if samples.is_empty() {
        eprintln!("warning: no successful samples for {}", name);
        return stats_from(name, vec![0.0]);
    }
    stats_from(name, samples)
}

/// Repeated-prefix phase result (the AV-prefix cache workload).
struct PrefixRun {
    samples: usize,
    questions_per_sample: usize,
    completed: usize,
    rejected: usize,
    wall: f64,
    warm_hits: u64,
    misses: u64,
    evictions: u64,
    /// Σ `prefix_tokens_reused` over completed requests = front-half
    /// prefill tokens the cache skipped.
    prefill_tokens_saved: u64,
    warm_lat: BenchStats,
    cold_lat: BenchStats,
}

impl PrefixRun {
    fn to_json(&self) -> Json {
        let lat = |s: &BenchStats| {
            Json::obj(vec![
                ("mean_s", Json::num(s.mean)),
                ("p50_s", Json::num(s.p50)),
                ("p95_s", Json::num(s.p95)),
                ("max_s", Json::num(s.max)),
            ])
        };
        Json::obj(vec![
            ("samples", Json::num(self.samples as f64)),
            ("questions_per_sample", Json::num(self.questions_per_sample as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("wall_s", Json::num(self.wall)),
            ("prefix_hits", Json::num(self.warm_hits as f64)),
            ("prefix_misses", Json::num(self.misses as f64)),
            ("prefix_evictions", Json::num(self.evictions as f64)),
            ("prefill_tokens_saved", Json::num(self.prefill_tokens_saved as f64)),
            ("cold_latency", lat(&self.cold_lat)),
            ("warm_latency", lat(&self.warm_lat)),
        ])
    }
}

/// Drive the repeated-prefix workload: for each of `samples` samples,
/// one cold request (publishes the AV-prefix entry), then
/// `questions - 1` further questions about the *same* sample issued
/// concurrently — each should resume from the shared prefix.
fn drive_prefix(
    replicas: usize,
    model: &str,
    samples: usize,
    questions: usize,
    plan: PruningPlan,
    layout: Layout,
) -> PrefixRun {
    let cfg = PoolConfig {
        replicas,
        queue_cap: 256,
        max_inflight: 4,
        warmup: true,
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start_pool(common::artifact_root(), model.to_string(), cfg)
            .expect("start pool"),
    );
    let handler =
        make_handler(Arc::clone(&coord), layout, plan_registry(&plan), LONG_MAX_GEN, 1234);
    let server = Server::bind("127.0.0.1:0", 8, handler).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let server_thread = std::thread::spawn(move || server.serve());

    let completed = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let tokens_saved = Arc::new(AtomicUsize::new(0));
    let warm_lat = Arc::new(Mutex::new(Vec::new()));
    let mut cold_lat = Vec::new();
    let clients = ThreadPool::new(8);
    let t0 = Instant::now();
    for s in 0..samples {
        // Cold request first (synchronously): builds + publishes the
        // entry so the remaining questions hit a warm cache.
        let body = prefix_body(s, 0);
        let t = Instant::now();
        match request(&addr, "POST", "/v1/generate", body.as_bytes()) {
            Ok((200, resp)) => {
                completed.fetch_add(1, Ordering::Relaxed);
                cold_lat.push(t.elapsed().as_secs_f64());
                tokens_saved.fetch_add(reused_tokens(&resp), Ordering::Relaxed);
            }
            Ok((429, _)) => {
                rejected.fetch_add(1, Ordering::Relaxed);
            }
            other => eprintln!("cold request {} -> {:?}", s, other.map(|(c, _)| c)),
        }
        for q in 1..questions {
            let addr = addr.clone();
            let completed = Arc::clone(&completed);
            let rejected = Arc::clone(&rejected);
            let tokens_saved = Arc::clone(&tokens_saved);
            let warm_lat = Arc::clone(&warm_lat);
            clients.execute(move || {
                let body = prefix_body(s, q);
                let t = Instant::now();
                match request(&addr, "POST", "/v1/generate", body.as_bytes()) {
                    Ok((200, resp)) => {
                        completed.fetch_add(1, Ordering::Relaxed);
                        warm_lat.lock().unwrap().push(t.elapsed().as_secs_f64());
                        tokens_saved.fetch_add(reused_tokens(&resp), Ordering::Relaxed);
                    }
                    Ok((429, _)) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok((code, resp)) => eprintln!(
                        "warm request {}/{} -> {}: {}",
                        s,
                        q,
                        code,
                        String::from_utf8_lossy(&resp)
                    ),
                    Err(e) => eprintln!("warm request {}/{} failed: {}", s, q, e),
                }
            });
        }
    }
    clients.wait_idle();
    let wall = t0.elapsed().as_secs_f64();

    // Cache counters from the pool endpoint.
    let (hits, misses, evictions) = match request(&addr, "GET", "/v1/pool", b"") {
        Ok((200, body)) => {
            let j = Json::parse(std::str::from_utf8(&body).unwrap_or("")).unwrap_or(Json::Null);
            let p = j.get("prefix_cache");
            (
                p.get("hits").as_f64().unwrap_or(0.0) as u64,
                p.get("misses").as_f64().unwrap_or(0.0) as u64,
                p.get("evictions").as_f64().unwrap_or(0.0) as u64,
            )
        }
        _ => (0, 0, 0),
    };
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();

    let warm = warm_lat.lock().unwrap().clone();
    PrefixRun {
        samples,
        questions_per_sample: questions,
        completed: completed.load(Ordering::Relaxed),
        rejected: rejected.load(Ordering::Relaxed),
        wall,
        warm_hits: hits,
        misses,
        evictions,
        prefill_tokens_saved: tokens_saved.load(Ordering::Relaxed) as u64,
        cold_lat: lat_stats("prefix cold (miss)", cold_lat),
        warm_lat: lat_stats("prefix warm (hit)", warm),
    }
}

/// Body for question `q` about sample `s`: same (dataset, index) → same
/// AV prefix; the `question` field swaps the text suffix.
fn prefix_body(s: usize, q: usize) -> String {
    format!(
        r#"{{"dataset": "avqa", "index": {}, "max_gen": 2, "question": "{}"}}"#,
        s,
        QuestionKind::nth(q).name()
    )
}

/// Pull `prefix_tokens_reused` out of a generate response.
fn reused_tokens(resp: &[u8]) -> usize {
    std::str::from_utf8(resp)
        .ok()
        .and_then(|s| Json::parse(s).ok())
        .map(|j| j.get("prefix_tokens_reused").as_usize().unwrap_or(0))
        .unwrap_or(0)
}

/// Tiered-KV phase result: one configuration (tier on or off) under a
/// working set of `samples` warm prefixes against a device budget that
/// holds only one of them.
struct TieredRun {
    tiered: bool,
    completed: usize,
    /// Requests after the warmup pass (each *should* be warm).
    warm_requests: usize,
    warm_hits: u64,
    /// Device+tier misses after warmup = full AV re-prefills paid.
    reprefills: u64,
    promotions: u64,
    demotions: u64,
    warm_lat: BenchStats,
}

impl TieredRun {
    fn warm_hit_rate(&self) -> f64 {
        self.warm_hits as f64 / (self.warm_requests as f64).max(1.0)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tiered", Json::Bool(self.tiered)),
            ("completed", Json::num(self.completed as f64)),
            ("warm_requests", Json::num(self.warm_requests as f64)),
            ("warm_hits", Json::num(self.warm_hits as f64)),
            ("warm_hit_rate", Json::num(self.warm_hit_rate())),
            ("full_reprefills_after_warmup", Json::num(self.reprefills as f64)),
            ("tier_promotions", Json::num(self.promotions as f64)),
            ("tier_demotions", Json::num(self.demotions as f64)),
            (
                "resume_latency",
                Json::obj(vec![
                    ("mean_s", Json::num(self.warm_lat.mean)),
                    ("p50_s", Json::num(self.warm_lat.p50)),
                    ("p95_s", Json::num(self.warm_lat.p95)),
                    ("max_s", Json::num(self.warm_lat.max)),
                ]),
            ),
        ])
    }
}

/// Bytes one published AV-prefix entry occupies, measured on a probe
/// pool with an unlimited budget (sizes the phase-8 device budget so
/// the `samples`-prefix working set is `samples`× over budget).
fn probe_prefix_entry_bytes(model: &str, plan: PruningPlan, layout: &Layout) -> usize {
    let cfg = PoolConfig { replicas: 1, queue_cap: 16, max_inflight: 2, warmup: true, ..Default::default() };
    let coord = Arc::new(
        Coordinator::start_pool(common::artifact_root(), model.to_string(), cfg)
            .expect("start probe pool"),
    );
    let handler =
        make_handler(Arc::clone(&coord), layout.clone(), plan_registry(&plan), LONG_MAX_GEN, 1234);
    let server = Server::bind("127.0.0.1:0", 2, handler).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let server_thread = std::thread::spawn(move || server.serve());
    let _ = request(&addr, "POST", "/v1/generate", prefix_body(0, 0).as_bytes());
    let bytes = match request(&addr, "GET", "/v1/pool", b"") {
        Ok((200, body)) => Json::parse(std::str::from_utf8(&body).unwrap_or(""))
            .unwrap_or(Json::Null)
            .get("prefix_cache")
            .get("bytes")
            .as_f64()
            .unwrap_or(0.0) as usize,
        _ => 0,
    };
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();
    bytes.max(1)
}

/// Drive the phase-8 workload: `samples` distinct AV prefixes round-
/// robin against a device budget holding one entry. With the tier on,
/// every post-warmup request should promote from RAM (zero full
/// re-prefills); with it off, eviction discards and every re-request
/// re-prefills.
fn drive_tiered(
    model: &str,
    plan: PruningPlan,
    layout: &Layout,
    device_budget: usize,
    samples: usize,
    passes: usize,
    tiered: bool,
) -> TieredRun {
    let cfg = PoolConfig {
        replicas: 1,
        queue_cap: 256,
        max_inflight: 4,
        warmup: true,
        prefix_cache_bytes: device_budget,
        tier_ram_bytes: if tiered { 512 << 20 } else { 0 },
        tier_prune_interval: std::time::Duration::from_millis(5),
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start_pool(common::artifact_root(), model.to_string(), cfg)
            .expect("start pool"),
    );
    let handler =
        make_handler(Arc::clone(&coord), layout.clone(), plan_registry(&plan), LONG_MAX_GEN, 1234);
    let server = Server::bind("127.0.0.1:0", 4, handler).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let server_thread = std::thread::spawn(move || server.serve());

    let mut completed = 0usize;
    let mut warm_lat = Vec::new();
    // Sequential round-robin: every re-request of a sample arrives
    // after `samples - 1` other prefixes evicted it from the device.
    for pass in 0..passes {
        for s in 0..samples {
            let body = prefix_body(s, pass);
            let t = Instant::now();
            if let Ok((200, _)) = request(&addr, "POST", "/v1/generate", body.as_bytes()) {
                completed += 1;
                if pass > 0 {
                    warm_lat.push(t.elapsed().as_secs_f64());
                }
            }
        }
    }

    let pool = match request(&addr, "GET", "/v1/pool", b"") {
        Ok((200, body)) => {
            Json::parse(std::str::from_utf8(&body).unwrap_or("")).unwrap_or(Json::Null)
        }
        _ => Json::Null,
    };
    let n = |j: &Json| j.as_f64().unwrap_or(0.0) as u64;
    let p = pool.get("prefix_cache");
    let (hits, misses) = (n(p.get("hits")), n(p.get("misses")));
    let tier = pool.get("tier");
    let promotions =
        n(tier.get("ram").get("promotions")) + n(tier.get("disk").get("promotions"));
    let demotions =
        n(tier.get("ram").get("demotions")) + n(tier.get("disk").get("demotions"));
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();

    TieredRun {
        tiered,
        completed,
        warm_requests: samples * passes.saturating_sub(1),
        warm_hits: hits,
        reprefills: misses.saturating_sub(samples as u64),
        promotions,
        demotions,
        warm_lat: lat_stats(
            if tiered { "tiered warm (promote)" } else { "untiered warm (re-prefill)" },
            warm_lat,
        ),
    }
}

/// One saturated-decode measurement: `occupancy` concurrent
/// long-generation requests on a single replica.
struct BatchRun {
    occupancy: usize,
    batched: bool,
    completed: usize,
    tokens: usize,
    wall: f64,
    /// Pool-reported decode quanta + requests advanced by them.
    quanta: u64,
    quanta_tokens: u64,
}

impl BatchRun {
    fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.wall.max(1e-12)
    }

    fn mean_occupancy(&self) -> f64 {
        if self.quanta == 0 {
            0.0
        } else {
            self.quanta_tokens as f64 / self.quanta as f64
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("occupancy", Json::num(self.occupancy as f64)),
            ("batched", Json::Bool(self.batched)),
            ("completed", Json::num(self.completed as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("wall_s", Json::num(self.wall)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec())),
            ("decode_quanta", Json::num(self.quanta as f64)),
            ("mean_batch_occupancy", Json::num(self.mean_occupancy())),
        ])
    }
}

/// Drive `occupancy` concurrent long generations to completion on one
/// replica, with the fused decode path enabled or forced off.
fn drive_batch(
    model: &str,
    occupancy: usize,
    batched: bool,
    pipeline: bool,
    plan: PruningPlan,
    layout: &Layout,
) -> BatchRun {
    let cfg = PoolConfig {
        replicas: 1,
        queue_cap: 64,
        max_inflight: occupancy,
        warmup: true,
        max_decode_batch: if batched { 0 } else { 1 },
        pipeline,
        ..Default::default()
    };
    let coord =
        Coordinator::start_pool(common::artifact_root(), model.to_string(), cfg)
            .expect("start pool");
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..occupancy)
        .map(|i| {
            let s = fastav::avsynth::gen_sample(
                layout,
                fastav::avsynth::Dataset::Avqa,
                1000 + i as u64,
                1234,
            );
            coord
                .submit(fastav::coordinator::GenRequest {
                    prompt: s.prompt,
                    segments: s.segments,
                    frame_of: s.frame_of,
                    spec: PruningSpec::from_plan(plan.clone()).expect("valid plan"),
                    max_gen: BATCH_MAX_GEN,
                    sampling: Default::default(),
                    priority: fastav::coordinator::Priority::Normal,
                    deadline: None,
                    profile: None,
                })
                .expect("submit")
        })
        .collect();
    let mut completed = 0usize;
    let mut tokens = 0usize;
    for rx in receivers {
        for ev in rx {
            match ev {
                fastav::coordinator::Event::Token(_) => {}
                fastav::coordinator::Event::Done(res) => {
                    completed += 1;
                    tokens += res.tokens.len();
                    break;
                }
                fastav::coordinator::Event::Error(e) => {
                    eprintln!("saturated-decode request failed: {}", e);
                    break;
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (quanta, quanta_tokens) = coord.decode_batch_stats();
    coord.shutdown();
    BatchRun { occupancy, batched, completed, tokens, wall, quanta, quanta_tokens }
}

/// One profile's slice of the mixed-profile (phase 5) workload.
struct ProfileSlice {
    profile: &'static str,
    completed: usize,
    rejected: usize,
    mean_rel_flops: f64,
    lat: BenchStats,
}

impl ProfileSlice {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("profile", Json::str(self.profile)),
            ("completed", Json::num(self.completed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("mean_relative_flops", Json::num(self.mean_rel_flops)),
            (
                "latency",
                Json::obj(vec![
                    ("mean_s", Json::num(self.lat.mean)),
                    ("p50_s", Json::num(self.lat.p50)),
                    ("p95_s", Json::num(self.lat.p95)),
                    ("max_s", Json::num(self.lat.max)),
                ]),
            ),
        ])
    }
}

/// Phase 5: alternate `/v2/generate` requests between two built-in
/// profiles on one pool; returns the per-profile slices plus the
/// per-config prefix-cache rows the pool reported.
fn drive_profiles(
    model: &str,
    n_requests: usize,
    registry: Arc<PolicyRegistry>,
    layout: Layout,
) -> (Vec<ProfileSlice>, Json) {
    let cfg = PoolConfig {
        replicas: 2,
        queue_cap: 256,
        max_inflight: 4,
        warmup: true,
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start_pool(common::artifact_root(), model.to_string(), cfg)
            .expect("start pool"),
    );
    let handler = make_handler(Arc::clone(&coord), layout, registry, LONG_MAX_GEN, 1234);
    let server = Server::bind("127.0.0.1:0", 8, handler).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let server_thread = std::thread::spawn(move || server.serve());

    const PROFILES: [&str; 2] = ["quality", "aggressive"];
    let lat: Vec<Arc<Mutex<Vec<f64>>>> =
        (0..2).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
    let flops: Vec<Arc<Mutex<Vec<f64>>>> =
        (0..2).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
    let completed: Vec<Arc<AtomicUsize>> =
        (0..2).map(|_| Arc::new(AtomicUsize::new(0))).collect();
    let rejected: Vec<Arc<AtomicUsize>> =
        (0..2).map(|_| Arc::new(AtomicUsize::new(0))).collect();
    let clients = ThreadPool::new(8);
    for i in 0..n_requests {
        let which = i % 2;
        let addr = addr.clone();
        let lat = Arc::clone(&lat[which]);
        let flops = Arc::clone(&flops[which]);
        let completed = Arc::clone(&completed[which]);
        let rejected = Arc::clone(&rejected[which]);
        clients.execute(move || {
            // Few distinct samples so both profiles revisit prefixes —
            // per-spec cache isolation is what phase 5 exercises.
            let body = format!(
                r#"{{"profile": "{}", "dataset": "avqa", "index": {}, "max_gen": 2, "question": "{}"}}"#,
                PROFILES[which],
                i % 4,
                QuestionKind::nth(i / 4).name()
            );
            let t = Instant::now();
            match request(&addr, "POST", "/v2/generate", body.as_bytes()) {
                Ok((200, resp)) => {
                    completed.fetch_add(1, Ordering::Relaxed);
                    lat.lock().unwrap().push(t.elapsed().as_secs_f64());
                    if let Ok(j) = Json::parse(&String::from_utf8_lossy(&resp)) {
                        if let Some(f) = j.get("relative_flops").as_f64() {
                            flops.lock().unwrap().push(f);
                        }
                    }
                }
                Ok((429, _)) => {
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
                Ok((code, resp)) => eprintln!(
                    "profile request {} -> {}: {}",
                    i,
                    code,
                    String::from_utf8_lossy(&resp)
                ),
                Err(e) => eprintln!("profile request {} failed: {}", i, e),
            }
        });
    }
    clients.wait_idle();
    let per_config = match request(&addr, "GET", "/v1/pool", b"") {
        Ok((200, body)) => Json::parse(std::str::from_utf8(&body).unwrap_or(""))
            .map(|j| j.get("prefix_cache").get("per_config").clone())
            .unwrap_or(Json::Null),
        _ => Json::Null,
    };
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();

    let slices = (0..2)
        .map(|w| {
            let f = flops[w].lock().unwrap().clone();
            ProfileSlice {
                profile: PROFILES[w],
                completed: completed[w].load(Ordering::Relaxed),
                rejected: rejected[w].load(Ordering::Relaxed),
                mean_rel_flops: if f.is_empty() {
                    0.0
                } else {
                    f.iter().sum::<f64>() / f.len() as f64
                },
                lat: lat_stats(
                    &format!("profile {}", PROFILES[w]),
                    lat[w].lock().unwrap().clone(),
                ),
            }
        })
        .collect();
    (slices, per_config)
}

/// Phase 6 result: the workload's fate under the seeded fault plan.
struct ChaosRun {
    n: usize,
    completed: u64,
    failed: u64,
    retried: u64,
    restarts: u64,
    panics: u64,
    injected_errs: u64,
    injected_panics: u64,
    wall: f64,
    conserved: bool,
}

impl ChaosRun {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_requests", Json::num(self.n as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("retried", Json::num(self.retried as f64)),
            ("replica_restarts", Json::num(self.restarts as f64)),
            ("replica_panics", Json::num(self.panics as f64)),
            ("injected_errs", Json::num(self.injected_errs as f64)),
            ("injected_panics", Json::num(self.injected_panics as f64)),
            ("wall_s", Json::num(self.wall)),
            ("ledger_conserved", Json::Bool(self.conserved)),
        ])
    }
}

/// Drive `n` direct submissions through a pool whose engines are
/// wrapped in the seeded chaos injector. Every stream is drained to its
/// terminal event — a stall here is a stranded request, the exact bug
/// the supervision layer exists to prevent.
fn drive_chaos(model: &str, n: usize, plan: PruningPlan, layout: &Layout) -> ChaosRun {
    let state = FaultState::new(FaultPlan {
        seed: 42,
        rules: vec![
            // A transient engine error every ~300 steps (bounded).
            FaultRule {
                site: FaultSite::Step,
                when: FaultWhen::Every(300),
                kind: FaultKind::Err,
                max_injections: 4,
            },
            // Two engine panics over the run: each poisons its replica
            // and forces a supervised respawn.
            FaultRule {
                site: FaultSite::Step,
                when: FaultWhen::Every(701),
                kind: FaultKind::Panic,
                max_injections: 2,
            },
            // Occasional begin-latency spikes (tail-latency injection).
            FaultRule {
                site: FaultSite::Begin,
                when: FaultWhen::WithProbability(0.05),
                kind: FaultKind::Latency(Duration::from_millis(5)),
                max_injections: 0,
            },
        ],
    });
    let cfg = PoolConfig {
        replicas: 2,
        queue_cap: 256,
        max_inflight: 4,
        restart_backoff: Duration::from_millis(5),
        ..Default::default()
    };
    let metrics = Arc::new(Registry::default());
    let root = common::artifact_root();
    let model_name = model.to_string();
    let pool = {
        let state = Arc::clone(&state);
        ReplicaPool::start_with_factory(cfg, Arc::clone(&metrics), move |_replica| {
            // Engines are built on their replica threads (PJRT handles
            // never cross threads) — including supervised respawns.
            Ok(ChaosEngine::new(
                ModelEngine::load(&root, &model_name)?,
                Arc::clone(&state),
            ))
        })
        .expect("start chaos pool")
    };

    let t0 = Instant::now();
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let s = fastav::avsynth::gen_sample(
                layout,
                fastav::avsynth::Dataset::Avqa,
                i as u64,
                1234,
            );
            pool.submit(fastav::coordinator::GenRequest::with_spec(
                s.prompt,
                s.segments,
                s.frame_of,
                PruningSpec::from_plan(plan.clone()).expect("valid plan"),
                if i % LONG_EVERY == LONG_EVERY - 1 { LONG_MAX_GEN } else { SHORT_MAX_GEN },
            ))
        })
        .filter_map(|r| r.ok().map(|(_, rx)| rx))
        .collect();
    for rx in receivers {
        // Done and Error are both terminal; the receiver iterator ends
        // when the pool drops its sender after the terminal event.
        for ev in rx {
            if matches!(
                ev,
                fastav::coordinator::Event::Done(_) | fastav::coordinator::Event::Error(_)
            ) {
                break;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = pool.stats();
    ChaosRun {
        n,
        completed: stats.completed,
        failed: stats.failed,
        retried: stats.retried,
        restarts: metrics.counter("fastav_replica_restarts_total").get(),
        panics: metrics.counter("fastav_replica_panics_total").get(),
        injected_errs: state.errs(),
        injected_panics: state.panics(),
        wall,
        conserved: stats.conserved(),
    }
}

/// Phase 7 dispatch-only measurement for one tensor-parallel degree:
/// the persistent-worker command-queue overhead with no PJRT execution
/// inside the job — the fixed per-quantum cost the mesh adds on top of
/// the kernels themselves.
struct MeshOverhead {
    tp: usize,
    iters: usize,
    /// Mean single-worker enqueue→reply round trip (the `execute` /
    /// `execute_queued` shape), microseconds.
    round_trip_us: f64,
    /// Mean enqueue-all → recv-all barrier across all `tp` workers (the
    /// `execute_sharded` shape), microseconds.
    fanout_us: f64,
}

impl MeshOverhead {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tp", Json::num(self.tp as f64)),
            ("iters", Json::num(self.iters as f64)),
            ("round_trip_us", Json::num(self.round_trip_us)),
            ("fanout_barrier_us", Json::num(self.fanout_us)),
        ])
    }
}

/// Measure worker-queue overhead at `tp` devices with no-op jobs.
fn measure_mesh_overhead(tp: usize, iters: usize) -> MeshOverhead {
    let workers: Vec<DeviceWorker> = (0..tp)
        .map(|d| DeviceWorker::spawn(d).expect("spawn device worker"))
        .collect();
    for w in &workers {
        for _ in 0..16 {
            w.call(|_rt| ()).expect("warmup job");
        }
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        workers[0].call(|_rt| ()).expect("round-trip job");
    }
    let round_trip_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        let rxs: Vec<_> = workers
            .iter()
            .map(|w| w.submit_outcome(|_rt| ()).expect("enqueue job"))
            .collect();
        for rx in rxs {
            match rx.recv().expect("worker reply") {
                JobOutcome::Done(()) => {}
                JobOutcome::Panicked(_) => panic!("no-op job panicked"),
            }
        }
    }
    let fanout_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    MeshOverhead { tp, iters, round_trip_us, fanout_us }
}

fn main() {
    let model = common::model_arg();
    let n_requests = common::n_arg(48).max(8);

    // Calibrate once (separate engine instance; serving engines live on
    // their replica threads), and grab the layout for request assembly.
    let (calib, plan, layout) = {
        let mut engine = common::load_engine(&model);
        let calib = common::load_or_calibrate(&mut engine, 50);
        let plan = calib.plan(20.0);
        (calib, plan, engine.cfg.layout.clone())
    };

    println!(
        "driving {} requests ({} short : 1 long) per configuration against {}",
        n_requests,
        LONG_EVERY - 1,
        model
    );
    let single = drive("single", 1, &model, n_requests, plan.clone(), layout.clone());
    single.report();
    let pool4 = drive("pool4", 4, &model, n_requests, plan.clone(), layout.clone());
    pool4.report();

    let speedup = pool4.throughput() / single.throughput().max(1e-12);
    println!("\npool-of-4 vs single-worker throughput: {:.2}x", speedup);

    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load")),
        ("model", Json::str(&model)),
        ("n_requests", Json::num(n_requests as f64)),
        ("short_max_gen", Json::num(SHORT_MAX_GEN as f64)),
        ("long_max_gen", Json::num(LONG_MAX_GEN as f64)),
        ("single", single.to_json()),
        ("pool4", pool4.to_json()),
        ("throughput_speedup", Json::num(speedup)),
        ("measured", Json::Bool(true)),
    ]);
    std::fs::write("BENCH_serving.json", out.to_string() + "\n").expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");

    // --- Phase 3: repeated-prefix workload (AV-prefix cache). ----------
    let samples = 4;
    let questions = 8;
    println!(
        "\ndriving repeated-prefix workload: {} samples x {} questions (pool of 2)",
        samples, questions
    );
    let prefix = drive_prefix(2, &model, samples, questions, plan.clone(), layout.clone());
    println!(
        "[prefix] {} ok / {} rejected in {:.2}s — {} hits / {} misses / {} evictions, \
         {} prefill tokens saved",
        prefix.completed,
        prefix.rejected,
        prefix.wall,
        prefix.warm_hits,
        prefix.misses,
        prefix.evictions,
        prefix.prefill_tokens_saved
    );
    prefix.cold_lat.report();
    prefix.warm_lat.report();
    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load_prefix")),
        ("model", Json::str(&model)),
        ("replicas", Json::num(2.0)),
        ("prefix", prefix.to_json()),
        ("measured", Json::Bool(true)),
        (
            "methodology",
            Json::str(
                "One cold request per sample publishes the AV-prefix entry; the \
                 remaining questions_per_sample-1 requests re-ask different questions \
                 (question body field) about the same sample concurrently. hits/misses/\
                 evictions come from GET /v1/pool prefix_cache; prefill_tokens_saved is \
                 the sum of per-response prefix_tokens_reused (front-half prefill tokens \
                 skipped by mid-sequence resume).",
            ),
        ),
    ]);
    std::fs::write("BENCH_prefix.json", out.to_string() + "\n").expect("write BENCH_prefix.json");
    println!("wrote BENCH_prefix.json");

    // --- Phase 4: saturated-decode workload (batched decode). ----------
    println!("\ndriving saturated-decode workload: occupancy 1/4/8, batched vs single-step");
    let mut runs = Vec::new();
    for &occ in &[1usize, 4, 8] {
        for &batched in &[true, false] {
            let r = drive_batch(&model, occ, batched, true, plan.clone(), &layout);
            println!(
                "[batch] occupancy {} {}: {} tokens in {:.2}s — {:.1} tok/s, \
                 mean batch occupancy {:.2} over {} decode quanta",
                r.occupancy,
                if r.batched { "batched " } else { "single-step" },
                r.tokens,
                r.wall,
                r.tokens_per_sec(),
                r.mean_occupancy(),
                r.quanta
            );
            runs.push(r);
        }
    }
    let speedup_at = |occ: usize| {
        let tps = |b: bool| {
            runs.iter()
                .find(|r| r.occupancy == occ && r.batched == b)
                .map(|r| r.tokens_per_sec())
                .unwrap_or(0.0)
        };
        tps(true) / tps(false).max(1e-12)
    };
    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load_batch")),
        ("model", Json::str(&model)),
        ("max_gen", Json::num(BATCH_MAX_GEN as f64)),
        ("runs", Json::arr(runs.iter().map(|r| r.to_json()))),
        ("speedup_occ4", Json::num(speedup_at(4))),
        ("speedup_occ8", Json::num(speedup_at(8))),
        ("measured", Json::Bool(true)),
        (
            "methodology",
            Json::str(
                "One replica, N concurrent long generations (pool occupancy 1/4/8) driven \
                 to completion; tokens_per_sec = total generated tokens / wall. batched=true \
                 runs with max_decode_batch=0 (fuse up to the artifact set's largest batch \
                 bucket per quantum); batched=false forces max_decode_batch=1 (the \
                 per-request single-token decode path). decode_quanta/mean_batch_occupancy \
                 come from the pool's decode_batch stats (the GET /v1/pool block).",
            ),
        ),
    ]);
    std::fs::write("BENCH_batch.json", out.to_string() + "\n").expect("write BENCH_batch.json");
    println!("wrote BENCH_batch.json");

    // --- Phase 5: mixed-profile workload (per-request pruning policy). --
    let registry = Arc::new(PolicyRegistry::builtin(&calib, 20.0));
    println!(
        "\ndriving mixed-profile workload: {} /v2/generate requests alternating \
         quality/aggressive (pool of 2)",
        n_requests
    );
    let (slices, per_config) = drive_profiles(&model, n_requests, registry, layout.clone());
    for s in &slices {
        println!(
            "[policy] {:<10} {} ok / {} rejected — mean rel FLOPs {:.1}",
            s.profile, s.completed, s.rejected, s.mean_rel_flops
        );
        s.lat.report();
    }
    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load_policy")),
        ("model", Json::str(&model)),
        ("replicas", Json::num(2.0)),
        ("n_requests", Json::num(n_requests as f64)),
        ("profiles", Json::arr(slices.iter().map(|s| s.to_json()))),
        ("prefix_per_config", per_config),
        ("measured", Json::Bool(true)),
        (
            "methodology",
            Json::str(
                "One pool of 2 replicas serving /v2/generate traffic that alternates \
                 between the quality and aggressive built-in profiles over 4 repeated \
                 samples x rotating questions (so both profiles revisit warm AV \
                 prefixes). Per-profile mean relative_flops shows the quality/latency \
                 tier split one pool sustains concurrently; prefix_per_config (from \
                 GET /v1/pool) shows per-spec prefix-cache isolation — each profile's \
                 pruning-config hash owns its own entries/hits/misses row.",
            ),
        ),
    ]);
    std::fs::write("BENCH_policy.json", out.to_string() + "\n")
        .expect("write BENCH_policy.json");
    println!("wrote BENCH_policy.json");

    // --- Phase 6: chaos soak (fault-domain supervision). ---------------
    println!(
        "\ndriving chaos soak: {} requests under a seeded FaultPlan (pool of 2)",
        n_requests
    );
    let chaos = drive_chaos(&model, n_requests, plan.clone(), &layout);
    println!(
        "[chaos] {} completed / {} failed / {} retried in {:.2}s — \
         {} restarts, {} caught panics ({} injected errs, {} injected panics), \
         ledger conserved: {}",
        chaos.completed,
        chaos.failed,
        chaos.retried,
        chaos.wall,
        chaos.restarts,
        chaos.panics,
        chaos.injected_errs,
        chaos.injected_panics,
        chaos.conserved
    );
    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load_chaos")),
        ("model", Json::str(&model)),
        ("replicas", Json::num(2.0)),
        ("chaos", chaos.to_json()),
        ("measured", Json::Bool(true)),
        (
            "methodology",
            Json::str(
                "One pool of 2 replicas whose engines are wrapped in the seeded \
                 ChaosEngine injector (FaultPlan seed 42: transient step errors, two \
                 engine panics, 5% begin-latency spikes). Every submission is drained \
                 to its terminal event; completed/failed/retried come from the pool \
                 ledger, replica_restarts/panics from the supervision counters. The \
                 soak passes when every request reaches exactly one terminal event \
                 and ledger_conserved is true.",
            ),
        ),
    ]);
    std::fs::write("BENCH_chaos.json", out.to_string() + "\n").expect("write BENCH_chaos.json");
    println!("wrote BENCH_chaos.json");

    // --- Phase 7: mesh overhead + pipelined quantum execution. ---------
    println!("\nmeasuring mesh dispatch overhead (persistent workers, no-op jobs)");
    let overheads: Vec<MeshOverhead> = [1usize, 2, 4]
        .iter()
        .map(|&tp| {
            let o = measure_mesh_overhead(tp, 512);
            println!(
                "[mesh] tp={}: {:.1}us round trip, {:.1}us fan-out barrier",
                o.tp, o.round_trip_us, o.fanout_us
            );
            o
        })
        .collect();
    println!("\ndriving full decode quanta: occupancy 8, pipelined vs --pipeline off");
    let mut pipe_runs = Vec::new();
    for &pipelined in &[true, false] {
        let r = drive_batch(&model, 8, true, pipelined, plan.clone(), &layout);
        println!(
            "[mesh] pipeline {}: {} tokens in {:.2}s — {:.1} tok/s",
            if pipelined { "on " } else { "off" },
            r.tokens,
            r.wall,
            r.tokens_per_sec()
        );
        pipe_runs.push((pipelined, r));
    }
    let tps_at = |on: bool| {
        pipe_runs
            .iter()
            .find(|(p, _)| *p == on)
            .map(|(_, r)| r.tokens_per_sec())
            .unwrap_or(0.0)
    };
    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load_mesh")),
        ("model", Json::str(&model)),
        ("dispatch_only", Json::arr(overheads.iter().map(|o| o.to_json()))),
        (
            "full_quantum",
            Json::arr(pipe_runs.iter().map(|(p, r)| {
                Json::obj(vec![("pipelined", Json::Bool(*p)), ("run", r.to_json())])
            })),
        ),
        ("pipeline_speedup", Json::num(tps_at(true) / tps_at(false).max(1e-12))),
        ("measured", Json::Bool(true)),
        (
            "methodology",
            Json::str(
                "dispatch_only: no-op jobs through the persistent per-device worker \
                 queues at tp=1/2/4 — round_trip_us is one enqueue→reply cycle on a \
                 single worker (the execute/execute_queued shape), fanout_barrier_us \
                 is enqueue-all→recv-all across all tp workers (the execute_sharded \
                 shape); both isolate command-queue overhead from kernel time. \
                 full_quantum: one replica, 8 concurrent long generations, batched \
                 decode, with pipelined quantum execution (layer l+1's KV gather + \
                 literal build overlapped with layer l's in-flight dispatch, plus \
                 delta-append staging buffers) vs pipeline=false (strict sequential \
                 upload→dispatch). pipeline_speedup = pipelined tok/s over \
                 sequential tok/s; tokens are byte-identical between the two runs.",
            ),
        ),
    ]);
    std::fs::write("BENCH_mesh.json", out.to_string() + "\n").expect("write BENCH_mesh.json");
    println!("wrote BENCH_mesh.json");

    // --- Phase 8: tiered KV spill (working set 4× device budget). ------
    let tier_samples = 4usize;
    let tier_passes = 4usize;
    println!(
        "\ndriving tiered-KV workload: {} warm prefixes, device budget holds 1, tier on vs off",
        tier_samples
    );
    let entry_bytes = probe_prefix_entry_bytes(&model, plan.clone(), &layout);
    println!("[tiered] one prefix entry = {} bytes (device budget)", entry_bytes);
    let mut tier_runs = Vec::new();
    for &tiered in &[true, false] {
        let r = drive_tiered(
            &model,
            plan.clone(),
            &layout,
            entry_bytes,
            tier_samples,
            tier_passes,
            tiered,
        );
        println!(
            "[tiered] tier {}: warm-hit rate {:.2}, {} full re-prefills, \
             p50 resume {:.4}s ({} promotions / {} demotions)",
            if tiered { "on " } else { "off" },
            r.warm_hit_rate(),
            r.reprefills,
            r.warm_lat.p50,
            r.promotions,
            r.demotions
        );
        tier_runs.push(r);
    }
    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load_tiered")),
        ("model", Json::str(&model)),
        ("samples", Json::num(tier_samples as f64)),
        ("passes", Json::num(tier_passes as f64)),
        ("device_budget_bytes", Json::num(entry_bytes as f64)),
        ("runs", Json::arr(tier_runs.iter().map(|r| r.to_json()))),
        ("measured", Json::Bool(true)),
        (
            "methodology",
            Json::str(
                "4 distinct AV samples requested round-robin for `passes` passes \
                 against a device prefix budget sized (by a probe pool) to hold \
                 exactly one entry, so every re-request finds its prefix evicted. \
                 tiered=true attaches a 512 MiB host-RAM spill tier (demote on \
                 evict, promote on probe, background pruner at 5 ms); tiered=false \
                 is the discard-on-evict baseline. warm_hit_rate and \
                 full_reprefills_after_warmup come from GET /v1/pool \
                 prefix_cache/tier blocks; resume_latency is per-request wall time \
                 for post-warmup requests (promotion + suffix vs full re-prefill).",
            ),
        ),
    ]);
    std::fs::write("BENCH_tiered.json", out.to_string() + "\n")
        .expect("write BENCH_tiered.json");
    println!("wrote BENCH_tiered.json");

    // --- Phase 9: streamed vs buffered delivery (TTFT + pool memory). --
    let stream_n = (n_requests / 2).max(8);
    println!(
        "\ndriving streamed-delivery workload: {} long generations, SSE vs buffered",
        stream_n
    );
    let mut stream_runs = Vec::new();
    for &streaming in &[true, false] {
        let r = drive_streaming(&model, stream_n, plan.clone(), layout.clone(), streaming);
        println!(
            "[stream] {}: {} ok in {:.2}s — ttft p50 {:.4}s, total p50 {:.4}s, \
             kv high-water {} bytes",
            if streaming { "sse     " } else { "buffered" },
            r.completed,
            r.wall,
            r.ttft.p50,
            r.total.p50,
            r.kv_high_water
        );
        stream_runs.push(r);
    }
    let out = Json::obj(vec![
        ("benchmark", Json::str("serve_load_streaming")),
        ("model", Json::str(&model)),
        ("requests", Json::num(stream_n as f64)),
        ("max_gen", Json::num(LONG_MAX_GEN as f64)),
        ("runs", Json::arr(stream_runs.iter().map(|r| r.to_json()))),
        ("measured", Json::Bool(true)),
        (
            "methodology",
            Json::str(
                "The same long-generation workload (max_gen 16, 8 concurrent \
                 clients, 1 replica) driven twice over POST /v2/generate: once \
                 with \"stream\": true (TTFT = wall time to the first SSE token \
                 event) and once buffered (TTFT = full-response latency — the \
                 pre-streaming user experience). kv_high_water_bytes is the max \
                 of GET /v1/pool kv_blocks.bytes_used sampled at 5 ms during \
                 each run. Streaming should cut p50 TTFT by roughly the decode \
                 tail (15/16ths of decode time) at equal total latency.",
            ),
        ),
    ]);
    std::fs::write("BENCH_streaming.json", out.to_string() + "\n")
        .expect("write BENCH_streaming.json");
    println!("wrote BENCH_streaming.json");
}

/// Phase 9 result: one delivery mode's view of the long workload.
struct StreamRun {
    streaming: bool,
    completed: usize,
    wall: f64,
    /// Streamed: wall time to the first SSE `token` event. Buffered:
    /// full-response latency (tokens only arrive with the 200 body).
    ttft: BenchStats,
    total: BenchStats,
    /// Max `kv_blocks.bytes_used` observed during the run.
    kv_high_water: u64,
}

impl StreamRun {
    fn to_json(&self) -> Json {
        let lat = |s: &BenchStats| {
            Json::obj(vec![
                ("mean_s", Json::num(s.mean)),
                ("p50_s", Json::num(s.p50)),
                ("p95_s", Json::num(s.p95)),
                ("max_s", Json::num(s.max)),
            ])
        };
        Json::obj(vec![
            ("streaming", Json::Bool(self.streaming)),
            ("completed", Json::num(self.completed as f64)),
            ("wall_s", Json::num(self.wall)),
            ("ttft", lat(&self.ttft)),
            ("total", lat(&self.total)),
            ("kv_high_water_bytes", Json::num(self.kv_high_water as f64)),
        ])
    }
}

/// Drive `n` long generations through `/v2/generate` in the given
/// delivery mode, sampling the pool's KV high-water alongside.
fn drive_streaming(
    model: &str,
    n: usize,
    plan: PruningPlan,
    layout: Layout,
    streaming: bool,
) -> StreamRun {
    let cfg = PoolConfig {
        replicas: 1,
        queue_cap: 256,
        max_inflight: 8,
        warmup: true,
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::start_pool(common::artifact_root(), model.to_string(), cfg)
            .expect("start pool"),
    );
    let handler =
        make_handler(Arc::clone(&coord), layout, plan_registry(&plan), LONG_MAX_GEN, 1234);
    let server = Server::bind("127.0.0.1:0", 8, handler).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let server_thread = std::thread::spawn(move || server.serve());

    // KV high-water sampler (5 ms): reads `kv_blocks.bytes_used` from
    // the pool endpoint for the memory half of the comparison.
    let sampling = Arc::new(std::sync::atomic::AtomicBool::new(true));
    let high_water = Arc::new(AtomicUsize::new(0));
    let sampler = {
        let addr = addr.clone();
        let sampling = Arc::clone(&sampling);
        let high_water = Arc::clone(&high_water);
        std::thread::spawn(move || {
            while sampling.load(Ordering::SeqCst) {
                if let Ok((200, body)) = request(&addr, "GET", "/v1/pool", b"") {
                    if let Ok(j) = Json::parse(&String::from_utf8_lossy(&body)) {
                        let used = j
                            .get("kv_blocks")
                            .get("bytes_used")
                            .as_usize()
                            .unwrap_or(0);
                        high_water.fetch_max(used, Ordering::Relaxed);
                    }
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };

    let ttft_lat = Arc::new(Mutex::new(Vec::new()));
    let total_lat = Arc::new(Mutex::new(Vec::new()));
    let ok = Arc::new(AtomicUsize::new(0));
    let clients = ThreadPool::new(8);
    let t0 = Instant::now();
    for i in 0..n {
        let addr = addr.clone();
        let ttft_lat = Arc::clone(&ttft_lat);
        let total_lat = Arc::clone(&total_lat);
        let ok = Arc::clone(&ok);
        clients.execute(move || {
            let body = format!(
                r#"{{"dataset": "avqa", "index": {}, "max_gen": {}, "stream": {}}}"#,
                i, LONG_MAX_GEN, streaming
            );
            let t = Instant::now();
            if streaming {
                let mut first_token: Option<f64> = None;
                let mut saw_done = false;
                let status =
                    request_streaming(&addr, "POST", "/v2/generate", body.as_bytes(), |c| {
                        let text = String::from_utf8_lossy(c);
                        if first_token.is_none() && text.contains("event: token") {
                            first_token = Some(t.elapsed().as_secs_f64());
                        }
                        if text.contains("event: done") {
                            saw_done = true;
                        }
                    });
                if matches!(status, Ok(200)) && saw_done {
                    ok.fetch_add(1, Ordering::Relaxed);
                    let total = t.elapsed().as_secs_f64();
                    ttft_lat.lock().unwrap().push(first_token.unwrap_or(total));
                    total_lat.lock().unwrap().push(total);
                }
            } else {
                match request(&addr, "POST", "/v2/generate", body.as_bytes()) {
                    Ok((200, _)) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                        let total = t.elapsed().as_secs_f64();
                        // Buffered clients see nothing until the body:
                        // TTFT *is* the full latency.
                        ttft_lat.lock().unwrap().push(total);
                        total_lat.lock().unwrap().push(total);
                    }
                    Ok((code, resp)) => eprintln!(
                        "request {} -> {}: {}",
                        i,
                        code,
                        String::from_utf8_lossy(&resp)
                    ),
                    Err(e) => eprintln!("request {} failed: {}", i, e),
                }
            }
        });
    }
    clients.wait_idle();
    let wall = t0.elapsed().as_secs_f64();
    sampling.store(false, Ordering::SeqCst);
    let _ = sampler.join();
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();

    let name = if streaming { "sse" } else { "buffered" };
    StreamRun {
        streaming,
        completed: ok.load(Ordering::Relaxed),
        wall,
        ttft: lat_stats(&format!("{} ttft", name), ttft_lat.lock().unwrap().clone()),
        total: lat_stats(&format!("{} total", name), total_lat.lock().unwrap().clone()),
        kv_high_water: high_water.load(Ordering::Relaxed) as u64,
    }
}
