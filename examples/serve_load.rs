//! End-to-end serving validation (DESIGN.md §5): boots the full stack —
//! HTTP server → coordinator → scheduler → engine worker → PJRT — then
//! drives a batched client workload over real sockets and reports
//! throughput + latency, vanilla vs FastAV.
//!
//! ```sh
//! cargo run --release --example serve_load [model] [n_requests]
//! ```

#[path = "common/mod.rs"]
mod common;

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use fastav::coordinator::Coordinator;
use fastav::http::{api::make_handler, request, Server};
use fastav::util::bench::stats_from;
use fastav::util::json::Json;
use fastav::util::threadpool::ThreadPool;

fn main() {
    let model = common::model_arg();
    let n_requests = common::n_arg(24);

    // Calibrate first (separate engine instance; the serving engine lives
    // on the coordinator's thread).
    let calib = {
        let mut engine = common::load_engine(&model);
        common::load_or_calibrate(&mut engine, 50)
    };
    let layout = {
        let engine = common::load_engine(&model);
        engine.cfg.layout.clone()
    };

    let coord = Arc::new(
        Coordinator::start(common::artifact_root(), model.clone(), 128, true)
            .expect("coordinator"),
    );
    let handler = make_handler(Arc::clone(&coord), layout, calib.plan(20.0), 4, 1234);
    let server = Server::bind("127.0.0.1:0", 8, handler).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let server_thread = std::thread::spawn(move || server.serve());
    println!("serving {} at {} — driving {} requests per mode", model, addr, n_requests);

    let datasets = ["avqa", "musicavqa", "avhbench"];
    for (mode, no_pruning) in [("fastav", false), ("vanilla", true)] {
        let latencies = Arc::new(Mutex::new(Vec::new()));
        let correct = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let flops = Arc::new(Mutex::new(Vec::new()));
        let pool = ThreadPool::new(6);
        let t0 = Instant::now();
        for i in 0..n_requests {
            let addr = addr.clone();
            let latencies = Arc::clone(&latencies);
            let correct = Arc::clone(&correct);
            let flops = Arc::clone(&flops);
            let ds = datasets[i % datasets.len()];
            pool.execute(move || {
                let body = format!(
                    r#"{{"dataset": "{}", "index": {}, "no_pruning": {}}}"#,
                    ds, i, no_pruning
                );
                let t = Instant::now();
                match request(&addr, "POST", "/v1/generate", body.as_bytes()) {
                    Ok((200, resp)) => {
                        latencies.lock().unwrap().push(t.elapsed().as_secs_f64());
                        if let Ok(j) = Json::parse(std::str::from_utf8(&resp).unwrap_or("")) {
                            if j.get("correct").as_bool() == Some(true) {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                            if let Some(f) = j.get("relative_flops").as_f64() {
                                flops.lock().unwrap().push(f);
                            }
                        }
                    }
                    Ok((code, _)) => eprintln!("request {} -> {}", i, code),
                    Err(e) => eprintln!("request {} failed: {}", i, e),
                }
            });
        }
        pool.wait_idle();
        let wall = t0.elapsed().as_secs_f64();
        let lat = latencies.lock().unwrap().clone();
        let fl = flops.lock().unwrap();
        let mean_flops = fl.iter().sum::<f64>() / fl.len().max(1) as f64;
        let stats = stats_from(&format!("{} end-to-end latency", mode), lat);
        println!(
            "\n[{}] {}/{} ok, accuracy {:.1}%, throughput {:.2} req/s, mean rel-FLOPs {:.1}",
            mode,
            stats.iters,
            n_requests,
            100.0 * correct.load(Ordering::Relaxed) as f64 / n_requests as f64,
            stats.iters as f64 / wall,
            mean_flops,
        );
        stats.report();
    }

    println!("\nserver metrics:\n{}", coord.metrics.export());
    stop.store(true, Ordering::SeqCst);
    let _ = server_thread.join();
}
