//! Table 3 — fine-pruning strategy ablation on AVHBench (vl2sim), global
//! pruning fixed to the calibrated FastAV rule, P = 20%.
//!
//! Paper shape: Low attentive (ours) > Random > Top attentive; low
//! attentive matches or beats vanilla.
//!
//! ```sh
//! cargo run --release --example table3_fine [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use fastav::avsynth::Dataset;
use fastav::eval::evaluate;
use fastav::model::PruningPlan;
use fastav::pruning::FineStrategy;

fn main() {
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let dataset = std::env::args()
        .nth(2)
        .and_then(|s| fastav::avsynth::Dataset::parse(&s))
        .unwrap_or(Dataset::AvhBench);
    let mut engine = common::load_engine("vl2sim");
    engine.warmup().ok();
    let calib = common::load_or_calibrate(&mut engine, 50);
    println!(
        "Table 3 — fine pruning strategies (vl2sim, avhbench, n={}, P=20%)",
        n
    );
    println!(
        "{:<24} {:>6} {:>8} {:>8} {:>8}",
        "strategy", "FLOPs", "hall%", "match%", "acc%"
    );

    let fastav_global = calib.plan(20.0).global;
    let mut rows: Vec<(&str, PruningPlan)> = vec![("Vanilla", PruningPlan::vanilla())];
    for (name, fine) in [
        ("Random", FineStrategy::Random),
        ("Top attentive", FineStrategy::TopAttentive),
        ("Low attentive (Ours)", FineStrategy::LowAttentive),
    ] {
        rows.push((
            name,
            PruningPlan {
                global: fastav_global.clone(),
                global_budget: calib.budget,
                fine,
                fine_percent: 20.0,
                ..PruningPlan::vanilla()
            },
        ));
    }

    for (name, plan) in rows {
        let report = evaluate(&mut engine, dataset, n, 1234, &plan, 4).expect("eval");
        let hall = report.subtask_accuracy("hallucination").unwrap_or(0.0);
        let mat = report.subtask_accuracy("matching").unwrap_or(0.0);
        println!(
            "{:<24} {:>6.1} {:>8.1} {:>8.1} {:>8.1}",
            name,
            report.mean_rel_flops,
            hall,
            mat,
            report.accuracy()
        );
    }
}
