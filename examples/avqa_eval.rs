//! Detailed single-benchmark evaluation: per-subtask accuracy, FLOPs,
//! latency, memory, and the per-layer live-token trace for one sample.
//!
//! ```sh
//! cargo run --release --example avqa_eval [model] [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::eval::evaluate;
use fastav::model::{GenerateOptions, PruningPlan, RequestInput};

fn main() {
    let model = common::model_arg();
    let n = common::n_arg(60);
    let mut engine = common::load_engine(&model);
    engine.warmup().ok();
    let calib = common::load_or_calibrate(&mut engine, 50);

    println!("avsynth-AVQA detailed evaluation — model {}, n={}", model, n);
    println!(
        "calibrated rule: vis_cutoff {}, keep_audio {}, keep_frames {}, budget {}",
        calib.vis_cutoff, calib.keep_audio, calib.keep_frames, calib.budget
    );

    for (tag, plan) in [
        ("vanilla", PruningPlan::vanilla()),
        ("fastav(P=20)", calib.plan(20.0)),
    ] {
        let r = evaluate(&mut engine, Dataset::Avqa, n, 1234, &plan, 4).expect("eval");
        println!(
            "\n[{}] accuracy {:.1}%  rel-FLOPs {:.1}  prefill {:.1}ms  {:.2}ms/tok  kv {:.2}MB",
            tag,
            r.accuracy(),
            r.mean_rel_flops,
            r.mean_prefill_s * 1e3,
            r.mean_decode_tok_s * 1e3,
            r.mean_peak_kv_bytes / 1e6
        );
        for (name, s) in &r.per_subtask {
            println!("    {:<18} n={:<4} acc {:.1}%", name, s.n, s.accuracy());
        }
    }

    // Pruning trace for one sample: live tokens entering each layer.
    let s = gen_sample(&engine.cfg.layout.clone(), Dataset::Avqa, 0, 1234);
    let res = engine
        .generate(
            &RequestInput::from_sample(&s),
            &GenerateOptions { plan: calib.plan(20.0), max_gen: 4, ..Default::default() },
        )
        .expect("generate");
    println!(
        "\npruning trace (sample 0, prompt {} tokens): live tokens per layer = {:?}",
        s.prompt.len(),
        res.live_counts
    );
}
