//! Shared glue for the examples: artifact discovery, engine setup, and
//! load-or-run calibration.

use std::path::PathBuf;

use fastav::calibration::{calibrate, Calibration};
use fastav::model::ModelEngine;

#[allow(dead_code)]
pub fn artifact_root() -> PathBuf {
    // Examples run from the repo root (cargo run --example ...).
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load the engine or exit with a pointer to `make artifacts`.
#[allow(dead_code)]
pub fn load_engine(model: &str) -> ModelEngine {
    match ModelEngine::load(&artifact_root(), model) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot load model '{}': {:#}", model, e);
            eprintln!("build artifacts first: make artifacts");
            std::process::exit(1);
        }
    }
}

/// Load `calibration.json` or run calibration (100 samples) and save it.
#[allow(dead_code)]
pub fn load_or_calibrate(engine: &mut ModelEngine, samples: usize) -> Calibration {
    let path = artifact_root()
        .join(&engine.cfg.name)
        .join("calibration.json");
    if let Ok(c) = Calibration::load(&path) {
        if c.samples >= samples {
            return c;
        }
    }
    eprintln!("calibrating {} ({} samples)...", engine.cfg.name, samples);
    let c = calibrate(engine, samples, 1234).expect("calibration");
    c.save(&path).expect("save calibration");
    c
}

/// Model name from argv[1], default vl2sim.
#[allow(dead_code)]
pub fn model_arg() -> String {
    std::env::args().nth(1).unwrap_or_else(|| "vl2sim".to_string())
}

/// Optional sample-count argv[2].
#[allow(dead_code)]
pub fn n_arg(default: usize) -> usize {
    std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}
