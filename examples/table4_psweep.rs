//! Table 4 — fine-pruning ratio sweep on AVHBench (vl2sim): P in
//! {0, 10, 20, 30}%, global pruning fixed.
//!
//! Paper shape: FLOPs fall with P; P = 20% gives the best average
//! accuracy at low FLOPs (P = 0 is global-only).
//!
//! ```sh
//! cargo run --release --example table4_psweep [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use fastav::avsynth::Dataset;
use fastav::eval::evaluate;

fn main() {
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let dataset = std::env::args()
        .nth(2)
        .and_then(|s| fastav::avsynth::Dataset::parse(&s))
        .unwrap_or(Dataset::AvhBench);
    let mut engine = common::load_engine("vl2sim");
    engine.warmup().ok();
    let calib = common::load_or_calibrate(&mut engine, 50);
    println!("Table 4 — pruning ratio P sweep (vl2sim, avhbench, n={})", n);
    println!(
        "{:<10} {:>6} {:>8} {:>8} {:>8}",
        "P (%)", "FLOPs", "hall%", "match%", "acc%"
    );

    for p in [0.0, 10.0, 20.0, 30.0] {
        let plan = if p == 0.0 { calib.global_only_plan() } else { calib.plan(p) };
        let report = evaluate(&mut engine, dataset, n, 1234, &plan, 4).expect("eval");
        let hall = report.subtask_accuracy("hallucination").unwrap_or(0.0);
        let mat = report.subtask_accuracy("matching").unwrap_or(0.0);
        let label = if p == 20.0 { "20 (Ours)".to_string() } else { format!("{:.0}", p) };
        println!(
            "{:<10} {:>6.1} {:>8.1} {:>8.1} {:>8.1}",
            label,
            report.mean_rel_flops,
            hall,
            mat,
            report.accuracy()
        );
    }
}
