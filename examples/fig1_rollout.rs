//! Fig. 1 — attention rollout at the middle layer for both models.
//!
//! Writes `results/fig1_<model>_rollout_mid.csv` (full n×n rollout matrix
//! averaged over calibration samples) plus a per-position summary of the
//! last-query row. Paper shape: accumulated attention concentrates on
//! early positions ("anchor" pattern to the left of the cutoff).
//!
//! ```sh
//! cargo run --release --example fig1_rollout [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use std::io::Write;

use fastav::avsynth::{gen_sample, Dataset};

fn main() {
    let n_samples = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    std::fs::create_dir_all("results").expect("mkdir results");

    for model in ["vl2sim", "salmsim"] {
        let mut engine = common::load_engine(model);
        let layout = engine.cfg.layout.clone();
        let mid = engine.cfg.mid_layer;
        let k_ref = gen_sample(&layout, Dataset::Calib, 0, 1234).prompt.len();
        let mut acc = vec![0.0f64; k_ref * k_ref];
        let mut used = 0usize;

        for i in 0..n_samples {
            let s = gen_sample(&layout, Dataset::Calib, i as u64, 1234);
            if s.prompt.len() != k_ref {
                continue; // keep the matrix shape uniform
            }
            let probe = engine.calib_probe(&s.prompt).expect("probe");
            for r in 0..k_ref {
                for c in 0..k_ref {
                    acc[r * k_ref + c] += probe.rollout_at(mid, r, c) as f64;
                }
            }
            used += 1;
        }
        assert!(used > 0, "no uniform-length calib samples");

        let path = format!("results/fig1_{}_rollout_mid.csv", model);
        let mut f = std::fs::File::create(&path).expect("create csv");
        for r in 0..k_ref {
            let row: Vec<String> = (0..k_ref)
                .map(|c| format!("{:.6e}", acc[r * k_ref + c] / used as f64))
                .collect();
            writeln!(f, "{}", row.join(",")).unwrap();
        }
        println!("wrote {} ({}x{} over {} samples)", path, k_ref, k_ref, used);

        // Last-query row summary: where does the final token's influence live?
        let last = k_ref - 1;
        let row: Vec<f64> = (0..k_ref).map(|c| acc[last * k_ref + c] / used as f64).collect();
        let front_mass: f64 = row[..k_ref / 4].iter().sum();
        let back_mass: f64 = row[3 * k_ref / 4..].iter().sum();
        println!(
            "  {}: first-quarter mass {:.3}, last-quarter mass {:.3}  (anchor ratio {:.1}x)",
            model,
            front_mass,
            back_mass,
            front_mass / back_mass.max(1e-9)
        );
    }
}
