//! Fig. 2 — attention rollout vs raw attention across layers (vl2sim).
//!
//! For early / middle / late layers, writes the last-query row of (a) the
//! accumulated rollout and (b) the raw head-averaged attention to
//! `results/fig2_vl2sim_layer<k>_{rollout,attn}.csv`, and prints the
//! early-position concentration of each. Paper shape: rollout concentrates
//! on early tokens from the middle layer onward; raw attention shows no
//! clear pattern.
//!
//! ```sh
//! cargo run --release --example fig2_rollout_vs_attn [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use std::io::Write;

use fastav::avsynth::{gen_sample, Dataset};

fn main() {
    let n_samples = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    std::fs::create_dir_all("results").expect("mkdir results");

    let mut engine = common::load_engine("vl2sim");
    let layout = engine.cfg.layout.clone();
    let n_layers = engine.cfg.n_layers;
    let mid = engine.cfg.mid_layer;
    // Early / middle / late probe layers (paper: 4, 14, 24 of 28).
    let probes = [1.max(n_layers / 4), mid, n_layers - 1];

    let k_ref = gen_sample(&layout, Dataset::Calib, 0, 1234).prompt.len();
    let mut roll = vec![vec![0.0f64; k_ref]; probes.len()];
    let mut attn = vec![vec![0.0f64; k_ref]; probes.len()];
    let mut used = 0usize;

    for i in 0..n_samples {
        let s = gen_sample(&layout, Dataset::Calib, i as u64, 1234);
        if s.prompt.len() != k_ref {
            continue;
        }
        let probe = engine.calib_probe(&s.prompt).expect("probe");
        let last = k_ref - 1;
        for (pi, &layer) in probes.iter().enumerate() {
            for c in 0..k_ref {
                roll[pi][c] += probe.rollout_at(layer, last, c) as f64;
                attn[pi][c] += probe.attn_at(layer, last, c) as f64;
            }
        }
        used += 1;
    }
    assert!(used > 0);

    println!("Fig 2 — rollout vs raw attention (vl2sim, {} samples)", used);
    println!(
        "{:>6} {:>22} {:>22}",
        "layer", "rollout front-mass", "raw-attn front-mass"
    );
    for (pi, &layer) in probes.iter().enumerate() {
        for (tag, data) in [("rollout", &roll[pi]), ("attn", &attn[pi])] {
            let path = format!("results/fig2_vl2sim_layer{}_{}.csv", layer, tag);
            let mut f = std::fs::File::create(&path).expect("create csv");
            writeln!(f, "position,value").unwrap();
            for (c, v) in data.iter().enumerate() {
                writeln!(f, "{},{:.6e}", c, v / used as f64).unwrap();
            }
        }
        let front = |d: &Vec<f64>| d[..k_ref / 4].iter().sum::<f64>() / d.iter().sum::<f64>();
        println!(
            "{:>6} {:>21.1}% {:>21.1}%",
            layer,
            100.0 * front(&roll[pi]),
            100.0 * front(&attn[pi])
        );
    }
    println!("CSV written to results/fig2_vl2sim_layer*_{{rollout,attn}}.csv");
}
