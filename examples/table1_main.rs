//! Table 1 — main results: FLOPs / latency / memory + accuracy for each
//! model × dataset, vanilla vs FastAV.
//!
//! Paper shape to reproduce: FastAV ≈ 55–60 relative FLOPs, ~30% faster
//! per token, lower memory, accuracy preserved or improved (AV matching
//! notably improves on VideoLLaMA2).
//!
//! ```sh
//! cargo run --release --example table1_main [n_samples]
//! ```

#[path = "common/mod.rs"]
mod common;

use fastav::avsynth::Dataset;
use fastav::eval::evaluate;
use fastav::model::PruningPlan;

fn main() {
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    println!("Table 1 — main results ({} samples per dataset)", n);
    println!(
        "{:<22} {:<10} {:>6} {:>9} {:>9} {:>8} {:>7} {:>7} {:>7} {:>7}",
        "model", "dataset", "FLOPs", "ms/tok", "KV MB", "acc%", "hall%", "match%", "cap/5", "music%"
    );

    for model in ["vl2sim", "salmsim"] {
        let mut engine = common::load_engine(model);
        if let Err(e) = engine.warmup() {
            eprintln!("warmup: {:#}", e);
        }
        let calib = common::load_or_calibrate(&mut engine, 50);
        for (tag, plan) in [
            ("vanilla", PruningPlan::vanilla()),
            ("fastav", calib.plan(20.0)),
        ] {
            for ds in [Dataset::MusicAvqa, Dataset::Avqa, Dataset::AvhBench] {
                // MUSIC-AVQA is NA for salmsim in the paper (long videos);
                // our substitute keeps the NA to preserve the table shape.
                if model == "salmsim" && ds == Dataset::MusicAvqa {
                    continue;
                }
                let report = evaluate(&mut engine, ds, n, 1234, &plan, 4).expect("eval");
                println!(
                    "{:<22} {:<10} {:>6.1} {:>9.2} {:>9.2} {:>8.1} {:>7} {:>7} {:>7} {:>7}",
                    format!("{} ({})", model, tag),
                    report.dataset,
                    report.mean_rel_flops,
                    report.mean_decode_tok_s * 1e3,
                    report.mean_peak_kv_bytes / 1e6,
                    report.accuracy(),
                    report
                        .subtask_accuracy("hallucination")
                        .map(|a| format!("{:.1}", a))
                        .unwrap_or_else(|| "-".into()),
                    report
                        .subtask_accuracy("matching")
                        .map(|a| format!("{:.1}", a))
                        .unwrap_or_else(|| "-".into()),
                    report
                        .caption_mean()
                        .map(|a| format!("{:.2}", a))
                        .unwrap_or_else(|| "-".into()),
                    report
                        .subtask_accuracy("how_many_beats")
                        .map(|a| format!("{:.1}", a))
                        .unwrap_or_else(|| "-".into()),
                );
            }
        }
    }
}
