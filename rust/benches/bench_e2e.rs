//! End-to-end generation latency (Table 1's latency/memory columns) plus
//! long-context scaling (vl2sim_long, 512-token prompts) where pruning
//! wins grow with sequence length, and a serving comparison: one
//! blocking replica vs a pool of four with iteration-level scheduling.

#[path = "bench_common/mod.rs"]
mod bench_common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::coordinator::{Coordinator, Event, GenRequest, Priority};
use fastav::model::{GenerateOptions, PruningPlan, RequestInput};
use fastav::policy::PruningSpec;
use fastav::serving::PoolConfig;
use fastav::util::bench::stats_from;

fn run_model(model: &str) {
    let Some(mut engine) = bench_common::try_engine(model) else { return };
    let calib = bench_common::load_or_calibrate(&mut engine, 30);
    let layout = engine.cfg.layout.clone();
    println!(
        "\n-- {} (prompt ~{} tokens) --",
        model,
        layout.prompt_len_max()
    );
    for (tag, plan) in [
        ("vanilla", PruningPlan::vanilla()),
        ("fastav ", calib.plan(20.0)),
    ] {
        let mut total = Vec::new();
        let (mut rel, mut kv) = (0.0f64, 0usize);
        for i in 0..5u64 {
            let s = gen_sample(&layout, Dataset::AvhBench, i, 1234);
            let res = engine
                .generate(
                    &RequestInput::from_sample(&s),
                    &GenerateOptions { plan: plan.clone(), max_gen: 4, ..Default::default() },
                )
                .expect("generate");
            total.push(res.prefill_seconds + res.decode_seconds);
            rel = res.relative_flops;
            kv = res.peak_kv_bytes;
        }
        let stats = stats_from(&format!("{} {} end-to-end", model, tag), total);
        stats.report();
        println!("    relative FLOPs {:.1}, peak KV {:.2} MB", rel, kv as f64 / 1e6);
    }
}

/// Throughput of the serving path: 16 mixed short/long requests pushed
/// at once, single replica vs pool of four.
fn run_pool_comparison(model: &str) {
    let Some(mut probe) = bench_common::try_engine(model) else { return };
    let calib = bench_common::load_or_calibrate(&mut probe, 30);
    let layout = probe.cfg.layout.clone();
    drop(probe); // serving engines live on their replica threads

    println!("\n-- {} serving throughput (12 short + 4 long requests) --", model);
    let mut single_rps = 0.0;
    for (tag, replicas) in [("single", 1usize), ("pool4", 4usize)] {
        let coord = Coordinator::start_pool(
            bench_common::artifact_root(),
            model.to_string(),
            PoolConfig {
                replicas,
                queue_cap: 128,
                max_inflight: 4,
                warmup: true,
                ..Default::default()
            },
        )
        .expect("start pool");
        let n = 16;
        let t0 = std::time::Instant::now();
        let receivers: Vec<_> = (0..n)
            .map(|i| {
                let s = gen_sample(&layout, Dataset::AvhBench, i as u64, 1234);
                let req = GenRequest {
                    prompt: s.prompt,
                    segments: s.segments,
                    frame_of: s.frame_of,
                    spec: PruningSpec::from_plan(calib.plan(20.0)).expect("valid plan"),
                    max_gen: if i % 4 == 3 { 16 } else { 2 },
                    sampling: Default::default(),
                    priority: Priority::Normal,
                    deadline: None,
                    profile: None,
                };
                coord.submit(req).expect("submit")
            })
            .collect();
        let mut failures = 0;
        for rx in receivers {
            for ev in rx {
                match ev {
                    Event::Done(_) => break,
                    Event::Error(_) => {
                        failures += 1;
                        break;
                    }
                    Event::Token(_) => {}
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let rps = (n - failures) as f64 / wall;
        if replicas == 1 {
            single_rps = rps;
        }
        println!(
            "    {:<7} {} ok / {} failed in {:6.2}s — {:6.2} req/s{}",
            tag,
            n - failures,
            failures,
            wall,
            rps,
            if replicas > 1 && single_rps > 0.0 {
                format!("  ({:.2}x vs single)", rps / single_rps)
            } else {
                String::new()
            }
        );
        coord.shutdown();
    }
}

fn main() {
    println!("== end-to-end generation latency ==");
    run_model("vl2sim");
    run_model("salmsim");
    run_model("vl2sim_long"); // long-context scaling
    println!("\n== serving: replica pool vs single worker ==");
    run_pool_comparison("vl2sim");
}
