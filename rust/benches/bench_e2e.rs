//! End-to-end generation latency (Table 1's latency/memory columns) plus
//! long-context scaling (vl2sim_long, 512-token prompts) where pruning
//! wins grow with sequence length.

#[path = "bench_common/mod.rs"]
mod bench_common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::model::{GenerateOptions, PruningPlan, RequestInput};
use fastav::util::bench::stats_from;

fn run_model(model: &str) {
    let Some(mut engine) = bench_common::try_engine(model) else { return };
    let calib = bench_common::load_or_calibrate(&mut engine, 30);
    let layout = engine.cfg.layout.clone();
    println!(
        "\n-- {} (prompt ~{} tokens) --",
        model,
        layout.prompt_len_max()
    );
    for (tag, plan) in [
        ("vanilla", PruningPlan::vanilla()),
        ("fastav ", calib.plan(20.0)),
    ] {
        let mut total = Vec::new();
        let (mut rel, mut kv) = (0.0f64, 0usize);
        for i in 0..5u64 {
            let s = gen_sample(&layout, Dataset::AvhBench, i, 1234);
            let res = engine
                .generate(
                    &RequestInput::from_sample(&s),
                    &GenerateOptions { plan: plan.clone(), max_gen: 4, ..Default::default() },
                )
                .expect("generate");
            total.push(res.prefill_seconds + res.decode_seconds);
            rel = res.relative_flops;
            kv = res.peak_kv_bytes;
        }
        let stats = stats_from(&format!("{} {} end-to-end", model, tag), total);
        stats.report();
        println!("    relative FLOPs {:.1}, peak KV {:.2} MB", rel, kv as f64 / 1e6);
    }
}

fn main() {
    println!("== end-to-end generation latency ==");
    run_model("vl2sim");
    run_model("salmsim");
    run_model("vl2sim_long"); // long-context scaling
}
