//! Decode-latency benchmark (paper §3.1: "latency indicates the time in
//! seconds to generate a single token during a forward pass").
//!
//! Measures per-token decode latency vanilla vs FastAV — the paper's
//! headline ~30% latency reduction (Table 1) comes from decoding over
//! pruned per-layer caches.

#[path = "bench_common/mod.rs"]
mod bench_common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::model::{GenerateOptions, PruningPlan, RequestInput};
use fastav::util::bench::stats_from;

fn main() {
    println!("== per-token decode latency ==");
    for model in ["vl2sim", "tiny"] {
        let Some(mut engine) = bench_common::try_engine(model) else { continue };
        let calib = bench_common::load_or_calibrate(&mut engine, 30);
        let layout = engine.cfg.layout.clone();

        for (tag, plan) in [
            ("vanilla", PruningPlan::vanilla()),
            ("fastav ", calib.plan(20.0)),
        ] {
            let mut per_tok = Vec::new();
            let mut rel = 0.0;
            for i in 0..6u64 {
                let s = gen_sample(&layout, Dataset::Avqa, i, 1234);
                let res = engine
                    .generate(
                        &RequestInput::from_sample(&s),
                        &GenerateOptions { plan: plan.clone(), max_gen: 4, ..Default::default() },
                    )
                    .expect("generate");
                if res.decode_steps > 0 {
                    per_tok.push(res.decode_seconds / res.decode_steps as f64);
                }
                rel = res.relative_flops;
            }
            if per_tok.is_empty() {
                println!("{} {}: no decode steps (answers were 1 token)", model, tag);
                continue;
            }
            let stats = stats_from(&format!("{} {} s/token", model, tag), per_tok);
            stats.report();
            println!("    relative FLOPs {:.1}", rel);
        }
    }
}
