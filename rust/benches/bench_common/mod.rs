//! Shared glue for bench targets (criterion is not on this image; each
//! bench is `harness = false` and uses `fastav::util::bench`).

use std::path::PathBuf;

use fastav::calibration::{calibrate, Calibration};
use fastav::model::ModelEngine;

#[allow(dead_code)]
pub fn artifact_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Load a model's engine; `None` (with a SKIP note) when artifacts are
/// missing so `cargo bench` stays green on a fresh checkout.
#[allow(dead_code)]
pub fn try_engine(model: &str) -> Option<ModelEngine> {
    match ModelEngine::load(&artifact_root(), model) {
        Ok(mut e) => {
            e.warmup().ok();
            Some(e)
        }
        Err(err) => {
            eprintln!("SKIP {}: {:#} (run `make artifacts`)", model, err);
            None
        }
    }
}

#[allow(dead_code)]
pub fn load_or_calibrate(engine: &mut ModelEngine, samples: usize) -> Calibration {
    let path = artifact_root()
        .join(&engine.cfg.name)
        .join("calibration.json");
    if let Ok(c) = Calibration::load(&path) {
        return c;
    }
    let c = calibrate(engine, samples, 1234).expect("calibration");
    c.save(&path).ok();
    c
}
