//! Kernel-level microbenchmarks: per-artifact execution latency (the L1
//! Pallas kernels live inside these artifacts).
//!
//! Reports prefill (flash-attention kernel path), pruned prefill, and the
//! calibration probe (rollout kernel). L1 TPU estimates live in DESIGN.md
//! §9; these CPU timings size the *serving* hot path.

#[path = "bench_common/mod.rs"]
mod bench_common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::model::{GenerateOptions, PruningPlan, RequestInput};
use fastav::util::bench::bench;

fn main() {
    println!("== kernel/artifact microbenchmarks ==");
    for model in ["tiny", "vl2sim"] {
        let Some(mut engine) = bench_common::try_engine(model) else { continue };
        let layout = engine.cfg.layout.clone();
        let sample = gen_sample(&layout, Dataset::Avqa, 0, 1234);
        let input = RequestInput::from_sample(&sample);

        // Whole-prefill benchmark (front + back layers + logits).
        let opts = GenerateOptions { plan: PruningPlan::vanilla(), max_gen: 1, ..Default::default() };
        bench(&format!("{}: prefill+1tok vanilla", model), 2, 8, || {
            engine.generate(&input, &opts).expect("generate");
        })
        .report();

        // Pruned prefill at the same shape.
        let opts_pruned = GenerateOptions {
            plan: PruningPlan::fastav(layout.vis_tokens() / 3, 2, 1, 20.0),
            max_gen: 1,
            ..Default::default()
        };
        bench(&format!("{}: prefill+1tok fastav", model), 2, 8, || {
            engine.generate(&input, &opts_pruned).expect("generate");
        })
        .report();

        // Calibration probe (rollout kernel path).
        bench(&format!("{}: calib_probe (rollout)", model), 1, 4, || {
            engine.calib_probe(&sample.prompt).expect("probe");
        })
        .report();
    }
}
