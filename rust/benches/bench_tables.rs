//! Strategy-ablation benchmark: latency + theoretical FLOPs per pruning
//! strategy (timing companion to Tables 2–4; accuracy rows come from the
//! example drivers, which run larger sample counts).

#[path = "bench_common/mod.rs"]
mod bench_common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::model::{GenerateOptions, PruningPlan, RequestInput};
use fastav::pruning::{FineStrategy, GlobalStrategy};
use fastav::util::bench::stats_from;

fn main() {
    println!("== pruning-strategy latency/FLOPs ablation (vl2sim) ==");
    let Some(mut engine) = bench_common::try_engine("vl2sim") else { return };
    let calib = bench_common::load_or_calibrate(&mut engine, 30);
    let layout = engine.cfg.layout.clone();

    let rows: Vec<(String, PruningPlan)> = vec![
        ("vanilla".into(), PruningPlan::vanilla()),
        ("fastav P=0 (global only)".into(), calib.global_only_plan()),
        ("fastav P=10".into(), calib.plan(10.0)),
        ("fastav P=20".into(), calib.plan(20.0)),
        ("fastav P=30".into(), calib.plan(30.0)),
        (
            "global random".into(),
            calib.ablation_plan(GlobalStrategy::Random, FineStrategy::None, 0.0),
        ),
        (
            "global low-attentive".into(),
            calib.ablation_plan(GlobalStrategy::LowAttentive, FineStrategy::None, 0.0),
        ),
        (
            "vtw (drop all AV)".into(),
            calib.ablation_plan(GlobalStrategy::Vtw, FineStrategy::None, 0.0),
        ),
        (
            "fastv (50% vis)".into(),
            calib.ablation_plan(
                GlobalStrategy::FastV { keep_ratio: 0.5 },
                FineStrategy::None,
                0.0,
            ),
        ),
    ];

    for (name, plan) in rows {
        let mut latencies = Vec::new();
        let mut rel = 0.0;
        for i in 0..4u64 {
            let s = gen_sample(&layout, Dataset::AvhBench, i, 1234);
            let res = engine
                .generate(
                    &RequestInput::from_sample(&s),
                    &GenerateOptions { plan: plan.clone(), max_gen: 4, ..Default::default() },
                )
                .expect("generate");
            latencies.push(res.prefill_seconds + res.decode_seconds);
            rel = res.relative_flops;
        }
        let stats = stats_from(&name, latencies);
        stats.report();
        println!("    relative FLOPs {:.1}", rel);
    }
}
