//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! This image builds with no registry access, so the crate graph must be
//! self-contained. This shim implements exactly the surface `fastav`
//! uses — `anyhow!`, `bail!`, `Context`, `Result`, `{:#}` chain
//! formatting, and `?` conversion from standard error types — with the
//! same observable semantics. Swap in the real crate by pointing the
//! `anyhow` dependency back at crates.io; no call sites change.

use std::fmt;

/// An error: a chain of messages, innermost cause first.
pub struct Error {
    /// `chain[0]` is the root cause; later entries are added context.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (like `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.push(c.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost message; `{:#}` prints the whole chain
    /// outermost-first, `": "`-separated (matching anyhow).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, msg) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{}", msg)?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // unwrap()/expect() go through Debug; show the full chain.
        write!(f, "{:#}", self)
    }
}

// `?` conversion from any std error. Error deliberately does NOT
// implement std::error::Error, so this blanket impl cannot conflict
// with `From<Error> for Error` (the same trick the real crate uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` (subset of anyhow's trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {}", args)` / `anyhow!(err)` — build an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `bail!(...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading config")
            .unwrap_err();
        assert_eq!(format!("{}", e), "reading config");
        assert_eq!(format!("{:#}", e), "reading config: missing");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(format!("{}", a), "plain");
        let b = anyhow!("x = {}", 7);
        assert_eq!(format!("{}", b), "x = 7");
        let c = anyhow!(String::from("owned"));
        assert_eq!(format!("{}", c), "owned");

        fn bails(flag: bool) -> Result<()> {
            if flag {
                bail!("nope {}", 1);
            }
            ensure!(!flag, "unreachable");
            Ok(())
        }
        assert_eq!(format!("{}", bails(true).unwrap_err()), "nope 1");
        assert!(bails(false).is_ok());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{}", e), "empty");
    }
}
