//! Host-only stub of the `xla` crate's PJRT surface.
//!
//! This image has no XLA runtime library, so the real `xla` crate (whose
//! build script links `libxla_extension`) cannot compile here. This stub
//! keeps the whole workspace buildable and the non-device test suite
//! green:
//!
//! * [`Literal`] is **fully functional** — shape + typed byte payload on
//!   the host. Everything in `fastav::runtime::literals` works for real.
//! * The PJRT pieces ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`]) parse artifacts but return a clear runtime error at
//!   `compile`/`execute` time. Engine paths already skip (tests) or
//!   report (CLI) when artifacts/devices are unavailable, so swapping the
//!   real crate back in is a one-line Cargo change with no call-site
//!   edits.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type; call sites only format it with `{:?}`.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

const NO_BACKEND: &str =
    "PJRT backend unavailable: this build uses the vendored host-only xla stub \
     (point the `xla` dependency at the real crate to execute artifacts)";

/// Element dtypes used by the fastav artifact ABI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn size_bytes(self) -> usize {
        match self {
            ElementType::F32 => 4,
            ElementType::S32 => 4,
        }
    }
}

/// Host value types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
    fn to_le(self) -> [u8; 4];
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: [u8; 4]) -> f32 {
        f32::from_le_bytes(b)
    }
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: [u8; 4]) -> i32 {
        i32::from_le_bytes(b)
    }
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

/// A host tensor: element type, dims, row-major little-endian payload.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    /// Tuple literals (artifact outputs) carry their elements instead.
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * ty.size_bytes() != data.len() {
            return err(format!(
                "shape {:?} needs {} bytes, got {}",
                dims,
                elems * ty.size_bytes(),
                data.len()
            ));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec(), tuple: None })
    }

    /// Build a tuple literal (what executable outputs decompose from).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), bytes: Vec::new(), tuple: Some(elements) }
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn shape_dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return err("to_vec on a tuple literal");
        }
        if self.ty != T::TY {
            return err(format!("dtype mismatch: literal is {:?}", self.ty));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|b| T::from_le([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first().copied().ok_or_else(|| Error("empty literal".into()))
    }

    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let v = self.to_vec::<T>()?;
        if v.len() != dst.len() {
            return err(format!("copy_raw_to: {} elems into {}", v.len(), dst.len()));
        }
        dst.copy_from_slice(&v);
        Ok(())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(elems) => Ok(elems),
            None => Ok(vec![self]),
        }
    }
}

/// Parsed HLO text module (stub: retains the source path + text).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {}: {}", path, e)))?;
        if !text.contains("HloModule") {
            return err(format!("{}: not an HLO text module", path));
        }
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation (stub wrapper around the proto).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT client (stub). Construction succeeds so engines can report a
/// uniform "backend unavailable" error at compile/execute time instead
/// of failing opaquely at startup.
pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "stub-host".to_string() })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(NO_BACKEND)
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        err(NO_BACKEND)
    }
}

/// Device-resident buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err(NO_BACKEND)
    }
}

/// Compiled executable (stub; unconstructible through the stub client,
/// but the execute API exists so call sites type-check).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(NO_BACKEND)
    }

    pub fn execute_b<T: Borrow<PjRtBuffer>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(NO_BACKEND)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<f32> = vec![1.0, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_shape_checked() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
            .unwrap();
        let t = Literal::tuple(vec![a.clone(), a]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn client_compiles_to_clear_error() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-host");
        let proto = HloModuleProto { text: "HloModule x".into() };
        let comp = XlaComputation::from_proto(&proto);
        let e = c.compile(&comp).unwrap_err();
        assert!(format!("{:?}", e).contains("PJRT backend unavailable"));
    }
}
