//! Coordinator integration tests: scheduling, streaming, backpressure,
//! conservation, and shutdown over the real tiny-model engine.

mod common;

use std::sync::Arc;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::coordinator::{Coordinator, Event, GenRequest, Priority};
use fastav::policy::PruningSpec;
use fastav::tokens::Layout;

fn layout() -> Layout {
    Layout { frames: 2, vis_per_frame: 4, aud_len: 6, aud_per_frame: 3, interleaved: false }
}

fn request(idx: u64, priority: Priority) -> GenRequest {
    let s = gen_sample(&layout(), Dataset::Avqa, idx, 1234);
    GenRequest {
        prompt: s.prompt,
        segments: s.segments,
        frame_of: s.frame_of,
        spec: PruningSpec::fastav(5, 2, 0, 20.0),
        max_gen: 3,
        sampling: Default::default(),
        priority,
        deadline: None,
        profile: None,
    }
}

#[test]
fn coordinator_processes_requests() {
    let Some(root) = common::tiny_ready() else { return };
    let coord = Coordinator::start(root, "tiny".into(), 16, false).unwrap();
    let res = coord.submit_blocking(request(0, Priority::Normal)).unwrap();
    assert!(!res.tokens.is_empty());
    assert!(res.relative_flops < 100.0);
    coord.shutdown();
}

#[test]
fn streaming_events_arrive_in_order() {
    let Some(root) = common::tiny_ready() else { return };
    let coord = Coordinator::start(root, "tiny".into(), 16, false).unwrap();
    let rx = coord.submit(request(1, Priority::Normal)).unwrap();
    let mut tokens = Vec::new();
    let mut done: Option<Vec<u32>> = None;
    for ev in rx {
        match ev {
            Event::Token(t) => tokens.push(t),
            Event::Done(res) => {
                done = Some(res.tokens.clone());
                break;
            }
            Event::Error(e) => panic!("unexpected error: {}", e),
        }
    }
    assert_eq!(Some(tokens), done);
    coord.shutdown();
}

#[test]
fn many_requests_all_complete_conservation() {
    let Some(root) = common::tiny_ready() else { return };
    let coord = Arc::new(Coordinator::start(root, "tiny".into(), 64, false).unwrap());
    let n = 12;
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let prio = if i % 3 == 0 { Priority::High } else { Priority::Normal };
            coord.submit(request(i as u64, prio)).unwrap()
        })
        .collect();
    let mut completed = 0;
    for rx in receivers {
        for ev in rx {
            if matches!(ev, Event::Done(_)) {
                completed += 1;
                break;
            }
            if let Event::Error(e) = ev {
                panic!("{}", e);
            }
        }
    }
    assert_eq!(completed, n);
    let stats = coord.sched_stats();
    assert_eq!(stats.admitted, n as u64);
    assert_eq!(stats.dequeued, n as u64);
    assert_eq!(stats.rejected, 0);
    assert_eq!(coord.queue_depth(), 0);
    assert_eq!(
        coord.metrics.counter("fastav_requests_completed_total").get(),
        n as u64
    );
}

#[test]
fn backpressure_rejects_when_full() {
    let Some(root) = common::tiny_ready() else { return };
    // Capacity 1: the first request occupies the worker, the second sits
    // in the queue, the third must bounce.
    let coord = Coordinator::start(root, "tiny".into(), 1, false).unwrap();
    let _rx1 = coord.submit(request(0, Priority::Normal)).unwrap();
    // Either accepted (if worker already pulled #1) or rejected; push until
    // a rejection proves the bound is enforced.
    let mut saw_reject = false;
    let mut held = Vec::new();
    for i in 1..10 {
        match coord.submit(request(i, Priority::Normal)) {
            Ok(rx) => held.push(rx),
            Err(_) => {
                saw_reject = true;
                break;
            }
        }
    }
    assert!(saw_reject, "queue of capacity 1 never rejected");
    assert!(coord.metrics.counter("fastav_requests_rejected_total").get() >= 1);
    // Drain what was accepted.
    for rx in held {
        for ev in rx {
            if matches!(ev, Event::Done(_) | Event::Error(_)) {
                break;
            }
        }
    }
}

#[test]
fn metrics_exported_after_traffic() {
    let Some(root) = common::tiny_ready() else { return };
    let coord = Coordinator::start(root, "tiny".into(), 8, false).unwrap();
    coord.submit_blocking(request(2, Priority::Normal)).unwrap();
    let text = coord.metrics.export();
    assert!(text.contains("fastav_requests_total 1"));
    assert!(text.contains("fastav_requests_completed_total 1"));
    assert!(text.contains("fastav_generate_seconds_count 1"));
    assert!(text.contains("fastav_tokens_generated_total"));
}

#[test]
fn shutdown_drains_cleanly() {
    let Some(root) = common::tiny_ready() else { return };
    let coord = Coordinator::start(root, "tiny".into(), 8, false).unwrap();
    let rx = coord.submit(request(3, Priority::Normal)).unwrap();
    coord.shutdown(); // must drain the in-flight request, then join
    let got_done = rx.iter().any(|ev| matches!(ev, Event::Done(_)));
    assert!(got_done, "in-flight request was dropped at shutdown");
}

#[test]
fn pool_of_two_replicas_serves_and_conserves() {
    let Some(root) = common::tiny_ready() else { return };
    let coord = Coordinator::start_pool(
        root,
        "tiny".into(),
        fastav::serving::PoolConfig {
            replicas: 2,
            queue_cap: 32,
            max_inflight: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(coord.replica_count(), 2);
    let n = 8;
    let receivers: Vec<_> = (0..n)
        .map(|i| coord.submit(request(i as u64, Priority::Normal)).unwrap())
        .collect();
    for rx in receivers {
        let done = rx.iter().any(|ev| matches!(ev, Event::Done(_)));
        assert!(done);
    }
    let stats = coord.pool_stats();
    assert_eq!(stats.submitted, n as u64);
    assert_eq!(stats.completed, n as u64);
    assert!(stats.conserved(), "ledger out of balance: {:?}", stats);
    let status = coord.pool_status();
    assert_eq!(status.len(), 2);
    // Least-loaded dispatch spread work across both replicas.
    assert!(
        status.iter().all(|r| r.completed > 0),
        "one replica sat idle: {:?}",
        status
    );
}

#[test]
fn cancellation_reaches_queued_request() {
    let Some(root) = common::tiny_ready() else { return };
    // One slot in flight: extra requests sit in the queue where a
    // cancel must drop them at pop.
    let coord = Coordinator::start_pool(
        root,
        "tiny".into(),
        fastav::serving::PoolConfig {
            replicas: 1,
            queue_cap: 16,
            max_inflight: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let _busy = coord.submit(request(0, Priority::Normal)).unwrap();
    let (id, rx) = coord.submit_with_id(request(1, Priority::Normal)).unwrap();
    // May race with completion on a fast engine; canceling an already
    // terminal id reports false, and the request then finishes Done.
    let was_live = coord.cancel(id);
    let mut saw_terminal_error = false;
    for ev in rx {
        match ev {
            Event::Error(msg) => {
                saw_terminal_error = true;
                assert!(msg.contains("cancel"), "unexpected error: {}", msg);
                break;
            }
            Event::Done(_) => break, // raced completion: acceptable
            Event::Token(_) => {}
        }
    }
    let stats = coord.pool_stats();
    assert!(
        !saw_terminal_error || stats.canceled >= 1,
        "canceled event without ledger entry: {:?}",
        stats
    );
    assert!(
        was_live || !saw_terminal_error,
        "cancel reported dead id yet the request was canceled"
    );
}
