//! Pipelined-quantum plumbing and equivalence, run against a mock
//! engine so no AOT artifacts are needed:
//!
//! * **Plumbing:** [`PoolConfig::pipeline`] reaches every engine through
//!   [`ReplicaEngine::set_pipeline`] at replica startup — including the
//!   rebuilt engine after a supervised respawn (a rebuilt engine that
//!   silently reverted to the default would change the execution order
//!   mid-deployment).
//! * **Equivalence:** the same workload driven with `pipeline: true` and
//!   `pipeline: false` produces token-for-token identical per-request
//!   streams and identical conservation ledgers. Each mock token is a
//!   deterministic function of (request seed, step index), so any
//!   reordering, loss, or duplication would change a stream.
//!
//! The real engine's pipelined path (`step_decode_batch_pipelined`) is
//! equivalence-argued where it overlaps: staging layer `l+1` touches
//! only layer `l+1` state, which the sequential order leaves untouched
//! until its own iteration. The delta-append gather it stages with is
//! property-tested against the stateless gather in `kvcache::gather`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::metrics::Registry;
use fastav::model::{GenerateResult, StepEvent};
use fastav::policy::PruningSpec;
use fastav::serving::{PoolConfig, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::util::proptest::{run_prop, Gen};

// ---------------------------------------------------------------- mock

/// Deterministic token stream: mixing up either the request identity or
/// the per-request step counter changes the token.
fn mock_token(seed: u64, step: usize) -> u32 {
    let x = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(step as u64)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    (x >> 33) as u32 % 1000
}

struct PipeGen {
    seed: u64,
    prefill_left: usize,
    produced: usize,
    total: usize,
}

/// What the pool told this engine family about pipelining, observable
/// from the test body.
#[derive(Default)]
struct PipeStats {
    /// `set_pipeline` invocations (one per engine build, respawns
    /// included).
    set_calls: AtomicUsize,
    /// Last value received.
    last_on: AtomicBool,
    /// One-shot step panic trigger (exercises the respawn path).
    panic_once: AtomicBool,
}

struct PipeMock {
    stats: Arc<PipeStats>,
    /// Engine-local mirror of the pool's pipeline flag.
    pipeline: bool,
}

impl PipeMock {
    fn advance(&self, gen: &mut PipeGen) -> StepEvent {
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return StepEvent::Prefilled { layer: 0 };
            }
        } else if gen.produced >= gen.total {
            return StepEvent::Done;
        }
        let tok = mock_token(gen.seed, gen.produced);
        gen.produced += 1;
        StepEvent::Token(tok)
    }
}

impl ReplicaEngine for PipeMock {
    type Gen = PipeGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<PipeGen> {
        Ok(PipeGen {
            seed: req.prompt.iter().fold(0u64, |a, &t| a * 31 + t as u64),
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
        })
    }

    fn step(&mut self, gen: &mut PipeGen) -> anyhow::Result<StepEvent> {
        if self.stats.panic_once.swap(false, Ordering::SeqCst) {
            panic!("injected step panic (pipeline respawn test)");
        }
        Ok(self.advance(gen))
    }

    fn is_decoding(&self, gen: &PipeGen) -> bool {
        gen.prefill_left == 0 && gen.produced > 0 && gen.produced < gen.total
    }

    fn max_decode_batch(&self) -> usize {
        8
    }

    fn step_batch(&mut self, gens: &mut [&mut PipeGen]) -> anyhow::Result<Vec<StepEvent>> {
        // The fused path must behave identically whichever mode the
        // pool configured — exactly the real engine's contract.
        let _mode = self.pipeline;
        Ok(gens.iter_mut().map(|g| self.advance(g)).collect())
    }

    fn is_done(&self, gen: &PipeGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: PipeGen) -> GenerateResult {
        GenerateResult {
            tokens: (0..gen.produced).map(|s| mock_token(gen.seed, s)).collect(),
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: 1000,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, _gen: &PipeGen) -> usize {
        1000
    }

    fn estimate_bytes(&self, _req: &GenRequest) -> usize {
        1000
    }

    fn set_pipeline(&mut self, on: bool) {
        self.pipeline = on;
        self.stats.set_calls.fetch_add(1, Ordering::SeqCst);
        self.stats.last_on.store(on, Ordering::SeqCst);
    }
}

fn pipe_request(seed_tok: u32, max_gen: usize) -> GenRequest {
    GenRequest {
        prompt: vec![seed_tok, 2, 3, 4],
        segments: vec![Segment::Ctrl, Segment::Vis, Segment::Aud, Segment::Text],
        frame_of: vec![-1, 0, -1, -1],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

fn pipe_pool(cfg: PoolConfig) -> (ReplicaPool, Arc<PipeStats>) {
    let stats = Arc::new(PipeStats::default());
    let s2 = Arc::clone(&stats);
    let pool = ReplicaPool::start_with_factory(cfg, Arc::new(Registry::default()), move |_r| {
        Ok(PipeMock { stats: Arc::clone(&s2), pipeline: true })
    })
    .expect("mock pool starts");
    (pool, stats)
}

/// Collect every request's full token stream (panics on stream errors).
fn streams(receivers: Vec<std::sync::mpsc::Receiver<Event>>) -> Vec<Vec<u32>> {
    receivers
        .into_iter()
        .map(|rx| {
            let mut toks = Vec::new();
            loop {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(Event::Token(t)) => toks.push(t),
                    Ok(Event::Done(res)) => {
                        assert_eq!(res.tokens, toks, "Done result diverges from stream");
                        return toks;
                    }
                    Ok(Event::Error(e)) => panic!("request failed: {}", e),
                    Err(e) => panic!("stream stalled: {}", e),
                }
            }
        })
        .collect()
}

fn settled(pool: &ReplicaPool) -> fastav::serving::PoolStats {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if (s.conserved() && s.in_flight == 0 && s.in_queue == 0)
            || t0.elapsed() > Duration::from_secs(10)
        {
            return s;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Drive one workload at the given pipeline setting.
fn drive(
    pipeline: bool,
    reqs: &[(u32, usize)],
) -> (Vec<Vec<u32>>, fastav::serving::PoolStats, Arc<PipeStats>) {
    let (pool, stats) = pipe_pool(PoolConfig {
        replicas: 1,
        queue_cap: 64,
        max_inflight: 4,
        pipeline,
        ..Default::default()
    });
    let receivers: Vec<_> = reqs
        .iter()
        .map(|&(seed, max_gen)| pool.submit(pipe_request(seed, max_gen)).unwrap().1)
        .collect();
    let streams = streams(receivers);
    let ledger = settled(&pool);
    (streams, ledger, stats)
}

// --------------------------------------------------------------- tests

#[test]
fn pool_forwards_pipeline_flag_to_every_engine() {
    for on in [true, false] {
        let (pool, stats) = pipe_pool(PoolConfig {
            replicas: 2,
            queue_cap: 8,
            pipeline: on,
            ..Default::default()
        });
        let rx = pool.submit(pipe_request(7, 2)).unwrap().1;
        let _ = streams(vec![rx]);
        // One call per replica engine, all with the configured value.
        // The second replica starts concurrently — poll briefly.
        let t0 = Instant::now();
        while stats.set_calls.load(Ordering::SeqCst) < 2
            && t0.elapsed() < Duration::from_secs(5)
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(
            stats.set_calls.load(Ordering::SeqCst) >= 2,
            "set_pipeline not applied on every replica"
        );
        assert_eq!(stats.last_on.load(Ordering::SeqCst), on);
        drop(pool);
    }
}

#[test]
fn respawned_engine_gets_the_pipeline_flag_again() {
    let (pool, stats) = pipe_pool(PoolConfig {
        replicas: 1,
        queue_cap: 8,
        pipeline: false,
        restart_backoff: Duration::from_millis(1),
        ..Default::default()
    });
    // Let the first engine come up, then arm a one-shot step panic: the
    // supervisor rebuilds the engine and must re-apply the flag.
    let warm = pool.submit(pipe_request(1, 2)).unwrap().1;
    let _ = streams(vec![warm]);
    let before = stats.set_calls.load(Ordering::SeqCst);
    stats.panic_once.store(true, Ordering::SeqCst);
    let rx = pool.submit(pipe_request(2, 2)).unwrap().1;
    let _ = streams(vec![rx]); // retried on the rebuilt engine
    assert!(
        stats.set_calls.load(Ordering::SeqCst) > before,
        "rebuilt engine never saw set_pipeline"
    );
    assert!(!stats.last_on.load(Ordering::SeqCst), "respawn lost pipeline=false");
}

#[test]
fn prop_pipelined_equals_sequential_streams() {
    run_prop("pipeline_stream_equivalence", 10, |g: &mut Gen| {
        let n = g.usize_in(2, 10);
        let reqs: Vec<(u32, usize)> = (0..n)
            .map(|i| (100 + i as u32 * 7, g.usize_in(1, 12)))
            .collect();

        let (on, on_ledger, on_stats) = drive(true, &reqs);
        let (off, off_ledger, off_stats) = drive(false, &reqs);

        assert_eq!(on, off, "pipeline on/off token streams must be identical");
        assert!(on_ledger.conserved(), "pipelined ledger: {:?}", on_ledger);
        assert!(off_ledger.conserved(), "sequential ledger: {:?}", off_ledger);
        assert_eq!(on_ledger.submitted, off_ledger.submitted);
        assert_eq!(on_ledger.completed, off_ledger.completed);
        assert_eq!(on_ledger.failed, off_ledger.failed);
        assert_eq!(on_ledger.completed, n as u64);
        // Both runs actually configured their engines.
        assert!(on_stats.last_on.load(Ordering::SeqCst));
        assert!(!off_stats.last_on.load(Ordering::SeqCst));
    });
}
