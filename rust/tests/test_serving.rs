//! Full-stack serving tests: HTTP server → coordinator → engine → PJRT,
//! all layers composed, exercised through real sockets.

mod common;

use std::sync::atomic::Ordering;
use std::sync::Arc;

use fastav::coordinator::Coordinator;
use fastav::http::{api::make_handler, request, request_with_headers, Server};
use fastav::policy::{PolicyRegistry, PruningSpec};
use fastav::tokens::Layout;
use fastav::util::json::Json;

fn layout() -> Layout {
    Layout { frames: 2, vis_per_frame: 4, aud_len: 6, aud_per_frame: 3, interleaved: false }
}

/// The registry every test serves: the four calibrated built-ins
/// (`quality`/`balanced`/`aggressive`/`off`; `balanced` — the default —
/// matches the plan the pre-profile tests passed to `make_handler`),
/// plus a `tight` profile with different positional cutoffs (⇒ a
/// different pruning-config hash) for the mixed-profile isolation test.
fn test_registry() -> Arc<PolicyRegistry> {
    let calib = fastav::calibration::Calibration {
        model: "tiny".into(),
        samples: 8,
        threshold: 0.01,
        vis_cutoff: 5,
        keep_audio: 2,
        keep_frames: 0,
        budget: 6,
        profile: Vec::new(),
    };
    let mut r = PolicyRegistry::builtin(&calib, 20.0);
    r.insert("tight", PruningSpec::fastav(3, 1, 0, 20.0)).unwrap();
    Arc::new(r)
}

struct Running {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    coord: Arc<Coordinator>,
}

impl Drop for Running {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn spin_up(root: std::path::PathBuf) -> Running {
    let coord = Arc::new(Coordinator::start(root, "tiny".into(), 16, false).unwrap());
    let handler = make_handler(Arc::clone(&coord), layout(), test_registry(), 3, 1234);
    let server = Server::bind("127.0.0.1:0", 2, handler).unwrap();
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let thread = std::thread::spawn(move || server.serve());
    Running { addr, stop, thread: Some(thread), coord }
}

#[test]
fn healthz_and_metrics() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, body) = request(&run.addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(code, 200);
    assert_eq!(body, b"ok");
    let (code, body) = request(&run.addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(code, 200);
    assert!(String::from_utf8_lossy(&body).contains("fastav_requests_total"));
}

#[test]
fn generate_roundtrip_with_and_without_pruning() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);

    let (code, body) = request(
        &run.addr,
        "POST",
        "/v1/generate",
        br#"{"dataset": "avqa", "index": 0}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let pruned_flops = j.get("relative_flops").as_f64().unwrap();
    assert!(pruned_flops < 100.0);
    assert!(j.get("answer").as_str().is_some());
    assert!(j.get("subtask").as_str().is_some());

    let (code, body) = request(
        &run.addr,
        "POST",
        "/v1/generate",
        br#"{"dataset": "avqa", "index": 0, "no_pruning": true}"#,
    )
    .unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let vanilla_flops = j.get("relative_flops").as_f64().unwrap();
    assert!((vanilla_flops - 100.0).abs() < 1e-6);
    assert!(pruned_flops < vanilla_flops);
}

#[test]
fn malformed_body_is_400() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, _) = request(&run.addr, "POST", "/v1/generate", b"{not json").unwrap();
    assert_eq!(code, 400);
}

#[test]
fn unknown_path_is_404() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, _) = request(&run.addr, "GET", "/nope", b"").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn request_id_echoed_and_pool_status_served() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let r = request_with_headers(
        &run.addr,
        "POST",
        "/v1/generate",
        &[("x-request-id", "trace-123")],
        br#"{"dataset": "avqa", "index": 1}"#,
    )
    .unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    assert_eq!(r.header("x-request-id"), Some("trace-123"));
    let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
    assert!(j.get("request_id").as_usize().is_some());

    // Pool status reflects the completed request.
    let (code, body) = request(&run.addr, "GET", "/v1/pool", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("replicas").as_arr().unwrap().len(), 1);
    assert!(j.get("stats").get("completed").as_f64().unwrap() >= 1.0);
}

#[test]
fn question_override_and_cache_flush_roundtrip() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    // Two different questions about the same sample: identical AV
    // prefix, different text suffix. The second should be able to reuse
    // the cached prefix (prefix_hit is engine-dependent here; the JSON
    // contract is what this test pins).
    for q in ["what_scene", "what_sound"] {
        let body = format!(r#"{{"dataset": "avqa", "index": 2, "question": "{}"}}"#, q);
        let (code, resp) =
            request(&run.addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
        assert_eq!(code, 200, "{}", String::from_utf8_lossy(&resp));
        let j = Json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
        assert!(j.get("prefix_hit").as_bool().is_some());
        assert!(j.get("prefix_tokens_reused").as_usize().is_some());
    }
    let (code, _) = request(
        &run.addr,
        "POST",
        "/v1/generate",
        br#"{"dataset": "avqa", "index": 2, "question": "nope"}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "unknown question override must 400");

    // Pool status exposes cache + block accounting; flush succeeds.
    let (code, body) = request(&run.addr, "GET", "/v1/pool", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("prefix_cache").get("misses").as_f64().is_some());
    assert!(j.get("kv_blocks").get("used").as_f64().is_some());
    let (code, body) = request(&run.addr, "POST", "/v1/cache/flush", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("flushed_entries").as_usize().is_some());
    assert!(j.get("freed_bytes").as_usize().is_some());
}

/// Golden test: the `/v1/generate` response shape is byte-compatible
/// with the pre-profile API — exactly the PR 4 key set (notably no
/// `policy` block), same types — and a `/v2/generate` request under the
/// default profile streams the identical result.
#[test]
fn v1_golden_response_shape_and_v2_default_equivalence() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let body = br#"{"dataset": "avqa", "index": 3}"#;
    let (code, v1) = request(&run.addr, "POST", "/v1/generate", body).unwrap();
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&v1));
    let v1 = Json::parse(std::str::from_utf8(&v1).unwrap()).unwrap();
    // The exact legacy key set, in the serializer's (sorted) order.
    let keys: Vec<&str> = v1.as_obj().unwrap().keys().map(|s| s.as_str()).collect();
    assert_eq!(
        keys,
        vec![
            "answer",
            "correct",
            "decode_seconds",
            "expected",
            "peak_kv_bytes",
            "prefill_seconds",
            "prefix_hit",
            "prefix_tokens_reused",
            "relative_flops",
            "request_id",
            "subtask",
            "tokens",
        ],
        "v1 response must stay byte-compatible (no new/renamed keys)"
    );
    // Same request through v2 with no profile = the default profile:
    // token-for-token identical, plus the resolved policy block. (Flush
    // the prefix cache first so both requests take the identical cold
    // path; warm-resume equivalence is covered elsewhere.)
    let (code, _) = request(&run.addr, "POST", "/v1/cache/flush", b"").unwrap();
    assert_eq!(code, 200);
    let (code, v2) = request(&run.addr, "POST", "/v2/generate", body).unwrap();
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&v2));
    let v2 = Json::parse(std::str::from_utf8(&v2).unwrap()).unwrap();
    assert_eq!(v2.get("tokens"), v1.get("tokens"));
    assert_eq!(v2.get("answer"), v1.get("answer"));
    assert_eq!(v2.get("relative_flops"), v1.get("relative_flops"));
    let policy = v2.get("policy");
    assert_eq!(policy.get("profile").as_str(), Some("balanced"));
    assert_eq!(policy.get("spec_hash").as_str().unwrap().len(), 16);
    assert_eq!(
        policy.get("spec").get("global").get("strategy").as_str(),
        Some("fastav_position")
    );
}

#[test]
fn unknown_body_fields_are_rejected_with_400() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    // v1 typo: "max_token" instead of "max_gen".
    let (code, body) = request(
        &run.addr,
        "POST",
        "/v1/generate",
        br#"{"dataset": "avqa", "max_token": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
    let msg = String::from_utf8_lossy(&body);
    assert!(msg.contains("max_token"), "400 must name the typo: {}", msg);
    // v2: no_pruning moved to the off profile.
    let (code, body) = request(
        &run.addr,
        "POST",
        "/v2/generate",
        br#"{"no_pruning": true}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
    assert!(String::from_utf8_lossy(&body).contains("no_pruning"));
    // Non-object bodies are rejected too.
    let (code, _) = request(&run.addr, "POST", "/v1/generate", b"[1, 2]").unwrap();
    assert_eq!(code, 400);
}

#[test]
fn policies_endpoint_lists_registry() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, body) = request(&run.addr, "GET", "/v1/policies", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("default").as_str(), Some("balanced"));
    let profiles = j.get("profiles").as_obj().unwrap();
    assert!(profiles.len() >= 4, "registry must list the 4 built-ins");
    for name in ["quality", "balanced", "aggressive", "off", "tight"] {
        let p = &profiles[name];
        assert!(p.get("spec").get("fine").get("percent").as_f64().is_some(), "{}", name);
        assert_eq!(p.get("spec_hash").as_str().unwrap().len(), 16, "{}", name);
    }
    // Unknown profile on generate is a 400 naming the known set.
    let (code, body) = request(
        &run.addr,
        "POST",
        "/v2/generate",
        br#"{"profile": "nope"}"#,
    )
    .unwrap();
    assert_eq!(code, 400);
    assert!(String::from_utf8_lossy(&body).contains("balanced"));
}

/// One pool, two profiles, same sample: per-spec prefix-cache isolation.
/// Each profile builds its own AV-prefix entry (different pruning-config
/// hash ⇒ different trie), re-use happens within a profile, and the
/// per-config rows of `GET /v1/pool` report the split.
#[test]
fn mixed_profiles_isolate_prefix_cache_per_spec() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    for profile in ["balanced", "tight", "balanced", "tight"] {
        let body = format!(
            r#"{{"dataset": "avqa", "index": 5, "profile": "{}"}}"#,
            profile
        );
        let (code, resp) =
            request(&run.addr, "POST", "/v2/generate", body.as_bytes()).unwrap();
        assert_eq!(code, 200, "{}: {}", profile, String::from_utf8_lossy(&resp));
        let j = Json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
        assert_eq!(j.get("policy").get("profile").as_str(), Some(profile));
    }
    let (code, body) = request(&run.addr, "GET", "/v1/pool", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let per = j.get("prefix_cache").get("per_config").as_arr().unwrap();
    let with_entries: Vec<_> = per
        .iter()
        .filter(|r| r.get("entries").as_usize().unwrap_or(0) > 0)
        .collect();
    assert!(
        with_entries.len() >= 2,
        "two positional profiles must build two isolated prefix configs: {}",
        j.get("prefix_cache").to_string()
    );
    for r in &with_entries {
        assert_eq!(r.get("config").as_str().unwrap().len(), 16);
        assert!(r.get("bytes").as_usize().unwrap() > 0);
    }
    // Per-profile traffic shows up in /metrics with the profile label.
    let (code, body) = request(&run.addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(code, 200);
    let text = String::from_utf8_lossy(&body);
    assert!(
        text.contains(r#"fastav_requests_total{profile="balanced"}"#),
        "labeled per-profile counter missing from /metrics"
    );
    assert!(text.contains(r#"fastav_requests_total{profile="tight"}"#));
}

#[test]
fn cancel_unknown_request_is_404() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, body) =
        request(&run.addr, "POST", "/v1/cancel", br#"{"request_id": 999999}"#).unwrap();
    assert_eq!(code, 404);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("canceled").as_bool(), Some(false));
}

#[test]
fn rejected_requests_carry_retry_after() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let barrier = Arc::new(std::sync::Barrier::new(16));
    let handles: Vec<_> = (0..16)
        .map(|i| {
            let addr = run.addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let body = format!(r#"{{"dataset": "avqa", "index": {}}}"#, i);
                barrier.wait();
                request_with_headers(&addr, "POST", "/v1/generate", &[], body.as_bytes())
                    .unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        match r.status {
            200 => {}
            // Backpressure must be retryable: 429 + Retry-After.
            429 => assert_eq!(r.header("retry-after"), Some("1")),
            other => panic!("unexpected status {}", other),
        }
    }
}

#[test]
fn concurrent_clients_all_served() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let addr = run.addr.clone();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = format!(r#"{{"dataset": "avhbench", "index": {}}}"#, i);
                request(&addr, "POST", "/v1/generate", body.as_bytes()).unwrap()
            })
        })
        .collect();
    for h in handles {
        let (code, body) = h.join().unwrap();
        assert!(
            code == 200 || code == 429,
            "unexpected status {}: {}",
            code,
            String::from_utf8_lossy(&body)
        );
    }
    // The engine saw at least one request end-to-end.
    assert!(run.coord.metrics.counter("fastav_requests_completed_total").get() >= 1);
}
