//! Full-stack serving tests: HTTP server → coordinator → engine → PJRT,
//! all layers composed, exercised through real sockets.

mod common;

use std::sync::atomic::Ordering;
use std::sync::Arc;

use fastav::coordinator::Coordinator;
use fastav::http::{api::make_handler, request, request_with_headers, Server};
use fastav::model::PruningPlan;
use fastav::tokens::Layout;
use fastav::util::json::Json;

fn layout() -> Layout {
    Layout { frames: 2, vis_per_frame: 4, aud_len: 6, aud_per_frame: 3, interleaved: false }
}

struct Running {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    coord: Arc<Coordinator>,
}

impl Drop for Running {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn spin_up(root: std::path::PathBuf) -> Running {
    let coord = Arc::new(Coordinator::start(root, "tiny".into(), 16, false).unwrap());
    let handler = make_handler(
        Arc::clone(&coord),
        layout(),
        PruningPlan::fastav(5, 2, 0, 20.0),
        3,
        1234,
    );
    let server = Server::bind("127.0.0.1:0", 2, handler).unwrap();
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let thread = std::thread::spawn(move || server.serve());
    Running { addr, stop, thread: Some(thread), coord }
}

#[test]
fn healthz_and_metrics() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, body) = request(&run.addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(code, 200);
    assert_eq!(body, b"ok");
    let (code, body) = request(&run.addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(code, 200);
    assert!(String::from_utf8_lossy(&body).contains("fastav_requests_total"));
}

#[test]
fn generate_roundtrip_with_and_without_pruning() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);

    let (code, body) = request(
        &run.addr,
        "POST",
        "/v1/generate",
        br#"{"dataset": "avqa", "index": 0}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let pruned_flops = j.get("relative_flops").as_f64().unwrap();
    assert!(pruned_flops < 100.0);
    assert!(j.get("answer").as_str().is_some());
    assert!(j.get("subtask").as_str().is_some());

    let (code, body) = request(
        &run.addr,
        "POST",
        "/v1/generate",
        br#"{"dataset": "avqa", "index": 0, "no_pruning": true}"#,
    )
    .unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let vanilla_flops = j.get("relative_flops").as_f64().unwrap();
    assert!((vanilla_flops - 100.0).abs() < 1e-6);
    assert!(pruned_flops < vanilla_flops);
}

#[test]
fn malformed_body_is_400() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, _) = request(&run.addr, "POST", "/v1/generate", b"{not json").unwrap();
    assert_eq!(code, 400);
}

#[test]
fn unknown_path_is_404() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, _) = request(&run.addr, "GET", "/nope", b"").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn request_id_echoed_and_pool_status_served() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let r = request_with_headers(
        &run.addr,
        "POST",
        "/v1/generate",
        &[("x-request-id", "trace-123")],
        br#"{"dataset": "avqa", "index": 1}"#,
    )
    .unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    assert_eq!(r.header("x-request-id"), Some("trace-123"));
    let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
    assert!(j.get("request_id").as_usize().is_some());

    // Pool status reflects the completed request.
    let (code, body) = request(&run.addr, "GET", "/v1/pool", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("replicas").as_arr().unwrap().len(), 1);
    assert!(j.get("stats").get("completed").as_f64().unwrap() >= 1.0);
}

#[test]
fn question_override_and_cache_flush_roundtrip() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    // Two different questions about the same sample: identical AV
    // prefix, different text suffix. The second should be able to reuse
    // the cached prefix (prefix_hit is engine-dependent here; the JSON
    // contract is what this test pins).
    for q in ["what_scene", "what_sound"] {
        let body = format!(r#"{{"dataset": "avqa", "index": 2, "question": "{}"}}"#, q);
        let (code, resp) =
            request(&run.addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
        assert_eq!(code, 200, "{}", String::from_utf8_lossy(&resp));
        let j = Json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
        assert!(j.get("prefix_hit").as_bool().is_some());
        assert!(j.get("prefix_tokens_reused").as_usize().is_some());
    }
    let (code, _) = request(
        &run.addr,
        "POST",
        "/v1/generate",
        br#"{"dataset": "avqa", "index": 2, "question": "nope"}"#,
    )
    .unwrap();
    assert_eq!(code, 400, "unknown question override must 400");

    // Pool status exposes cache + block accounting; flush succeeds.
    let (code, body) = request(&run.addr, "GET", "/v1/pool", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("prefix_cache").get("misses").as_f64().is_some());
    assert!(j.get("kv_blocks").get("used").as_f64().is_some());
    let (code, body) = request(&run.addr, "POST", "/v1/cache/flush", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("flushed_entries").as_usize().is_some());
    assert!(j.get("freed_bytes").as_usize().is_some());
}

#[test]
fn cancel_unknown_request_is_404() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let (code, body) =
        request(&run.addr, "POST", "/v1/cancel", br#"{"request_id": 999999}"#).unwrap();
    assert_eq!(code, 404);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("canceled").as_bool(), Some(false));
}

#[test]
fn rejected_requests_carry_retry_after() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let barrier = Arc::new(std::sync::Barrier::new(16));
    let handles: Vec<_> = (0..16)
        .map(|i| {
            let addr = run.addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let body = format!(r#"{{"dataset": "avqa", "index": {}}}"#, i);
                barrier.wait();
                request_with_headers(&addr, "POST", "/v1/generate", &[], body.as_bytes())
                    .unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        match r.status {
            200 => {}
            // Backpressure must be retryable: 429 + Retry-After.
            429 => assert_eq!(r.header("retry-after"), Some("1")),
            other => panic!("unexpected status {}", other),
        }
    }
}

#[test]
fn concurrent_clients_all_served() {
    let Some(root) = common::tiny_ready() else { return };
    let run = spin_up(root);
    let addr = run.addr.clone();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = format!(r#"{{"dataset": "avhbench", "index": {}}}"#, i);
                request(&addr, "POST", "/v1/generate", body.as_bytes()).unwrap()
            })
        })
        .collect();
    for h in handles {
        let (code, body) = h.join().unwrap();
        assert!(
            code == 200 || code == 429,
            "unexpected status {}: {}",
            code,
            String::from_utf8_lossy(&body)
        );
    }
    // The engine saw at least one request end-to-end.
    assert!(run.coord.metrics.counter("fastav_requests_completed_total").get() >= 1);
}
