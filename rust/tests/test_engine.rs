//! Engine integration tests over the real AOT artifacts (tiny model).
//!
//! These exercise the full rust↔XLA path: artifact loading, the staged
//! prefill/decode pipeline, pruning plans, FLOPs accounting, and the
//! calibration probe. Tests skip when artifacts are absent.

mod common;

use fastav::avsynth::{gen_sample, Dataset};
use fastav::calibration::calibrate;
use fastav::model::{GenerateOptions, ModelEngine, PruningPlan, RequestInput};
use fastav::pruning::{FineStrategy, GlobalStrategy};
use fastav::tokens::EOS;

fn engine() -> Option<ModelEngine> {
    let root = common::tiny_ready()?;
    Some(ModelEngine::load(&root, "tiny").expect("engine load"))
}

fn sample(idx: u64) -> fastav::avsynth::Sample {
    let layout = fastav::tokens::Layout {
        frames: 2,
        vis_per_frame: 4,
        aud_len: 6,
        aud_per_frame: 3,
        interleaved: false,
    };
    gen_sample(&layout, Dataset::Avqa, idx, 1234)
}

#[test]
fn vanilla_generation_is_deterministic() {
    let Some(mut eng) = engine() else { return };
    let s = sample(0);
    let opts = GenerateOptions::default();
    let a = eng.generate(&RequestInput::from_sample(&s), &opts).unwrap();
    let b = eng.generate(&RequestInput::from_sample(&s), &opts).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.flops.total, b.flops.total);
    assert!(!a.tokens.is_empty() && a.tokens.len() <= opts.max_gen);
}

#[test]
fn vanilla_relative_flops_is_100() {
    let Some(mut eng) = engine() else { return };
    let s = sample(1);
    let res = eng
        .generate(&RequestInput::from_sample(&s), &GenerateOptions::default())
        .unwrap();
    assert!(
        (res.relative_flops - 100.0).abs() < 1e-6,
        "vanilla must be exactly 100, got {}",
        res.relative_flops
    );
    // Live counts: every layer sees the full prompt.
    assert!(res.live_counts.iter().all(|&n| n == s.prompt.len()));
}

#[test]
fn fastav_reduces_flops_and_live_counts() {
    let Some(mut eng) = engine() else { return };
    let s = sample(2);
    let plan = PruningPlan::fastav(5, 2, 0, 20.0);
    let opts = GenerateOptions { plan, max_gen: 4, ..Default::default() };
    let res = eng.generate(&RequestInput::from_sample(&s), &opts).unwrap();
    assert!(res.relative_flops < 95.0, "got {}", res.relative_flops);
    // Monotone non-increasing live counts after the global layer.
    let mid = eng.cfg.mid_layer;
    for w in res.live_counts[mid..].windows(2) {
        assert!(w[1] <= w[0], "live counts must shrink: {:?}", res.live_counts);
    }
    assert!(res.live_counts[mid] < s.prompt.len());
}

#[test]
fn pruned_output_stays_plausible() {
    // Pruning must not break decoding: tokens come from the vocab and the
    // sequence terminates (EOS or max_gen).
    let Some(mut eng) = engine() else { return };
    let s = sample(3);
    let plan = PruningPlan::fastav(6, 2, 0, 30.0);
    let res = eng
        .generate(&RequestInput::from_sample(&s), &GenerateOptions { plan, max_gen: 4, ..Default::default() })
        .unwrap();
    assert!(res.tokens.iter().all(|&t| (t as usize) < eng.cfg.vocab));
    assert!(res.tokens.contains(&EOS) || res.tokens.len() == 4);
}

#[test]
fn vtw_drops_all_av_tokens() {
    let Some(mut eng) = engine() else { return };
    let s = sample(4);
    let plan = PruningPlan {
        global: GlobalStrategy::Vtw,
        global_budget: 0,
        fine: FineStrategy::None,
        fine_percent: 0.0,
        seed: 0,
        global_layer: None,
        ..PruningPlan::vanilla()
    };
    let res = eng
        .generate(&RequestInput::from_sample(&s), &GenerateOptions { plan, max_gen: 2, ..Default::default() })
        .unwrap();
    let av = s
        .segments
        .iter()
        .filter(|g| matches!(g, fastav::tokens::Segment::Vis | fastav::tokens::Segment::Aud))
        .count();
    let mid = eng.cfg.mid_layer;
    assert_eq!(res.live_counts[mid], s.prompt.len() - av);
    assert!(res.relative_flops < 90.0);
}

#[test]
fn random_strategy_respects_budget() {
    let Some(mut eng) = engine() else { return };
    let s = sample(5);
    let plan = PruningPlan {
        global: GlobalStrategy::Random,
        global_budget: 4,
        fine: FineStrategy::None,
        fine_percent: 0.0,
        seed: 99,
        global_layer: None,
        ..PruningPlan::vanilla()
    };
    let res = eng
        .generate(&RequestInput::from_sample(&s), &GenerateOptions { plan, max_gen: 2, ..Default::default() })
        .unwrap();
    let non_av = s
        .segments
        .iter()
        .filter(|g| matches!(g, fastav::tokens::Segment::Ctrl | fastav::tokens::Segment::Text))
        .count();
    assert_eq!(res.live_counts[eng.cfg.mid_layer], non_av + 4);
}

#[test]
fn attentive_strategies_run_score_capture() {
    let Some(mut eng) = engine() else { return };
    let s = sample(6);
    for strat in [GlobalStrategy::LowAttentive, GlobalStrategy::TopAttentive] {
        let plan = PruningPlan {
            global: strat,
            global_budget: 5,
            fine: FineStrategy::None,
            fine_percent: 0.0,
            seed: 0,
            global_layer: None,
            ..PruningPlan::vanilla()
        };
        let res = eng
            .generate(&RequestInput::from_sample(&s), &GenerateOptions { plan, max_gen: 2, ..Default::default() })
            .unwrap();
        // Score capture runs layer mid unpruned: its live count is full.
        assert_eq!(res.live_counts[eng.cfg.mid_layer], s.prompt.len());
        // The following layer sees the pruned set.
        let non_av = s.prompt.len()
            - s.segments
                .iter()
                .filter(|g| {
                    matches!(g, fastav::tokens::Segment::Vis | fastav::tokens::Segment::Aud)
                })
                .count();
        assert_eq!(res.live_counts[eng.cfg.mid_layer + 1], non_av + 5);
    }
}

#[test]
fn informative_strategies_use_rollout() {
    let Some(mut eng) = engine() else { return };
    let s = sample(7);
    for strat in [GlobalStrategy::LowInformative, GlobalStrategy::TopInformative] {
        let plan = PruningPlan {
            global: strat,
            global_budget: 5,
            fine: FineStrategy::None,
            fine_percent: 0.0,
            seed: 0,
            global_layer: None,
            ..PruningPlan::vanilla()
        };
        let res = eng
            .generate(&RequestInput::from_sample(&s), &GenerateOptions { plan, max_gen: 2, ..Default::default() })
            .unwrap();
        let non_av = s
            .segments
            .iter()
            .filter(|g| matches!(g, fastav::tokens::Segment::Ctrl | fastav::tokens::Segment::Text))
            .count();
        assert_eq!(res.live_counts[eng.cfg.mid_layer], non_av + 5);
    }
}

#[test]
fn fine_pruning_drops_expected_counts() {
    let Some(mut eng) = engine() else { return };
    let s = sample(8);
    let plan = PruningPlan {
        global: GlobalStrategy::None,
        global_budget: 0,
        fine: FineStrategy::LowAttentive,
        fine_percent: 25.0,
        seed: 0,
        global_layer: None,
        ..PruningPlan::vanilla()
    };
    let res = eng
        .generate(&RequestInput::from_sample(&s), &GenerateOptions { plan, max_gen: 2, ..Default::default() })
        .unwrap();
    let mid = eng.cfg.mid_layer;
    // Each back layer drops round(25% of prunable AV rows) of the previous.
    let av0 = s
        .segments
        .iter()
        .filter(|g| matches!(g, fastav::tokens::Segment::Vis | fastav::tokens::Segment::Aud))
        .count();
    let keep0 = s.prompt.len();
    let expect1 = keep0 - ((av0 as f64) * 0.25).round() as usize;
    assert_eq!(res.live_counts[mid], keep0);
    assert_eq!(res.live_counts[mid + 1], expect1);
}

#[test]
fn frontsplit_layer_sweep_runs() {
    let Some(mut eng) = engine() else { return };
    let s = sample(9);
    // tiny has splits at 1 and 3 (mid=2 is prefill_front).
    for g in [1usize, 2, 3] {
        let plan = PruningPlan {
            global: GlobalStrategy::FastAvPosition {
                vis_cutoff: 5,
                keep_audio: 2,
                keep_frames: 0,
            },
            global_budget: 0,
            fine: FineStrategy::LowAttentive,
            fine_percent: 20.0,
            seed: 0,
            global_layer: Some(g),
            ..PruningPlan::vanilla()
        };
        let res = eng
            .generate(&RequestInput::from_sample(&s), &GenerateOptions { plan, max_gen: 2, ..Default::default() })
            .unwrap();
        assert!(res.relative_flops < 100.0, "g={} got {}", g, res.relative_flops);
        assert_eq!(res.live_counts[..g], vec![s.prompt.len(); g][..]);
    }
}

#[test]
fn calib_probe_rollout_is_stochastic() {
    let Some(mut eng) = engine() else { return };
    let s = sample(10);
    let probe = eng.calib_probe(&s.prompt).unwrap();
    let k = s.prompt.len();
    for layer in 1..=probe.n_layers {
        for row in 0..k {
            let sum: f32 = (0..k).map(|c| probe.rollout_at(layer, row, c)).sum();
            assert!((sum - 1.0).abs() < 1e-2, "layer {} row {} sum {}", layer, row, sum);
        }
    }
    // Influence on the last query is a distribution too.
    let lr = probe.last_row(eng.cfg.mid_layer);
    let total: f32 = lr.iter().sum();
    assert!((total - 1.0).abs() < 1e-2);
}

#[test]
fn calibration_pipeline_produces_sane_rule() {
    let Some(mut eng) = engine() else { return };
    let calib = calibrate(&mut eng, 8, 1234).unwrap();
    assert!(calib.vis_cutoff >= 1);
    assert!(calib.keep_audio >= 1);
    assert!(calib.budget >= 2);
    let layout = &eng.cfg.layout;
    assert!(calib.budget <= layout.vis_tokens() + layout.audio_tokens());
    // The derived plan must execute.
    let s = sample(11);
    let res = eng
        .generate(
            &RequestInput::from_sample(&s),
            &GenerateOptions { plan: calib.plan(20.0), max_gen: 3, ..Default::default() },
        )
        .unwrap();
    assert!(res.relative_flops < 100.0);
}

#[test]
fn kv_memory_shrinks_under_pruning() {
    let Some(mut eng) = engine() else { return };
    let s = sample(12);
    let vanilla = eng
        .generate(&RequestInput::from_sample(&s), &GenerateOptions::default())
        .unwrap();
    let pruned = eng
        .generate(
            &RequestInput::from_sample(&s),
            &GenerateOptions { plan: PruningPlan::fastav(4, 1, 0, 20.0), max_gen: 4, ..Default::default() },
        )
        .unwrap();
    assert!(
        pruned.peak_kv_bytes <= vanilla.peak_kv_bytes,
        "pruned {} vs vanilla {}",
        pruned.peak_kv_bytes,
        vanilla.peak_kv_bytes
    );
}

#[test]
fn oversized_prompt_is_rejected() {
    let Some(mut eng) = engine() else { return };
    let prompt = vec![1u32; 100]; // tiny prefill bucket is 32
    let segments = vec![fastav::tokens::Segment::Text; 100];
    let frames = vec![-1i32; 100];
    let input = RequestInput { prompt: &prompt, segments: &segments, frame_of: &frames };
    assert!(eng.generate(&input, &GenerateOptions::default()).is_err());
}

#[test]
fn sampling_greedy_matches_default_and_seeded_sampling_is_deterministic() {
    let Some(mut eng) = engine() else { return };
    let s = sample(14);
    let greedy = eng
        .generate(&RequestInput::from_sample(&s), &GenerateOptions::default())
        .unwrap();
    let temp0 = eng
        .generate(
            &RequestInput::from_sample(&s),
            &GenerateOptions {
                sampling: fastav::model::engine::Sampling { temperature: 0.0, top_k: 5, seed: 1 },
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(greedy.tokens, temp0.tokens, "temperature 0 is greedy");
    let sampled = GenerateOptions {
        sampling: fastav::model::engine::Sampling { temperature: 0.8, top_k: 0, seed: 7 },
        ..Default::default()
    };
    let a = eng.generate(&RequestInput::from_sample(&s), &sampled).unwrap();
    let b = eng.generate(&RequestInput::from_sample(&s), &sampled).unwrap();
    assert_eq!(a.tokens, b.tokens, "fixed seed must be deterministic");
}

#[test]
fn decode_time_pruning_shrinks_caches() {
    let Some(mut eng) = engine() else { return };
    let s = sample(15);
    let mut plan = PruningPlan::fastav(6, 2, 0, 30.0);
    plan.fine_during_decode = true;
    let pruned = eng
        .generate(
            &RequestInput::from_sample(&s),
            &GenerateOptions { plan: plan.clone(), max_gen: 4, ..Default::default() },
        )
        .unwrap();
    plan.fine_during_decode = false;
    let baseline = eng
        .generate(
            &RequestInput::from_sample(&s),
            &GenerateOptions { plan, max_gen: 4, ..Default::default() },
        )
        .unwrap();
    // Decode-time pruning can only reduce decode FLOPs (cache keys shrink).
    if pruned.decode_steps > 0 && baseline.decode_steps > 0 {
        assert!(pruned.flops.decode <= baseline.flops.decode);
    }
    assert!(pruned.tokens.iter().all(|&t| (t as usize) < eng.cfg.vocab));
}

#[test]
fn streaming_callback_sees_all_tokens() {
    let Some(mut eng) = engine() else { return };
    let s = sample(13);
    let mut streamed = Vec::new();
    let res = eng
        .generate_with(
            &RequestInput::from_sample(&s),
            &GenerateOptions::default(),
            |t| streamed.push(t),
        )
        .unwrap();
    assert_eq!(streamed, res.tokens);
}
