//! Batched-decode equivalence and coverage properties, run against a
//! mock engine so no AOT artifacts are needed:
//!
//! * **Equivalence:** driving a pool whose engine fuses decode batches
//!   (`step_batch` over every decode-ready generation) produces
//!   token-for-token identical per-request streams — and an identical
//!   conservation ledger — as the same workload on the single-step path
//!   (`max_decode_batch: 1`). Each mock token is a deterministic
//!   function of (request seed, step index), so any cross-request mixing
//!   or lost/duplicated step would change a stream.
//! * **Engagement:** with ≥ 2 decode-ready requests in flight, the
//!   batched path is what actually runs (fused quanta observed, batch
//!   occupancy > 1).
//! * **Ragged tail:** more decode-ready requests than the engine's batch
//!   limit fall back to bounded batches + leftovers; nothing exceeds the
//!   limit and everything still completes with the right stream.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::metrics::Registry;
use fastav::model::{GenerateResult, StepEvent};
use fastav::policy::PruningSpec;
use fastav::serving::{PoolConfig, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::util::proptest::{run_prop, Gen};

// ---------------------------------------------------------------- mock

/// Deterministic token stream: mixing up either the request identity or
/// the per-request step counter changes the token.
fn mock_token(seed: u64, step: usize) -> u32 {
    let x = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(step as u64)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    (x >> 33) as u32 % 1000
}

struct BatchGen {
    seed: u64,
    prefill_left: usize,
    produced: usize,
    total: usize,
}

/// Counters shared with the test body so engagement is observable.
#[derive(Default)]
struct BatchStats {
    fused_quanta: AtomicU64,
    fused_tokens: AtomicU64,
    max_batch_seen: AtomicUsize,
}

/// Engine with a real fused path: `step_batch` advances every handed
/// generation with the same per-generation transition as `step`.
struct BatchMock {
    max_batch: usize,
    step_cost: Duration,
    stats: Arc<BatchStats>,
}

impl BatchMock {
    fn advance(&self, gen: &mut BatchGen) -> StepEvent {
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return StepEvent::Prefilled { layer: 0 };
            }
            // Prefill completion emits the first token, like the engine.
        } else if gen.produced >= gen.total {
            return StepEvent::Done;
        }
        let tok = mock_token(gen.seed, gen.produced);
        gen.produced += 1;
        StepEvent::Token(tok)
    }
}

impl ReplicaEngine for BatchMock {
    type Gen = BatchGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<BatchGen> {
        Ok(BatchGen {
            seed: req.prompt.iter().fold(0u64, |a, &t| a * 31 + t as u64),
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
        })
    }

    fn step(&mut self, gen: &mut BatchGen) -> anyhow::Result<StepEvent> {
        if !self.step_cost.is_zero() {
            std::thread::sleep(self.step_cost);
        }
        Ok(self.advance(gen))
    }

    fn is_decoding(&self, gen: &BatchGen) -> bool {
        gen.prefill_left == 0 && gen.produced > 0 && gen.produced < gen.total
    }

    fn max_decode_batch(&self) -> usize {
        self.max_batch
    }

    fn step_batch(&mut self, gens: &mut [&mut BatchGen]) -> anyhow::Result<Vec<StepEvent>> {
        assert!(
            gens.len() <= self.max_batch,
            "replica handed a batch of {} over the engine limit {}",
            gens.len(),
            self.max_batch
        );
        for g in gens.iter() {
            assert!(
                g.prefill_left == 0 && g.produced < g.total,
                "non-decode-ready generation in a fused batch"
            );
        }
        // One fused dispatch costs one step, however many rows it has.
        if !self.step_cost.is_zero() {
            std::thread::sleep(self.step_cost);
        }
        if gens.len() >= 2 {
            self.stats.fused_quanta.fetch_add(1, Ordering::Relaxed);
            self.stats.fused_tokens.fetch_add(gens.len() as u64, Ordering::Relaxed);
            self.stats.max_batch_seen.fetch_max(gens.len(), Ordering::Relaxed);
        }
        Ok(gens.iter_mut().map(|g| self.advance(g)).collect())
    }

    fn is_done(&self, gen: &BatchGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: BatchGen) -> GenerateResult {
        GenerateResult {
            tokens: (0..gen.produced).map(|s| mock_token(gen.seed, s)).collect(),
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: 1000,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, _gen: &BatchGen) -> usize {
        1000
    }

    fn estimate_bytes(&self, _req: &GenRequest) -> usize {
        1000
    }
}

fn batch_request(seed_tok: u32, max_gen: usize) -> GenRequest {
    GenRequest {
        prompt: vec![seed_tok, 2, 3, 4],
        segments: vec![Segment::Ctrl, Segment::Vis, Segment::Aud, Segment::Text],
        frame_of: vec![-1, 0, -1, -1],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

struct Run {
    pool: ReplicaPool,
    stats: Arc<BatchStats>,
}

fn batch_pool(cfg: PoolConfig, max_batch: usize, step_cost: Duration) -> Run {
    let stats = Arc::new(BatchStats::default());
    let s2 = Arc::clone(&stats);
    let pool = ReplicaPool::start_with_factory(cfg, Arc::new(Registry::default()), move |_r| {
        Ok(BatchMock { max_batch, step_cost, stats: Arc::clone(&s2) })
    })
    .expect("mock pool starts");
    Run { pool, stats }
}

/// Collect every request's full token stream (panics on stream errors).
fn streams(receivers: Vec<std::sync::mpsc::Receiver<Event>>) -> Vec<Vec<u32>> {
    receivers
        .into_iter()
        .map(|rx| {
            let mut toks = Vec::new();
            loop {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(Event::Token(t)) => toks.push(t),
                    Ok(Event::Done(res)) => {
                        assert_eq!(res.tokens, toks, "Done result diverges from stream");
                        return toks;
                    }
                    Ok(Event::Error(e)) => panic!("request failed: {}", e),
                    Err(e) => panic!("stream stalled: {}", e),
                }
            }
        })
        .collect()
}

fn settled(pool: &ReplicaPool) -> fastav::serving::PoolStats {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if (s.conserved() && s.in_flight == 0 && s.in_queue == 0)
            || t0.elapsed() > Duration::from_secs(10)
        {
            return s;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Drive one workload, returning (per-request streams, ledger).
fn drive(
    max_decode_batch: usize,
    engine_max: usize,
    reqs: &[(u32, usize)],
    max_inflight: usize,
) -> (Vec<Vec<u32>>, fastav::serving::PoolStats, Arc<BatchStats>) {
    let run = batch_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 64,
            max_inflight,
            max_decode_batch,
            ..Default::default()
        },
        engine_max,
        Duration::from_micros(100),
    );
    let receivers: Vec<_> = reqs
        .iter()
        .map(|&(seed, max_gen)| run.pool.submit(batch_request(seed, max_gen)).unwrap().1)
        .collect();
    let streams = streams(receivers);
    let stats = settled(&run.pool);
    (streams, stats, run.stats)
}

// --------------------------------------------------------------- tests

#[test]
fn prop_batched_equals_sequential() {
    run_prop("batched_decode_equivalence", 10, |g: &mut Gen| {
        let n = g.usize_in(2, 12);
        let max_inflight = g.usize_in(2, 6);
        let engine_max = g.usize_in(2, 8);
        let reqs: Vec<(u32, usize)> = (0..n)
            .map(|i| (100 + i as u32 * 7, g.usize_in(1, 12)))
            .collect();

        let (batched, bstats, bshared) = drive(0, engine_max, &reqs, max_inflight);
        let (sequential, sstats, _) = drive(1, engine_max, &reqs, max_inflight);

        assert_eq!(
            batched, sequential,
            "batched and sequential token streams must be identical"
        );
        // Identical conservation ledgers, not just both conserved.
        assert!(bstats.conserved(), "batched ledger: {:?}", bstats);
        assert!(sstats.conserved(), "sequential ledger: {:?}", sstats);
        assert_eq!(bstats.submitted, sstats.submitted);
        assert_eq!(bstats.completed, sstats.completed);
        assert_eq!(bstats.failed, sstats.failed);
        assert_eq!(bstats.completed, n as u64);
        // The engine limit was always respected.
        assert!(bshared.max_batch_seen.load(Ordering::Relaxed) <= engine_max);
    });
}

#[test]
fn batched_path_is_default_with_two_plus_decoding() {
    // 6 long generations interleaved on one replica: once ≥ 2 are
    // decode-ready, quanta must fuse.
    let reqs: Vec<(u32, usize)> = (0..6).map(|i| (500 + i, 32)).collect();
    let (streams, stats, shared) = drive(0, 8, &reqs, 6);
    assert_eq!(stats.completed, 6);
    for (i, s) in streams.iter().enumerate() {
        assert_eq!(s.len(), 32, "request {} stream truncated", i);
    }
    let quanta = shared.fused_quanta.load(Ordering::Relaxed);
    let tokens = shared.fused_tokens.load(Ordering::Relaxed);
    assert!(quanta > 0, "no fused decode quanta despite 6 concurrent decoders");
    let occupancy = tokens as f64 / quanta as f64;
    assert!(
        occupancy > 1.5,
        "mean fused occupancy {:.2} too low for 6 concurrent decoders",
        occupancy
    );
}

#[test]
fn ragged_tail_falls_back_to_bounded_batches() {
    // 5 decoders, engine limit 4: the scheduler may fuse at most 4; the
    // leftover advances as a single step or a later batch — streams and
    // ledger must be unaffected.
    let reqs: Vec<(u32, usize)> = (0..5).map(|i| (900 + i, 16)).collect();
    let (streams, stats, shared) = drive(0, 4, &reqs, 5);
    assert_eq!(stats.completed, 5);
    assert!(shared.max_batch_seen.load(Ordering::Relaxed) <= 4);
    let (sequential, _, _) = drive(1, 4, &reqs, 5);
    assert_eq!(streams, sequential);
}

#[test]
fn single_decoder_never_fuses() {
    let reqs = vec![(42u32, 16usize)];
    let (streams, stats, shared) = drive(0, 8, &reqs, 4);
    assert_eq!(stats.completed, 1);
    assert_eq!(streams[0].len(), 16);
    assert_eq!(shared.fused_quanta.load(Ordering::Relaxed), 0);
}
