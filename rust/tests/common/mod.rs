//! Shared helpers for integration tests.
//!
//! Engine tests need AOT artifacts (`make artifacts` builds them). When the
//! `tiny` model is absent the tests SKIP (print + return) instead of
//! failing, so `cargo test` stays green on a fresh checkout; CI and the
//! Makefile run them after artifact builds.

use std::path::PathBuf;

#[allow(dead_code)]
pub fn artifact_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Returns the artifact root if the `tiny` model is fully built.
#[allow(dead_code)]
pub fn tiny_ready() -> Option<PathBuf> {
    let root = artifact_root();
    let dir = root.join("tiny");
    for f in ["model.json", "weights.bin", "prefill_front_32.hlo.txt", "logits.hlo.txt"] {
        if !dir.join(f).exists() {
            eprintln!("SKIP: artifacts/tiny/{} missing (run `make artifacts`)", f);
            return None;
        }
    }
    Some(root)
}

#[macro_export]
macro_rules! require_tiny {
    () => {
        match common::tiny_ready() {
            Some(root) => root,
            None => return,
        }
    };
}
