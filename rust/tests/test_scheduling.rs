//! Serving-pool scheduling properties, run against a mock engine so no
//! AOT artifacts are needed: fairness under a sustained High-priority
//! stream (no starvation once the step scheduler interleaves) and the
//! pool conservation ledger
//! (`submitted == rejected + terminal + in_queue + in_flight`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::metrics::Registry;
use fastav::model::{GenerateResult, StepEvent};
use fastav::policy::PruningSpec;
use fastav::serving::{PoolConfig, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::util::proptest::{run_prop, Gen};

// ---------------------------------------------------------------- mock

/// A generation that takes `prefill_left + total` quanta to finish.
struct MockGen {
    prefill_left: usize,
    produced: usize,
    total: usize,
    kv_bytes: usize,
}

/// Engine stand-in: every quantum burns `step_cost` of wall clock, so
/// scheduling contention is observable.
struct MockEngine {
    step_cost: Duration,
}

impl ReplicaEngine for MockEngine {
    type Gen = MockGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<MockGen> {
        Ok(MockGen {
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
            kv_bytes: req.prompt.len() * 1000,
        })
    }

    fn step(&mut self, gen: &mut MockGen) -> anyhow::Result<StepEvent> {
        if !self.step_cost.is_zero() {
            std::thread::sleep(self.step_cost);
        }
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return Ok(StepEvent::Prefilled { layer: 2 - gen.prefill_left });
            }
        }
        if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        gen.produced += 1;
        Ok(StepEvent::Token(7))
    }

    fn is_done(&self, gen: &MockGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: MockGen) -> GenerateResult {
        GenerateResult {
            tokens: vec![7; gen.produced],
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: gen.kv_bytes,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, gen: &MockGen) -> usize {
        gen.kv_bytes
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        req.prompt.len() * 1000
    }
}

fn mock_request(max_gen: usize, priority: Priority) -> GenRequest {
    GenRequest {
        prompt: vec![1, 2, 3, 4],
        segments: vec![Segment::Ctrl, Segment::Vis, Segment::Aud, Segment::Text],
        frame_of: vec![-1, 0, -1, -1],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority,
        deadline: None,
        profile: None,
    }
}

fn mock_pool(cfg: PoolConfig, step_cost: Duration) -> ReplicaPool {
    ReplicaPool::start_with_factory(cfg, Arc::new(Registry::default()), move |_replica| {
        Ok(MockEngine { step_cost })
    })
    .expect("mock pool starts")
}

/// Wait (bounded) for the pool to reach a quiescent, conserved state.
fn settled_stats(pool: &ReplicaPool) -> fastav::serving::PoolStats {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if (s.conserved() && s.in_flight == 0 && s.in_queue == 0)
            || t0.elapsed() > Duration::from_secs(10)
        {
            return s;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn drain(rx: std::sync::mpsc::Receiver<Event>) -> Result<usize, String> {
    let mut tokens = 0;
    loop {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Event::Token(_)) => tokens += 1,
            Ok(Event::Done(_)) => return Ok(tokens),
            Ok(Event::Error(e)) => return Err(e),
            Err(e) => panic!("stream stalled: {}", e),
        }
    }
}

// --------------------------------------------------------------- tests

#[test]
fn normal_requests_complete_under_sustained_high_stream() {
    let pool = Arc::new(mock_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 8,
            max_inflight: 2,
            ..Default::default()
        },
        Duration::from_micros(200),
    ));

    // Producer: a saturating stream of High-priority long generations.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let producer = {
        let pool = Arc::clone(&pool);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut receivers = Vec::new();
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                if let Ok((_, rx)) = pool.submit(mock_request(16, Priority::High)) {
                    receivers.push(rx);
                }
                std::thread::sleep(Duration::from_micros(300));
            }
            receivers
        })
    };

    // Under that stream, short Normal requests must still finish.
    let mut normal_done = 0;
    for _ in 0..10 {
        // Retry through transient queue-full backpressure.
        let rx = loop {
            match pool.submit(mock_request(3, Priority::Normal)) {
                Ok((_, rx)) => break rx,
                Err(_) => std::thread::sleep(Duration::from_millis(1)),
            }
        };
        match drain(rx) {
            Ok(_) => normal_done += 1,
            Err(e) => panic!("normal request failed: {}", e),
        }
    }
    assert_eq!(normal_done, 10, "normal requests starved by High stream");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let high_receivers = producer.join().unwrap();
    for rx in high_receivers {
        drain(rx).expect("high request failed");
    }
    let stats = settled_stats(&pool);
    assert!(stats.conserved(), "ledger out of balance: {:?}", stats);
}

#[test]
fn prop_conservation_under_mixed_load() {
    run_prop("pool_conservation", 12, |g: &mut Gen| {
        let replicas = g.usize_in(1, 3);
        let queue_cap = g.usize_in(1, 4);
        let max_inflight = g.usize_in(1, 3);
        let n = g.usize_in(5, 40);
        let pool = mock_pool(
            PoolConfig {
                replicas,
                queue_cap,
                max_inflight,
                ..Default::default()
            },
            Duration::from_micros(50),
        );
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        for i in 0..n {
            let prio = if i % 3 == 0 { Priority::High } else { Priority::Normal };
            match pool.submit(mock_request(g.usize_in(1, 6), prio)) {
                Ok((id, rx)) => {
                    // Cancel a random slice of live requests.
                    if g.bool() && g.bool() {
                        pool.cancel(id);
                    }
                    accepted.push(rx);
                }
                Err(_) => rejected += 1,
            }
        }
        let mut terminal_seen = 0u64;
        for rx in accepted {
            let _ = drain(rx); // Done and Error both count as terminal
            terminal_seen += 1;
        }
        let stats = settled_stats(&pool);
        assert!(stats.conserved(), "not conserved: {:?}", stats);
        assert_eq!(stats.submitted, n as u64);
        assert_eq!(stats.rejected, rejected);
        assert_eq!(stats.terminal(), terminal_seen);
        // Queue-level conservation across the pool, too.
        let qs = pool.sched_stats();
        assert_eq!(qs.admitted, qs.dequeued, "queue drained at quiescence");
    });
}

#[test]
fn kv_budget_serializes_admissions_and_rejects_oversize() {
    // Budget fits exactly one 4000-byte request at a time.
    let pool = mock_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 8,
            max_inflight: 4,
            kv_budget_bytes: 5000,
            ..Default::default()
        },
        Duration::from_micros(100),
    );
    let rxs: Vec<_> = (0..4)
        .map(|_| pool.submit(mock_request(4, Priority::Normal)).unwrap().1)
        .collect();
    for rx in rxs {
        drain(rx).expect("budget-admitted request must complete");
    }

    // A request whose estimate exceeds the whole budget fails fast.
    let mut big = mock_request(2, Priority::Normal);
    big.prompt = vec![1; 10]; // 10_000 estimated bytes > 5000 budget
    big.segments = vec![Segment::Text; 10];
    big.frame_of = vec![-1; 10];
    let (_, rx) = pool.submit(big).unwrap();
    let err = drain(rx).expect_err("oversize request must be rejected");
    assert!(err.contains("budget"), "unexpected error: {}", err);
    let stats = settled_stats(&pool);
    assert!(stats.conserved(), "{:?}", stats);
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.failed, 1);
}

#[test]
fn deadlines_expire_queued_requests() {
    let pool = mock_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 8,
            max_inflight: 1,
            ..Default::default()
        },
        Duration::from_micros(500),
    );
    // Occupy the only slot with a long generation...
    let (_, busy) = pool.submit(mock_request(64, Priority::Normal)).unwrap();
    // ...then queue a request that can only expire.
    let mut doomed = mock_request(4, Priority::Normal);
    doomed.deadline = Some(Duration::from_millis(1));
    let (_, rx) = pool.submit(doomed).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let err = drain(rx).expect_err("deadline must expire the request");
    assert!(err.contains("deadline"), "unexpected error: {}", err);
    drain(busy).expect("long request still completes");
    let stats = settled_stats(&pool);
    assert_eq!(stats.expired, 1);
    assert!(stats.conserved(), "{:?}", stats);
}

#[test]
fn pool_shutdown_drains_in_flight_work() {
    let pool = mock_pool(
        PoolConfig {
            replicas: 2,
            queue_cap: 16,
            max_inflight: 2,
            ..Default::default()
        },
        Duration::from_micros(100),
    );
    let rxs: Vec<_> = (0..6)
        .map(|_| pool.submit(mock_request(8, Priority::Normal)).unwrap().1)
        .collect();
    pool.shutdown(); // close + drain + join
    for rx in rxs {
        let done = rx.iter().any(|ev| matches!(ev, Event::Done(_)));
        assert!(done, "in-flight request dropped at shutdown");
    }
}
