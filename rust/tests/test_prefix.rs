//! Paged-KV + prefix-reuse properties, artifact-free:
//!
//! * BlockPool conservation — every slot is free or referenced, and each
//!   block's refcount equals the number of caches holding it, across
//!   random clone/append/compact/drop interleavings;
//! * copy-on-write — mutating one cache never perturbs another cache (or
//!   a frozen prefix entry) sharing its blocks, verified against shadow
//!   models (`BlockPool::write_row` additionally panics on any write to
//!   a shared block);
//! * no use-after-free — entries evicted/flushed while borrowed stay
//!   readable until the last borrower drops;
//! * serving acceptance (mock engine through the real `ReplicaPool`) —
//!   a warm prefix hit skips ≥ 90% of front-layer prefill steps for the
//!   shared AV prefix, admission counts shared prefix bytes once so K
//!   concurrent same-prefix requests fit sub-linearly, and dispatch is
//!   prefix-affine.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::kvcache::{
    BlockPool, LayerCache, PrefixCache, PrefixEntry, PrefixLease, BLOCK_TOKENS,
};
use fastav::metrics::Registry;
use fastav::model::{av_prefix_len, GenerateResult, StepEvent};
use fastav::policy::PruningSpec;
use fastav::serving::{PoolConfig, PrefixCharge, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::util::proptest::{run_prop, Gen};

// ------------------------------------------------- block pool properties

/// Shadow row: the full `[n_heads * d_head]` K row and its position.
type ShadowRows = Vec<(Vec<f32>, i32)>;

fn check_cache(c: &LayerCache, shadow: &ShadowRows, dh: usize) {
    assert_eq!(c.len(), shadow.len());
    for (r, (row, pos)) in shadow.iter().enumerate() {
        assert_eq!(c.positions()[r], *pos, "position drift at row {}", r);
        for h in 0..c.n_heads {
            assert_eq!(
                c.k_row(h, r),
                row[h * dh..(h + 1) * dh].to_vec(),
                "K drift at row {} head {}",
                r,
                h
            );
        }
    }
    assert!(c.padding_is_zero(), "stale data beyond len");
}

fn assert_refcount_conservation(pool: &BlockPool, caches: &[(LayerCache, ShadowRows)]) {
    // allocated == free + owned, and per-block refcount == holder count.
    let st = pool.stats();
    assert_eq!(st.used + st.free, pool.total_slots(), "slot conservation");
    let mut holders: HashMap<usize, u32> = HashMap::new();
    for (c, _) in caches {
        for &id in c.block_ids() {
            *holders.entry(id).or_insert(0) += 1;
        }
    }
    assert_eq!(holders.len(), st.used, "used blocks == distinct held blocks");
    for (&id, &n) in &holders {
        assert_eq!(pool.refs(id), n, "refcount mismatch on block {}", id);
    }
    let shared_expected = holders.values().filter(|&&n| n > 1).count();
    assert_eq!(st.shared, shared_expected);
}

#[test]
fn prop_blockpool_cow_and_refcount_conservation() {
    run_prop("blockpool_cow", 40, |g: &mut Gen| {
        let pool = BlockPool::new();
        let n_heads = g.usize_in(1, 2);
        let dh = g.usize_in(2, 4);
        let w = n_heads * dh;
        let cap = 4 * BLOCK_TOKENS;
        let mut caches: Vec<(LayerCache, ShadowRows)> =
            vec![(LayerCache::new_in(pool.clone(), n_heads, dh, cap), Vec::new())];
        let mut stamp = 0.0f32;
        for _ in 0..g.usize_in(10, 60) {
            let i = g.usize_in(0, caches.len() - 1);
            match g.usize_in(0, 4) {
                0 | 1 => {
                    // Append (two weights: appends dominate real traffic).
                    let (c, sh) = &mut caches[i];
                    if c.len() < c.cap() {
                        stamp += 1.0;
                        let k_row: Vec<f32> = (0..w).map(|e| stamp * 100.0 + e as f32).collect();
                        let v_row: Vec<f32> = k_row.iter().map(|x| -x).collect();
                        let pos = stamp as i32;
                        c.append(&k_row, &v_row, pos);
                        sh.push((k_row, pos));
                    }
                }
                2 => {
                    // Clone (share blocks).
                    if caches.len() < 6 {
                        let cl = (caches[i].0.clone(), caches[i].1.clone());
                        caches.push(cl);
                    }
                }
                3 => {
                    // Compact to a random ascending subset (fine pruning).
                    let (c, sh) = &mut caches[i];
                    if !c.is_empty() {
                        let mut keep: Vec<usize> = (0..c.len()).filter(|_| g.bool()).collect();
                        if keep.is_empty() {
                            keep.push(g.usize_in(0, c.len() - 1));
                        }
                        c.compact(&keep);
                        *sh = keep.iter().map(|&j| sh[j].clone()).collect();
                    }
                }
                _ => {
                    // Drop a cache (release its references).
                    if caches.len() > 1 {
                        caches.swap_remove(i);
                    }
                }
            }
            assert_refcount_conservation(&pool, &caches);
        }
        // Copy-on-write: every survivor still matches its shadow exactly,
        // no matter what its block-sharing siblings did.
        for (c, sh) in &caches {
            check_cache(c, sh, dh);
        }
        caches.clear();
        let st = pool.stats();
        assert_eq!(st.used, 0, "all blocks recycled after last drop");
        assert_eq!(st.free, pool.total_slots());
    });
}

#[test]
fn fine_prune_on_one_request_never_perturbs_shared_prefix() {
    let pool = BlockPool::new();
    let (h_n, dh, w) = (2usize, 4usize, 8usize);
    let mut frozen = LayerCache::new_in(pool.clone(), h_n, dh, 64);
    let p = BLOCK_TOKENS + 5; // frozen prefix spans a partial tail block
    for i in 0..p {
        let k: Vec<f32> = (0..w).map(|e| (i * 100 + e) as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        frozen.append(&k, &v, i as i32);
    }
    let snapshot: Vec<Vec<f32>> = (0..p).map(|i| frozen.k_row(1, i)).collect();

    // Two "requests" share the frozen prefix and diverge.
    let mut a = frozen.clone();
    let mut b = frozen.clone();
    for j in 0..4 {
        let row = vec![900.0 + j as f32; w];
        a.append(&row, &row, (p + j) as i32);
        b.append(&row, &row, (p + j) as i32);
    }
    // Request A fine-prunes aggressively; request B compacts differently.
    a.compact(&[0, 3, p + 2]);
    let b_keep: Vec<usize> = (0..p + 4).step_by(2).collect();
    b.compact(&b_keep);

    // The shared frozen prefix is bit-identical and fully-shared blocks
    // were never copied: only the partial tail block was forked.
    for (i, snap) in snapshot.iter().enumerate() {
        assert_eq!(&frozen.k_row(1, i), snap);
    }
    assert!(frozen.padding_is_zero());
    assert_eq!(a.positions(), &[0, 3, (p + 2) as i32]);
    assert_eq!(b.len(), b_keep.len());
}

// --------------------------------------- eviction / use-after-free safety

fn tiny_entry(pool: &BlockPool, rows: usize, extra_bytes: usize) -> PrefixEntry {
    let mut c = LayerCache::new_in(pool.clone(), 1, 2, rows.max(1));
    for i in 0..rows {
        c.append(&[i as f32, 7.0], &[-(i as f32), -7.0], i as i32);
    }
    PrefixEntry {
        prefix_len: rows,
        full_layers: vec![c.clone()],
        keep_layers: vec![c],
        h_keep: vec![0.0; extra_bytes / std::mem::size_of::<f32>()],
        keep_positions: (0..rows as i32).collect(),
        bytes: 0,
    }
    .finalize()
}

#[test]
fn prop_clone_compact_evict_interleavings_are_uaf_free() {
    run_prop("prefix_uaf", 25, |g: &mut Gen| {
        let pool = BlockPool::new();
        let budget = g.usize_in(1, 3) * 600;
        let cache = Arc::new(PrefixCache::new_in(pool.clone(), budget));
        let mut borrowed: Vec<(LayerCache, usize)> = Vec::new(); // (clone, rows)
        let mut leases: Vec<PrefixLease> = Vec::new();
        for step in 0..g.usize_in(5, 25) {
            match g.usize_in(0, 3) {
                0 => {
                    let rows = g.usize_in(1, 2 * BLOCK_TOKENS);
                    cache.insert(1, &[step as u32], tiny_entry(&pool, rows, 64));
                }
                1 => {
                    if let Some(lease) = cache.lookup(1, &[g.usize_in(0, 30) as u32]) {
                        let rows = lease.entry().prefix_len;
                        let mut c = lease.entry().keep_layers[0].clone();
                        // Borrower mutates its view (COW) — entry frozen.
                        if rows > 1 && g.bool() {
                            c.compact(&[0, rows - 1]);
                            borrowed.push((c, 2));
                        } else {
                            borrowed.push((c, rows));
                        }
                        if g.bool() {
                            leases.push(lease); // keep pinned a while
                        }
                    }
                }
                2 => {
                    if g.bool() {
                        cache.flush();
                    }
                    leases.clear();
                }
                _ => {
                    // Every borrowed view stays readable and consistent,
                    // whatever was evicted meanwhile.
                    for (c, n) in &borrowed {
                        assert_eq!(c.len(), *n);
                        if *n > 0 {
                            assert_eq!(c.k_row(0, 0)[1], 7.0);
                        }
                        assert!(c.padding_is_zero());
                    }
                }
            }
        }
        drop(leases);
        cache.flush();
        drop(borrowed);
        assert_eq!(pool.stats().used, 0, "pool drained after all borrowers drop");
    });
}

// ----------------------------------------------- serving acceptance (mock)

/// Prefix tokens per request class in the serving tests.
const P: usize = 40;
/// Question (text-suffix) tokens.
const SUFFIX: usize = 4;
/// Conservative per-request KV estimate the mock reports.
const EST_BYTES: usize = 1000;
/// Entry payload bytes the mock publishes (h_keep only).
const SHARED_BYTES: usize = 800;
/// Mock cache config key.
const CFG: u64 = 11;

struct PMGen {
    front_left: usize,
    back_left: usize,
    produced: usize,
    total: usize,
    hit: bool,
    reused: usize,
    /// Pins the entry while in flight (mirrors `Generation`).
    _lease: Option<PrefixLease>,
}

/// Mock engine: front-half prefill costs one quantum per *token* it must
/// process — the full prompt on a miss, only the text suffix on a warm
/// prefix hit (mirroring `ModelEngine`'s resume path). Publishes a real
/// `PrefixEntry` into the pool-attached `PrefixCache` on a miss.
struct PrefixMockEngine {
    cache: Option<Arc<PrefixCache>>,
    front_token_steps: Arc<AtomicUsize>,
    step_cost: Duration,
}

impl ReplicaEngine for PrefixMockEngine {
    type Gen = PMGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<PMGen> {
        let k = req.prompt.len();
        let p = av_prefix_len(&req.segments).filter(|&p| p < k);
        let (mut front, mut hit, mut reused, mut lease) = (k, false, 0, None);
        if let (Some(cache), Some(p)) = (&self.cache, p) {
            let tokens = &req.prompt[..p];
            if let Some(l) = cache.lookup_exact(CFG, tokens) {
                front = k - p; // resume: only the suffix runs
                hit = true;
                reused = p;
                lease = Some(l);
            } else {
                let entry = PrefixEntry {
                    prefix_len: p,
                    full_layers: Vec::new(),
                    keep_layers: Vec::new(),
                    h_keep: vec![0.0; SHARED_BYTES / std::mem::size_of::<f32>()],
                    keep_positions: Vec::new(),
                    bytes: 0,
                }
                .finalize();
                assert_eq!(entry.bytes, SHARED_BYTES);
                cache.insert(CFG, tokens, entry);
            }
        }
        Ok(PMGen {
            front_left: front,
            back_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
            hit,
            reused,
            _lease: lease,
        })
    }

    fn step(&mut self, gen: &mut PMGen) -> anyhow::Result<StepEvent> {
        if !self.step_cost.is_zero() {
            std::thread::sleep(self.step_cost);
        }
        if gen.front_left > 0 {
            gen.front_left -= 1;
            self.front_token_steps.fetch_add(1, Ordering::SeqCst);
            return Ok(StepEvent::Prefilled { layer: 0 });
        }
        if gen.back_left > 0 {
            gen.back_left -= 1;
            return Ok(StepEvent::Prefilled { layer: 1 });
        }
        if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        gen.produced += 1;
        Ok(StepEvent::Token(7))
    }

    fn is_done(&self, gen: &PMGen) -> bool {
        gen.front_left == 0 && gen.back_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: PMGen) -> GenerateResult {
        GenerateResult {
            tokens: vec![7; gen.produced],
            prompt_len: P + SUFFIX,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: EST_BYTES,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: gen.hit,
            prefix_tokens_reused: gen.reused,
        }
    }

    fn kv_bytes(&self, _gen: &PMGen) -> usize {
        EST_BYTES
    }

    fn estimate_bytes(&self, _req: &GenRequest) -> usize {
        EST_BYTES
    }

    fn attach_prefix_cache(&mut self, cache: Arc<PrefixCache>, _replica: usize) {
        self.cache = Some(cache);
    }

    fn prefix_probe(&self, req: &GenRequest) -> Option<PrefixCharge> {
        let cache = self.cache.as_ref()?;
        let p = av_prefix_len(&req.segments).filter(|&p| p < req.prompt.len())?;
        cache
            .peek(CFG, &req.prompt[..p])
            .map(|(key, bytes)| PrefixCharge { key, bytes })
    }
}

/// A request whose first `P` tokens are a sample-specific AV prefix and
/// whose last `SUFFIX` tokens are the (varying) question.
fn prefix_request(sample: u32, question: u32, max_gen: usize) -> GenRequest {
    let mut prompt = vec![1u32];
    let mut segments = vec![Segment::Ctrl];
    let mut frame_of = vec![-1i32];
    for i in 0..P - 1 {
        prompt.push(sample * 1000 + i as u32);
        segments.push(Segment::Vis);
        frame_of.push((i / 8) as i32);
    }
    for t in [3, 192 + question, 250 + question, 3] {
        prompt.push(t);
        segments.push(Segment::Text);
        frame_of.push(-1);
    }
    GenRequest {
        prompt,
        segments,
        frame_of,
        // Positional (query-independent) spec: cacheable + affine.
        spec: PruningSpec::fastav(32, 4, 2, 20.0),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

/// All-text request: no AV prefix, never cacheable, no affinity.
fn filler_request(max_gen: usize) -> GenRequest {
    let n = 8;
    GenRequest {
        prompt: (0..n as u32).collect(),
        segments: vec![Segment::Text; n],
        frame_of: vec![-1; n],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

fn prefix_pool(
    replicas: usize,
    kv_budget: usize,
    front_steps: Arc<AtomicUsize>,
    step_cost: Duration,
    metrics: Arc<Registry>,
) -> ReplicaPool {
    ReplicaPool::start_with_factory(
        PoolConfig {
            replicas,
            queue_cap: 64,
            max_inflight: 8,
            kv_budget_bytes: kv_budget,
            ..Default::default()
        },
        metrics,
        move |_replica| {
            Ok(PrefixMockEngine {
                cache: None,
                front_token_steps: Arc::clone(&front_steps),
                step_cost,
            })
        },
    )
    .expect("mock pool starts")
}

fn drain(rx: std::sync::mpsc::Receiver<Event>) -> Result<usize, String> {
    let mut tokens = 0;
    loop {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Event::Token(_)) => tokens += 1,
            Ok(Event::Done(_)) => return Ok(tokens),
            Ok(Event::Error(e)) => return Err(e),
            Err(e) => panic!("stream stalled: {}", e),
        }
    }
}

/// Acceptance: a warm prefix hit skips ≥ 90% of front-layer prefill
/// steps for the shared AV prefix (here: all of them — only the text
/// suffix runs), across *different questions* on the same sample.
#[test]
fn warm_prefix_hits_skip_front_prefill_steps() {
    let front_steps = Arc::new(AtomicUsize::new(0));
    let metrics = Arc::new(Registry::default());
    let pool = prefix_pool(
        1,
        0,
        Arc::clone(&front_steps),
        Duration::ZERO,
        Arc::clone(&metrics),
    );
    let k = P + SUFFIX;
    let n_questions = 6;
    for q in 0..n_questions {
        let (_, rx) = pool.submit(prefix_request(1, q, 3)).unwrap();
        drain(rx).expect("request completes");
    }
    // Cold request pays the full prompt; each warm one only the suffix.
    let total = front_steps.load(Ordering::SeqCst);
    assert_eq!(total, k + (n_questions as usize - 1) * SUFFIX);
    // The skipped share of front-layer prefill on a warm hit is
    // (k - SUFFIX) / k — must clear the 90% acceptance bar.
    assert!(
        (k - SUFFIX) * 10 >= k * 9,
        "warm hit skips only {}/{} front steps",
        k - SUFFIX,
        k
    );
    let s = pool.prefix_stats();
    assert_eq!(s.hits, n_questions as u64 - 1);
    assert_eq!(s.insertions, 1);
    assert!(s.misses >= 1);
    // Metrics surfaced the reuse.
    assert_eq!(
        metrics.counter("fastav_prefix_tokens_reused_total").get(),
        (n_questions as u64 - 1) * P as u64
    );
    assert!(metrics.counter("fastav_prefix_cache_hits_total").get() >= s.hits);
}

/// Acceptance: shared prefix bytes are charged once by admission, so K
/// concurrent same-prefix requests fit where K × dense-estimate would
/// not (sub-linear KV accounting in K).
#[test]
fn admission_counts_shared_prefix_once_across_concurrent_requests() {
    let front_steps = Arc::new(AtomicUsize::new(0));
    let metrics = Arc::new(Registry::default());
    // Budget fits shared(800) + 4 × unique(200) exactly — but under
    // per-request dense estimates (1000 each) only ONE request at a time.
    let budget = SHARED_BYTES + 4 * (EST_BYTES - SHARED_BYTES);
    let pool = prefix_pool(
        1,
        budget,
        front_steps,
        Duration::from_millis(2),
        metrics,
    );
    // Warm the entry first.
    let (_, rx) = pool.submit(prefix_request(2, 0, 2)).unwrap();
    drain(rx).unwrap();
    // Now 4 concurrent warm requests must be co-admitted.
    let rxs: Vec<_> = (0..4)
        .map(|q| pool.submit(prefix_request(2, q, 32)).unwrap().1)
        .collect();
    let mut max_active = 0;
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(5) {
        let active = pool.status()[0].active;
        max_active = max_active.max(active);
        if max_active >= 4 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    for rx in rxs {
        drain(rx).expect("warm request completes");
    }
    assert_eq!(
        max_active, 4,
        "shared-prefix admission must co-admit all 4 (budget {} vs 4×{} dense)",
        budget, EST_BYTES
    );
    assert!(4 * EST_BYTES > budget, "test would pass trivially");
}

/// Prefix-affinity dispatch: same-prefix requests land on the replica
/// that owns the warm entry, even when another replica is less loaded.
#[test]
fn same_prefix_requests_land_on_owning_replica() {
    let front_steps = Arc::new(AtomicUsize::new(0));
    let metrics = Arc::new(Registry::default());
    let pool = prefix_pool(2, 0, front_steps, Duration::from_millis(1), metrics);
    // Occupy replica 0 (both idle → least-loaded tie-break starts at 0),
    // so the first same-prefix request routes to replica 1, which
    // becomes the entry owner.
    let (filler_id, filler_rx) = pool.submit(filler_request(1000)).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let (_, rx) = pool.submit(prefix_request(3, 0, 2)).unwrap();
    drain(rx).unwrap();
    // Free replica 0 entirely.
    pool.cancel(filler_id);
    let _ = drain(filler_rx);
    // Keep the owner (replica 1) busy with a long same-prefix request...
    let (_, long_rx) = pool.submit(prefix_request(3, 1, 300)).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    // ...then submit short same-prefix requests. Least-loaded dispatch
    // would send them to idle replica 0; affinity must keep them on 1.
    for q in 2..6 {
        let (_, rx) = pool.submit(prefix_request(3, q, 2)).unwrap();
        drain(rx).expect("short same-prefix request completes");
    }
    drain(long_rx).expect("long same-prefix request completes");
    let status = pool.status();
    assert_eq!(
        status[0].completed, 0,
        "idle replica 0 must not steal same-prefix requests from the owner"
    );
    assert_eq!(status[1].completed, 6, "owner replica serves the prefix group");
    pool.shutdown();
}
