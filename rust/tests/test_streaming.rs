//! Streamed-delivery properties against mock pools (no AOT artifacts):
//! the streamed token sequence is byte-identical to the buffered result
//! for the same request; TTFT on a [`MockClock`] lands well before full
//! latency; a consumer that stops draining parks without stalling its
//! batchmates or perturbing the admission ledger; a mid-stream
//! disconnect cancels within one scheduling quantum; terminal delivery
//! and KV release never wait on an undrained consumer; and both front
//! doors (SSE over HTTP, gRPC over h2c) relay the same events.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastav::coordinator::{Coordinator, Event, GenRequest, Priority};
use fastav::http::{api::make_handler, request, request_streaming, Server};
use fastav::metrics::Registry;
use fastav::model::{GenerateResult, StepEvent};
use fastav::policy::{PolicyRegistry, PruningSpec};
use fastav::serving::{PoolConfig, ReplicaEngine, ReplicaPool};
use fastav::streaming::{grpc, StreamReceiver, StreamRecv};
use fastav::tokens::{Layout, Segment};
use fastav::trace::{Clock, MockClock};
use fastav::util::json::Json;
use fastav::util::proptest::{run_prop, Gen};

// ---------------------------------------------------------------- mock

/// Deterministic per-request token: derived from the prompt and the
/// position, so identical requests produce identical sequences and
/// different requests (almost surely) don't — what makes the
/// byte-identity property meaningful.
fn tok(prompt: &[u32], i: usize) -> u32 {
    let mut h = 0x9e37_79b9u64;
    for &p in prompt {
        h = h.wrapping_mul(31).wrapping_add(u64::from(p));
    }
    (h.wrapping_add((i as u64).wrapping_mul(2_654_435_761)) % 97) as u32
}

struct MockGen {
    prefill_left: usize,
    produced: usize,
    total: usize,
    prompt: Vec<u32>,
    kv_bytes: usize,
}

/// Engine stand-in: every quantum burns `step_cost` of wall clock and
/// optionally ticks a shared [`MockClock`] (for exact TTFT assertions).
struct StreamMock {
    step_cost: Duration,
    tick: Option<(Arc<MockClock>, u64)>,
}

impl ReplicaEngine for StreamMock {
    type Gen = MockGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<MockGen> {
        Ok(MockGen {
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
            prompt: req.prompt.clone(),
            kv_bytes: req.prompt.len() * 1000,
        })
    }

    fn step(&mut self, gen: &mut MockGen) -> anyhow::Result<StepEvent> {
        if !self.step_cost.is_zero() {
            std::thread::sleep(self.step_cost);
        }
        if let Some((clock, d)) = &self.tick {
            clock.advance_ns(*d);
        }
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return Ok(StepEvent::Prefilled { layer: 2 - gen.prefill_left });
            }
        }
        if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        let t = tok(&gen.prompt, gen.produced);
        gen.produced += 1;
        Ok(StepEvent::Token(t))
    }

    fn is_decoding(&self, gen: &MockGen) -> bool {
        gen.prefill_left == 0 && gen.produced > 0 && gen.produced < gen.total
    }

    fn is_done(&self, gen: &MockGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: MockGen) -> GenerateResult {
        GenerateResult {
            tokens: (0..gen.produced).map(|i| tok(&gen.prompt, i)).collect(),
            prompt_len: gen.prompt.len(),
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: gen.kv_bytes,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, gen: &MockGen) -> usize {
        gen.kv_bytes
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        req.prompt.len() * 1000
    }
}

fn mock_request(prompt: Vec<u32>, max_gen: usize) -> GenRequest {
    let n = prompt.len();
    GenRequest {
        prompt,
        segments: vec![Segment::Text; n],
        frame_of: vec![-1; n],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

fn mock_pool(cfg: PoolConfig, metrics: Arc<Registry>, step_cost: Duration) -> ReplicaPool {
    ReplicaPool::start_with_factory(cfg, metrics, move |_replica| {
        Ok(StreamMock { step_cost, tick: None })
    })
    .expect("mock pool starts")
}

fn settled_stats(pool: &ReplicaPool) -> fastav::serving::PoolStats {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if (s.conserved() && s.in_flight == 0 && s.in_queue == 0)
            || t0.elapsed() > Duration::from_secs(10)
        {
            return s;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Drain a buffered receiver; returns the streamed tokens and the final
/// result tokens.
fn drain_buffered(rx: std::sync::mpsc::Receiver<Event>) -> (Vec<u32>, Vec<u32>) {
    let mut streamed = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Event::Token(t)) => streamed.push(t),
            Ok(Event::Done(res)) => return (streamed, res.tokens),
            Ok(Event::Error(e)) => panic!("buffered request failed: {}", e),
            Err(e) => panic!("buffered stream stalled: {}", e),
        }
    }
}

/// Drain a stream receiver; returns the streamed tokens and the final
/// result tokens.
fn drain_stream(rx: &StreamReceiver) -> (Vec<u32>, Vec<u32>) {
    let mut streamed = Vec::new();
    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(10), "stream stalled");
        match rx.recv(Duration::from_millis(100)) {
            StreamRecv::Token(t) => streamed.push(t),
            StreamRecv::Done(res) => return (streamed, res.tokens),
            StreamRecv::Error(e) => panic!("streamed request failed: {}", e),
            StreamRecv::TimedOut => continue,
            StreamRecv::SenderGone => panic!("worker dropped the request"),
        }
    }
}

// --------------------------------------------------------------- tests

#[test]
fn prop_streamed_tokens_byte_identical_to_buffered() {
    run_prop("streamed_equals_buffered", 12, |g: &mut Gen| {
        let pool = mock_pool(
            PoolConfig {
                replicas: 1,
                queue_cap: 16,
                max_inflight: g.usize_in(1, 3),
                ..Default::default()
            },
            Arc::new(Registry::default()),
            Duration::ZERO,
        );
        for _ in 0..g.usize_in(1, 3) {
            let prompt: Vec<u32> =
                (0..g.usize_in(1, 12)).map(|_| (g.u64() % 1000) as u32).collect();
            let max_gen = g.usize_in(1, 20);

            let (_, brx) = pool.submit(mock_request(prompt.clone(), max_gen)).unwrap();
            let (buf_streamed, buf_final) = drain_buffered(brx);

            let (_, srx) = pool.submit_streaming(mock_request(prompt, max_gen)).unwrap();
            let (str_streamed, str_final) = drain_stream(&srx);

            assert_eq!(str_streamed, buf_streamed, "streamed token sequences diverge");
            assert_eq!(str_final, buf_final, "final results diverge");
            assert_eq!(str_streamed, str_final, "stream is not the result");
        }
        let s = settled_stats(&pool);
        assert!(s.conserved(), "ledger out of balance: {:?}", s);
    });
}

#[test]
fn ttft_is_far_below_full_latency_on_mock_clock() {
    // Every engine quantum ticks the mock clock 1ms; with 2 prefill
    // quanta and 12 decode quanta, TTFT must land near the front.
    let clock = Arc::new(MockClock::new());
    let engine_clock = Arc::clone(&clock);
    let pool = ReplicaPool::start_with_factory_clocked(
        PoolConfig {
            replicas: 1,
            queue_cap: 4,
            max_inflight: 1,
            trace_sample: 1.0,
            trace_ring: 16,
            ..Default::default()
        },
        Arc::new(Registry::default()),
        move |_replica| {
            Ok(StreamMock {
                step_cost: Duration::ZERO,
                tick: Some((Arc::clone(&engine_clock), 1_000_000)),
            })
        },
        clock as Arc<dyn Clock>,
    )
    .expect("clocked mock pool starts");

    let (id, rx) = pool.submit_streaming(mock_request(vec![1, 2, 3], 12)).unwrap();
    let (streamed, _) = drain_stream(&rx);
    assert_eq!(streamed.len(), 12);
    settled_stats(&pool);

    let trace = pool.tracer().get(id).expect("sampled trace");
    let ttft = trace.ttft_ns.expect("stream recorded a first token");
    let total = trace.duration_ns();
    assert!(
        ttft * 3 < total,
        "TTFT {}ns is not well below full latency {}ns",
        ttft,
        total
    );
    assert!(
        trace.spans.iter().any(|s| s.name == "first_token_sent"),
        "streamed trace missing first_token_sent marker"
    );
}

#[test]
fn parked_stream_never_stalls_batchmates_or_the_ledger() {
    let metrics = Arc::new(Registry::default());
    let pool = mock_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 8,
            max_inflight: 2,
            stream_channel_cap: 2,
            ..Default::default()
        },
        Arc::clone(&metrics),
        Duration::from_micros(100),
    );

    // A: a streaming request whose consumer goes silent — the tiny
    // channel fills after 2 tokens and the request parks.
    let (_, arx) = pool.submit_streaming(mock_request(vec![9, 9, 9], 16)).unwrap();
    let t0 = Instant::now();
    while pool.stream_stats().parked == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "stream never parked");
        std::thread::sleep(Duration::from_millis(1));
    }

    // B, B': buffered batchmates submitted while A is parked — they
    // must complete promptly and byte-identically to each other.
    let (_, b1) = pool.submit(mock_request(vec![5, 6], 8)).unwrap();
    let (streamed1, final1) = drain_buffered(b1);
    let (_, b2) = pool.submit(mock_request(vec![5, 6], 8)).unwrap();
    let (streamed2, final2) = drain_buffered(b2);
    assert_eq!(streamed1, streamed2, "parked neighbor perturbed a batchmate");
    assert_eq!(final1, final2);
    assert_eq!(final1.len(), 8);

    // A is still parked (we never drained it) and was counted.
    assert_eq!(pool.stream_stats().parked, 1);
    assert!(metrics.counter("fastav_streams_parked_total").get() >= 1);

    // Draining resumes A: the full sequence arrives, nothing lost.
    let (a_streamed, a_final) = drain_stream(&arx);
    assert_eq!(a_streamed.len(), 16);
    assert_eq!(a_streamed, a_final);

    let s = settled_stats(&pool);
    assert!(s.conserved(), "ledger out of balance: {:?}", s);
    assert_eq!(s.completed, 3);
    let st = pool.stream_stats();
    assert_eq!((st.active, st.parked, st.completed), (0, 0, 1));
}

#[test]
fn mid_stream_disconnect_cancels_within_one_quantum() {
    let metrics = Arc::new(Registry::default());
    let pool = mock_pool(
        PoolConfig { replicas: 1, queue_cap: 4, max_inflight: 1, ..Default::default() },
        Arc::clone(&metrics),
        Duration::from_millis(1),
    );

    let (_, rx) = pool.submit_streaming(mock_request(vec![4, 4], 10_000)).unwrap();
    // Take a couple of tokens, then vanish.
    let mut got = 0;
    while got < 2 {
        match rx.recv(Duration::from_millis(100)) {
            StreamRecv::Token(_) => got += 1,
            StreamRecv::TimedOut => continue,
            other => panic!("unexpected early terminal: {:?}", other),
        }
    }
    drop(rx);

    let s = settled_stats(&pool);
    assert!(s.conserved(), "ledger out of balance: {:?}", s);
    assert_eq!(s.canceled, 1, "disconnect did not cancel: {:?}", s);
    assert_eq!(metrics.counter("fastav_client_disconnects_total").get(), 1);
    // The canceled stream still closed out the session accounting.
    let st = pool.stream_stats();
    assert_eq!((st.active, st.parked, st.completed), (0, 0, 1));
    // KV fully released (eager terminal cleanup).
    for r in pool.status() {
        assert_eq!(r.kv_bytes, 0, "replica {} still holds KV", r.id);
    }
}

#[test]
fn terminal_delivery_and_kv_release_never_wait_on_the_consumer() {
    // Admission budget fits exactly one request (prompt 3 → 3000-byte
    // estimate): the second admits only once the first's KV is freed.
    let pool = mock_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 4,
            max_inflight: 4,
            kv_budget_bytes: 4000,
            ..Default::default()
        },
        Arc::new(Registry::default()),
        Duration::ZERO,
    );

    // A streams 3 tokens (well under the channel cap — never parks)
    // into a consumer that reads nothing, and must still finish.
    let (_, arx) = pool.submit_streaming(mock_request(vec![1, 2, 3], 3)).unwrap();
    let t0 = Instant::now();
    while pool.stats().completed == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "completion is blocked on an undrained consumer"
        );
        std::thread::sleep(Duration::from_millis(1));
    }

    // Its KV grant is back: an equally-sized request admits and runs.
    let (_, brx) = pool.submit(mock_request(vec![7, 8, 9], 3)).unwrap();
    let (_, b_final) = drain_buffered(brx);
    assert_eq!(b_final.len(), 3);

    // The undrained terminal is still there when the consumer returns.
    let (a_streamed, a_final) = drain_stream(&arx);
    assert_eq!(a_streamed.len(), 3);
    assert_eq!(a_streamed, a_final);

    let s = settled_stats(&pool);
    assert!(s.conserved(), "ledger out of balance: {:?}", s);
    for r in pool.status() {
        assert_eq!(r.kv_bytes, 0, "replica {} still holds KV", r.id);
    }
}

// ------------------------------------------------------ HTTP front door

fn layout() -> Layout {
    Layout { frames: 2, vis_per_frame: 4, aud_len: 6, aud_per_frame: 3, interleaved: false }
}

fn test_registry() -> Arc<PolicyRegistry> {
    let calib = fastav::calibration::Calibration {
        model: "tiny".into(),
        samples: 8,
        threshold: 0.01,
        vis_cutoff: 5,
        keep_audio: 2,
        keep_frames: 0,
        budget: 6,
        profile: Vec::new(),
    };
    Arc::new(PolicyRegistry::builtin(&calib, 20.0))
}

fn mock_coordinator() -> Arc<Coordinator> {
    let pool = mock_pool(
        PoolConfig { replicas: 1, queue_cap: 16, max_inflight: 2, ..Default::default() },
        Arc::new(Registry::default()),
        Duration::ZERO,
    );
    Arc::new(Coordinator::from_pool(pool))
}

/// Parse an SSE body into `(event, data)` pairs.
fn parse_sse(body: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for frame in body.split("\n\n").filter(|f| !f.trim().is_empty()) {
        let mut event = String::new();
        let mut data = String::new();
        for line in frame.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                event = v.to_string();
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v.to_string();
            }
        }
        out.push((event, data));
    }
    out
}

#[test]
fn sse_stream_matches_buffered_http_response() {
    let coord = mock_coordinator();
    let handler = make_handler(Arc::clone(&coord), layout(), test_registry(), 6, 1234);
    let server = Server::bind("127.0.0.1:0", 2, handler).unwrap();
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let thread = std::thread::spawn(move || server.serve());

    let body = br#"{"dataset": "avqa", "index": 3, "max_gen": 5}"#;
    let (code, buf) = request(&addr, "POST", "/v2/generate", body).unwrap();
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&buf));
    let buffered = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();

    let stream_body = br#"{"dataset": "avqa", "index": 3, "max_gen": 5, "stream": true}"#;
    let mut sse = Vec::new();
    let status = request_streaming(&addr, "POST", "/v2/generate", stream_body, |chunk| {
        sse.extend_from_slice(chunk)
    })
    .unwrap();
    assert_eq!(status, 200);
    let events = parse_sse(std::str::from_utf8(&sse).unwrap());

    // Grammar: policy first, then tokens with contiguous indexes, then
    // exactly one done.
    assert_eq!(events.first().map(|(e, _)| e.as_str()), Some("policy"));
    let policy = Json::parse(&events[0].1).unwrap();
    assert!(policy.get("profile").as_str().is_some());
    assert!(policy.get("spec_hash").as_str().is_some());
    let done: Vec<&(String, String)> =
        events.iter().filter(|(e, _)| e == "done").collect();
    assert_eq!(done.len(), 1, "expected exactly one done event");
    assert_eq!(events.last().map(|(e, _)| e.as_str()), Some("done"));

    let mut streamed_tokens = Vec::new();
    for (i, (event, data)) in events[1..events.len() - 1].iter().enumerate() {
        assert_eq!(event, "token");
        let j = Json::parse(data).unwrap();
        assert_eq!(j.get("index").as_usize(), Some(i));
        streamed_tokens.push(j.get("token").as_usize().unwrap() as u32);
    }

    // Byte-identity with the buffered response for the same request:
    // same tokens, same rendered answer, same policy block.
    let final_payload = Json::parse(&done[0].1).unwrap();
    let buffered_tokens: Vec<u32> = buffered
        .get("tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(streamed_tokens, buffered_tokens);
    assert_eq!(
        final_payload.get("tokens").to_string(),
        buffered.get("tokens").to_string()
    );
    assert_eq!(
        final_payload.get("answer").as_str(),
        buffered.get("answer").as_str()
    );
    assert_eq!(
        final_payload.get("policy").to_string(),
        buffered.get("policy").to_string()
    );

    // The pool block reports the finished stream.
    let (code, buf) = request(&addr, "GET", "/v1/pool", b"").unwrap();
    assert_eq!(code, 200);
    let pool_json = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
    assert_eq!(pool_json.get("streams").get("completed").as_usize(), Some(1));
    assert_eq!(pool_json.get("streams").get("active").as_usize(), Some(0));

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(&addr);
    let _ = thread.join();
}

// ------------------------------------------------------ gRPC front door

fn spin_up_grpc(
    coord: Arc<Coordinator>,
    max_gen: usize,
) -> (String, Arc<std::sync::atomic::AtomicBool>) {
    let server = grpc::GrpcServer::bind(
        "127.0.0.1:0",
        2,
        grpc::GrpcCtx {
            coord,
            layout: layout(),
            registry: test_registry(),
            max_gen,
            base_seed: 1234,
        },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    std::thread::spawn(move || server.serve());
    (addr, stop)
}

#[test]
fn grpc_unary_and_streaming_agree() {
    let coord = mock_coordinator();
    let (addr, stop) = spin_up_grpc(Arc::clone(&coord), 6);

    let req = grpc::encode_generate_request(&grpc::GenerateRequestPb {
        dataset: "avqa".into(),
        index: 3,
        max_gen: 5,
        ..Default::default()
    });

    let unary = grpc::call(&addr, grpc::PATH_GENERATE, &req).unwrap();
    assert_eq!(unary.status, 0, "unary failed: {}", unary.message);
    let unary_resp = grpc::decode_generate_response(&unary.messages[0]).unwrap();
    assert_eq!(unary_resp.tokens.len(), 5);
    assert!(unary_resp.policy.is_some());

    let streamed = grpc::call(&addr, grpc::PATH_GENERATE_STREAM, &req).unwrap();
    assert_eq!(streamed.status, 0, "stream failed: {}", streamed.message);
    let chunks: Vec<grpc::StreamChunkPb> = streamed
        .messages
        .iter()
        .map(|m| grpc::decode_stream_chunk(m).unwrap())
        .collect();
    assert!(matches!(chunks.first(), Some(grpc::StreamChunkPb::Policy(_))));
    let mut tokens = Vec::new();
    let mut done_tokens = Vec::new();
    for c in &chunks {
        match c {
            grpc::StreamChunkPb::Policy(_) => {}
            grpc::StreamChunkPb::Token { value, index } => {
                assert_eq!(*index as usize, tokens.len());
                tokens.push(*value);
            }
            grpc::StreamChunkPb::Done(r) => done_tokens = r.tokens.clone(),
            grpc::StreamChunkPb::Error(e) => panic!("stream errored: {}", e),
        }
    }
    // Same request over both RPCs → identical token sequences.
    assert_eq!(tokens, unary_resp.tokens);
    assert_eq!(done_tokens, unary_resp.tokens);

    // gRPC requests flow through the same per-profile counter family.
    assert!(
        coord
            .metrics
            .counter("fastav_requests_total{profile=\"balanced\"}")
            .get()
            >= 2
    );
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
}

#[test]
fn grpc_client_cancel_stops_generation() {
    let pool = mock_pool(
        PoolConfig { replicas: 1, queue_cap: 4, max_inflight: 1, ..Default::default() },
        Arc::new(Registry::default()),
        Duration::from_millis(1),
    );
    let coord = Arc::new(Coordinator::from_pool(pool));
    // A long generation (1ms per step) so the cancel always lands
    // mid-stream, never in a race with natural completion.
    let (addr, stop) = spin_up_grpc(Arc::clone(&coord), 5000);

    let req = grpc::encode_generate_request(&grpc::GenerateRequestPb {
        dataset: "avqa".into(),
        index: 0,
        max_gen: 5000,
        ..Default::default()
    });
    // Bail after the first token chunk; the client sends RST_STREAM and
    // the server cancels the request.
    let mut seen_token = false;
    let reply = grpc::call_streaming(&addr, grpc::PATH_GENERATE_STREAM, &req, |m| {
        match grpc::decode_stream_chunk(m) {
            Some(grpc::StreamChunkPb::Token { .. }) => {
                seen_token = true;
                false
            }
            _ => true,
        }
    })
    .unwrap();
    assert!(seen_token, "never saw a token before canceling");
    assert_eq!(reply.status, grpc::GRPC_CANCELLED);

    let t0 = Instant::now();
    loop {
        let s = coord.pool_stats();
        if s.canceled == 1 && s.in_flight == 0 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "server never canceled: {:?}",
            s
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
}
