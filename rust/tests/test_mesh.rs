//! Device-mesh (tensor-parallel) serving properties, run against a mock
//! engine so no AOT artifacts or PJRT devices are needed:
//!
//! * **tp_degree = 1 is the identity:** a pool whose engine runs the
//!   mesh executor with one shard produces token-for-token identical
//!   per-request streams — and an identical conservation ledger — as the
//!   pre-refactor direct engine on the same workload. The mock mirrors
//!   the real engine's structure (per-shard partials + host combine),
//!   with one shard covering everything at D = 1.
//! * **Shard invariance:** the combine step (concat attention outputs /
//!   all-reduce partials) makes D = 2 and D = 4 groups emit the same
//!   streams as D = 1 — sharding must never change results, only where
//!   they are computed.
//! * **Pooled group capacity:** admission charges KV bytes against the
//!   device group's pooled budget (per-device budget × tp_degree), so a
//!   request that is Oversize for a single device fits a tp = 2 group.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::metrics::Registry;
use fastav::model::{GenerateResult, StepEvent};
use fastav::policy::PruningSpec;
use fastav::serving::{PoolConfig, PoolStats, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::util::proptest::{run_prop, Gen};

// ---------------------------------------------------------------- mock

/// Deterministic per-(request, step) token — the value every mesh degree
/// must reproduce exactly.
fn mock_token(seed: u64, step: usize) -> u32 {
    let x = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(step as u64)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    (x >> 33) as u32 % 1000
}

struct MeshGen {
    seed: u64,
    prefill_left: usize,
    produced: usize,
    total: usize,
}

/// Engine that mirrors the mesh executor's shape: every step computes
/// one *partial* per shard and host-combines (sums) them into the
/// token. The partials tile the direct value exactly, so any combine
/// bug (lost shard, double count, wrong order) changes the stream.
struct MeshMock {
    tp: usize,
    est_bytes: usize,
}

impl MeshMock {
    fn combined_token(&self, seed: u64, step: usize) -> u32 {
        let base = mock_token(seed, step);
        // Shard s owns base/tp "heads"; shard 0 also owns the remainder
        // (like the head ranges of a non-divisible logits slice). The
        // all-reduce (sum) reconstructs base for every tp.
        let share = base / self.tp as u32;
        let mut sum = 0u32;
        for s in 0..self.tp {
            let partial = if s == 0 { share + base % self.tp as u32 } else { share };
            sum += partial;
        }
        sum
    }

    fn advance(&self, gen: &mut MeshGen) -> StepEvent {
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return StepEvent::Prefilled { layer: 0 };
            }
        } else if gen.produced >= gen.total {
            return StepEvent::Done;
        }
        let tok = self.combined_token(gen.seed, gen.produced);
        gen.produced += 1;
        StepEvent::Token(tok)
    }
}

impl ReplicaEngine for MeshMock {
    type Gen = MeshGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<MeshGen> {
        Ok(MeshGen {
            seed: req.prompt.iter().fold(0u64, |a, &t| a * 31 + t as u64),
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
        })
    }

    fn step(&mut self, gen: &mut MeshGen) -> anyhow::Result<StepEvent> {
        Ok(self.advance(gen))
    }

    fn is_done(&self, gen: &MeshGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: MeshGen) -> GenerateResult {
        GenerateResult {
            tokens: (0..gen.produced)
                .map(|s| self.combined_token(gen.seed, s))
                .collect(),
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: self.est_bytes,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, _gen: &MeshGen) -> usize {
        self.est_bytes
    }

    fn estimate_bytes(&self, _req: &GenRequest) -> usize {
        self.est_bytes
    }
}

/// The pre-refactor shape: one engine, one device, no combine step.
struct DirectMock;

impl ReplicaEngine for DirectMock {
    type Gen = MeshGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<MeshGen> {
        Ok(MeshGen {
            seed: req.prompt.iter().fold(0u64, |a, &t| a * 31 + t as u64),
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
        })
    }

    fn step(&mut self, gen: &mut MeshGen) -> anyhow::Result<StepEvent> {
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return Ok(StepEvent::Prefilled { layer: 0 });
            }
        } else if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        let tok = mock_token(gen.seed, gen.produced);
        gen.produced += 1;
        Ok(StepEvent::Token(tok))
    }

    fn is_done(&self, gen: &MeshGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: MeshGen) -> GenerateResult {
        GenerateResult {
            tokens: (0..gen.produced).map(|s| mock_token(gen.seed, s)).collect(),
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: 1000,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, _gen: &MeshGen) -> usize {
        1000
    }

    fn estimate_bytes(&self, _req: &GenRequest) -> usize {
        1000
    }
}

// ------------------------------------------------------------- harness

fn request(seed_tok: u32, max_gen: usize) -> GenRequest {
    GenRequest {
        prompt: vec![seed_tok, 2, 3, 4],
        segments: vec![Segment::Ctrl, Segment::Vis, Segment::Aud, Segment::Text],
        frame_of: vec![-1, 0, -1, -1],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

fn streams(receivers: Vec<Receiver<Event>>) -> Vec<Vec<u32>> {
    receivers
        .into_iter()
        .map(|rx| {
            let mut toks = Vec::new();
            loop {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(Event::Token(t)) => toks.push(t),
                    Ok(Event::Done(res)) => {
                        assert_eq!(res.tokens, toks, "Done result diverges from stream");
                        return toks;
                    }
                    Ok(Event::Error(e)) => panic!("request failed: {}", e),
                    Err(e) => panic!("stream stalled: {}", e),
                }
            }
        })
        .collect()
}

fn settled(pool: &ReplicaPool) -> PoolStats {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if (s.conserved() && s.in_flight == 0 && s.in_queue == 0)
            || t0.elapsed() > Duration::from_secs(10)
        {
            return s;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Drive one workload on a mesh pool at `tp`, returning streams + ledger.
fn drive_mesh(tp: usize, reqs: &[(u32, usize)], max_inflight: usize) -> (Vec<Vec<u32>>, PoolStats) {
    let pool = ReplicaPool::start_with_factory(
        PoolConfig {
            replicas: 1,
            queue_cap: 64,
            max_inflight,
            tp_degree: tp,
            ..Default::default()
        },
        std::sync::Arc::new(Registry::default()),
        move |_r| Ok(MeshMock { tp, est_bytes: 1000 }),
    )
    .expect("mesh mock pool starts");
    let receivers: Vec<_> = reqs
        .iter()
        .map(|&(seed, max_gen)| pool.submit(request(seed, max_gen)).unwrap().1)
        .collect();
    let streams = streams(receivers);
    let stats = settled(&pool);
    (streams, stats)
}

// --------------------------------------------------------------- tests

#[test]
fn prop_tp1_identical_to_prerefactor_engine() {
    run_prop("tp1_identity", 10, |g: &mut Gen| {
        let n = g.usize_in(2, 10);
        let max_inflight = g.usize_in(2, 6);
        let reqs: Vec<(u32, usize)> = (0..n)
            .map(|i| (100 + i as u32 * 7, g.usize_in(1, 12)))
            .collect();

        // Pre-refactor shape: the direct engine with no mesh plumbing.
        let direct_pool = ReplicaPool::start_with_factory(
            PoolConfig {
                replicas: 1,
                queue_cap: 64,
                max_inflight,
                ..Default::default()
            },
            std::sync::Arc::new(Registry::default()),
            |_r| Ok(DirectMock),
        )
        .expect("direct pool starts");
        let receivers: Vec<_> = reqs
            .iter()
            .map(|&(seed, max_gen)| direct_pool.submit(request(seed, max_gen)).unwrap().1)
            .collect();
        let direct_streams = streams(receivers);
        let direct_stats = settled(&direct_pool);

        let (mesh_streams, mesh_stats) = drive_mesh(1, &reqs, max_inflight);
        assert_eq!(
            mesh_streams, direct_streams,
            "tp_degree=1 must be token-for-token identical to the direct engine"
        );
        assert!(mesh_stats.conserved() && direct_stats.conserved());
        assert_eq!(mesh_stats.submitted, direct_stats.submitted);
        assert_eq!(mesh_stats.completed, direct_stats.completed);
        assert_eq!(mesh_stats.failed, direct_stats.failed);
        assert_eq!(mesh_stats.completed, n as u64);
    });
}

#[test]
fn prop_shard_degree_invariant() {
    run_prop("shard_degree_invariance", 10, |g: &mut Gen| {
        let n = g.usize_in(2, 8);
        let max_inflight = g.usize_in(2, 4);
        let reqs: Vec<(u32, usize)> = (0..n)
            .map(|i| (500 + i as u32 * 13, g.usize_in(1, 10)))
            .collect();
        let (s1, t1) = drive_mesh(1, &reqs, max_inflight);
        let (s2, t2) = drive_mesh(2, &reqs, max_inflight);
        let (s4, t4) = drive_mesh(4, &reqs, max_inflight);
        assert_eq!(s1, s2, "tp=2 group must emit tp=1 streams");
        assert_eq!(s1, s4, "tp=4 group must emit tp=1 streams");
        assert_eq!(t1.completed, t2.completed);
        assert_eq!(t1.completed, t4.completed);
        assert!(t2.conserved() && t4.conserved());
    });
}

#[test]
fn group_pools_kv_capacity_across_devices() {
    // Per-device budget 1000, request estimate 1500: Oversize for a
    // single device, fits a tp=2 group's pooled 2000-byte capacity.
    let run = |tp: usize| {
        let pool = ReplicaPool::start_with_factory(
            PoolConfig {
                replicas: 1,
                queue_cap: 16,
                max_inflight: 2,
                kv_budget_bytes: 1000,
                tp_degree: tp,
                ..Default::default()
            },
            std::sync::Arc::new(Registry::default()),
            move |_r| Ok(MeshMock { tp, est_bytes: 1500 }),
        )
        .expect("pool starts");
        let rx: Vec<_> = (0..3)
            .map(|i| pool.submit(request(800 + i, 4)).unwrap().1)
            .collect();
        // Drain every stream to completion or error.
        let mut completed = 0;
        for r in rx {
            loop {
                match r.recv_timeout(Duration::from_secs(10)) {
                    Ok(Event::Done(_)) => {
                        completed += 1;
                        break;
                    }
                    Ok(Event::Error(e)) => {
                        assert!(
                            e.contains("over the replica budget"),
                            "unexpected error: {}",
                            e
                        );
                        break;
                    }
                    Ok(Event::Token(_)) => {}
                    Err(e) => panic!("stream stalled: {}", e),
                }
            }
        }
        let stats = settled(&pool);
        (completed, stats)
    };
    let (done1, stats1) = run(1);
    assert_eq!(done1, 0, "1500-byte requests cannot fit a 1000-byte device");
    assert_eq!(stats1.failed, 3);
    let (done2, stats2) = run(2);
    assert_eq!(done2, 3, "tp=2 pools 2000 bytes; requests must fit");
    assert_eq!(stats2.failed, 0);
}

#[test]
fn pool_status_reports_group_shape() {
    let pool = ReplicaPool::start_with_factory(
        PoolConfig {
            replicas: 2,
            kv_budget_bytes: 1000,
            tp_degree: 2,
            ..Default::default()
        },
        std::sync::Arc::new(Registry::default()),
        |_r| Ok(MeshMock { tp: 2, est_bytes: 10 }),
    )
    .expect("pool starts");
    let status = pool.status();
    assert_eq!(status.len(), 2);
    for r in &status {
        assert_eq!(r.tp_degree, 2);
        assert_eq!(r.kv_budget_bytes, 2000, "budget reported per group, pooled");
    }
}
