//! Property-based tests over the pure substrates (no artifacts needed):
//! pruning invariants, KV-cache compaction, FLOPs monotonicity, scheduler
//! conservation, JSON round-trips, and HTTP parser robustness.

mod common;

use fastav::flops::FlopsModel;
use fastav::http::{parse_request, ParseOutcome};
use fastav::kvcache::LayerCache;
use fastav::pruning::{
    fine_keep, global_keep, validate_keep, FineStrategy, GlobalInputs, GlobalStrategy,
};
use fastav::tokens::Segment;
use fastav::util::json::Json;
use fastav::util::proptest::{run_prop, Gen};

/// Random prompt-shaped segment vector: BOS + vis/aud mix + >=1 text.
fn gen_segments(g: &mut Gen) -> (Vec<Segment>, Vec<i32>) {
    let frames = g.usize_in(1, 5) as i32;
    let vis_per = g.usize_in(1, 6);
    let auds = g.usize_in(1, 8);
    let texts = g.usize_in(1, 4);
    let mut segs = vec![Segment::Ctrl];
    let mut fr = vec![-1i32];
    for f in 0..frames {
        for _ in 0..vis_per {
            segs.push(Segment::Vis);
            fr.push(f);
        }
    }
    for _ in 0..auds {
        segs.push(Segment::Aud);
        fr.push(-1);
    }
    for _ in 0..texts {
        segs.push(Segment::Text);
        fr.push(-1);
    }
    (segs, fr)
}

#[test]
fn prop_global_keep_always_valid() {
    run_prop("global_keep_valid", 200, |g| {
        let (segs, fr) = gen_segments(g);
        let n = segs.len();
        let scores: Vec<f32> = (0..n).map(|_| g.f64_unit() as f32).collect();
        let rollout: Vec<f32> = (0..n).map(|_| g.f64_unit() as f32).collect();
        let av = segs
            .iter()
            .filter(|s| matches!(s, Segment::Vis | Segment::Aud))
            .count();
        let budget = g.usize_in(0, av);
        let strategies = [
            GlobalStrategy::None,
            GlobalStrategy::Vtw,
            GlobalStrategy::Random,
            GlobalStrategy::TopAttentive,
            GlobalStrategy::LowAttentive,
            GlobalStrategy::TopInformative,
            GlobalStrategy::LowInformative,
            GlobalStrategy::FastAvPosition {
                vis_cutoff: g.usize_in(0, n),
                keep_audio: g.usize_in(1, 8),
                keep_frames: g.usize_in(1, 5),
            },
            GlobalStrategy::FastV { keep_ratio: g.f64_unit() },
        ];
        let strat = g.choose(&strategies).clone();
        let inp = GlobalInputs {
            segments: &segs,
            frame_of: &fr,
            scores: Some(&scores),
            rollout: Some(&rollout),
            budget,
            seed: g.u64(),
            min_keep_vis: 0,
            min_keep_aud: 0,
        };
        let keep = global_keep(&strat, &inp);
        validate_keep(&keep, &segs).unwrap_or_else(|e| {
            panic!("strategy {:?} violated invariants: {}", strat, e)
        });
        // Budget strategies keep exactly `budget` AV tokens.
        if matches!(
            strat,
            GlobalStrategy::Random
                | GlobalStrategy::TopAttentive
                | GlobalStrategy::LowAttentive
                | GlobalStrategy::TopInformative
                | GlobalStrategy::LowInformative
        ) {
            let kept_av = keep
                .iter()
                .filter(|&&i| matches!(segs[i], Segment::Vis | Segment::Aud))
                .count();
            assert_eq!(kept_av, budget.min(av));
        }
    });
}

#[test]
fn prop_fine_keep_exact_drop_count() {
    run_prop("fine_keep_count", 200, |g| {
        let (segs, _) = gen_segments(g);
        let n = segs.len();
        let scores: Vec<f32> = (0..n).map(|_| g.f64_unit() as f32).collect();
        let percent = g.usize_in(0, 100) as f64;
        let strat = *g.choose(&[
            FineStrategy::Random,
            FineStrategy::TopAttentive,
            FineStrategy::LowAttentive,
        ]);
        let keep = fine_keep(strat, &scores, &segs, percent, g.u64(), 0, 0);
        validate_keep(&keep, &segs).unwrap();
        let prunable = (0..n)
            .filter(|&i| i != n - 1 && matches!(segs[i], Segment::Vis | Segment::Aud))
            .count();
        let expect_drop = ((percent / 100.0) * prunable as f64).round() as usize;
        assert_eq!(keep.len(), n - expect_drop.min(prunable));
    });
}

#[test]
fn prop_fine_keep_low_attentive_drops_lowest() {
    run_prop("fine_low_attentive", 100, |g| {
        let (segs, _) = gen_segments(g);
        let n = segs.len();
        // Distinct scores so the ordering is unambiguous.
        let scores: Vec<f32> = (0..n).map(|i| (i as f32) * 0.001 + g.f64_unit() as f32 * 0.0001).collect();
        let keep = fine_keep(FineStrategy::LowAttentive, &scores, &segs, 50.0, 0, 0, 0);
        let dropped: Vec<usize> = (0..n).filter(|i| !keep.contains(i)).collect();
        // Every dropped AV token must score <= every kept prunable AV token.
        let kept_av_min = keep
            .iter()
            .filter(|&&i| i != n - 1 && matches!(segs[i], Segment::Vis | Segment::Aud))
            .map(|&i| scores[i])
            .fold(f32::INFINITY, f32::min);
        for &d in &dropped {
            assert!(scores[d] <= kept_av_min + 1e-6);
        }
    });
}

#[test]
fn prop_kvcache_compact_preserves_mapping() {
    run_prop("kvcache_compact", 150, |g| {
        let n_heads = g.usize_in(1, 4);
        let dh = g.usize_in(2, 8);
        let n = g.usize_in(1, 24);
        let cap = n + g.usize_in(0, 8);
        // K rows tagged by index so we can trace them.
        let mut src_k = vec![0.0f32; n_heads * n * dh];
        let mut src_v = vec![0.0f32; n_heads * n * dh];
        for h in 0..n_heads {
            for i in 0..n {
                for e in 0..dh {
                    src_k[h * n * dh + i * dh + e] = (h * 1000 + i) as f32;
                    src_v[h * n * dh + i * dh + e] = -((h * 1000 + i) as f32);
                }
            }
        }
        let positions: Vec<i32> = (0..n as i32).map(|i| i * 3 + 1).collect();
        let mut cache = LayerCache::from_prefill(
            n_heads, dh, cap, &src_k, &src_v, n, n, &positions,
        );
        // Random ascending keep subset (non-empty).
        let mut keep: Vec<usize> = (0..n).filter(|_| g.bool()).collect();
        if keep.is_empty() {
            keep.push(g.usize_in(0, n - 1));
        }
        cache.compact(&keep);
        assert_eq!(cache.len(), keep.len());
        for (row, &src) in keep.iter().enumerate() {
            assert_eq!(cache.positions()[row], positions[src]);
            for h in 0..n_heads {
                assert_eq!(cache.k_row(h, row)[0], (h * 1000 + src) as f32);
                assert_eq!(cache.v_row(h, row)[0], -((h * 1000 + src) as f32));
            }
        }
        // The vacated range reads exactly zero — the whole range, not
        // just the first 64 rows (regression: pre-paged compact left
        // stale K/V beyond a 64-slot zeroing window).
        assert!(cache.padding_is_zero(), "stale rows beyond len after compact");
        // Grow preserves everything.
        let bigger = cap + g.usize_in(1, 16);
        cache.grow(bigger);
        for (row, &src) in keep.iter().enumerate() {
            assert_eq!(cache.k_row(0, row)[0], src as f32);
        }
    });
}

#[test]
fn prop_flops_monotone_and_positive() {
    run_prop("flops_monotone", 200, |g| {
        let m = FlopsModel {
            d_model: g.usize_in(8, 256),
            d_ff: g.usize_in(8, 512),
            n_layers: g.usize_in(1, 32),
            vocab: g.usize_in(16, 1024),
        };
        let n = g.usize_in(1, 512);
        assert!(m.layer(n, n) > 0);
        assert!(m.layer(n, n) <= m.layer(n + 1, n + 1));
        assert!(m.vanilla_prefill(n) < m.vanilla_prefill(n + 1));
        let gen = g.usize_in(1, 8);
        assert!(m.vanilla_generate(n, gen) <= m.vanilla_generate(n, gen + 1));
    });
}

#[test]
fn prop_json_roundtrip() {
    run_prop("json_roundtrip", 200, |g| {
        // Random JSON tree of bounded depth.
        fn build(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.usize_in(0, 1_000_000) as f64) - 500_000.0),
                3 => {
                    let len = g.usize_in(0, 12);
                    let s: String = (0..len)
                        .map(|_| char::from_u32(g.usize_in(32, 126) as u32).unwrap())
                        .collect();
                    Json::Str(s)
                }
                4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| build(g, depth - 1)).collect()),
                _ => {
                    let n = g.usize_in(0, 4);
                    Json::Obj(
                        (0..n)
                            .map(|i| (format!("k{}", i), build(g, depth - 1)))
                            .collect(),
                    )
                }
            }
        }
        let v = build(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("reparse {}: {}", text, e));
        assert_eq!(back, v);
    });
}

#[test]
fn prop_http_parser_never_panics() {
    run_prop("http_garbage", 300, |g| {
        let len = g.usize_in(0, 200);
        let bytes: Vec<u8> = (0..len).map(|_| (g.u64() & 0xFF) as u8).collect();
        // Must classify without panicking.
        let _ = parse_request(&bytes);
        // Valid requests with injected noise: random truncation points.
        let valid = b"POST /v1/generate HTTP/1.1\r\ncontent-length: 4\r\n\r\nbody";
        let cut = g.usize_in(0, valid.len());
        match parse_request(&valid[..cut]) {
            ParseOutcome::Done(req, _) if cut == valid.len() => {
                assert_eq!(req.body, b"body");
            }
            ParseOutcome::Done(_, _) => panic!("premature Done at cut {}", cut),
            _ => {}
        }
    });
}

#[test]
fn prop_scheduler_conservation_under_concurrency() {
    use fastav::coordinator::{Priority, SchedulerQueue};
    use std::sync::Arc;

    run_prop("sched_conservation", 20, |g| {
        let q: Arc<SchedulerQueue<u64>> = Arc::new(SchedulerQueue::new(g.usize_in(1, 64)));
        let producers = g.usize_in(1, 4);
        let per = g.usize_in(1, 50);
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                let mut pushed = 0u64;
                for i in 0..per {
                    let prio = if i % 2 == 0 { Priority::High } else { Priority::Normal };
                    if q.try_push((p * 1000 + i) as u64, prio).is_ok() {
                        pushed += 1;
                    }
                }
                pushed
            }));
        }
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut popped = 0u64;
                while q.pop_blocking().is_some() {
                    popped += 1;
                }
                popped
            })
        };
        let pushed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        q.close();
        let popped = consumer.join().unwrap();
        assert_eq!(pushed, popped);
        let stats = q.stats();
        assert_eq!(stats.admitted, pushed);
        assert_eq!(stats.dequeued, popped);
        assert_eq!(stats.admitted + stats.rejected, (producers * per) as u64);
    });
}
