//! Tiered-KV spill properties, artifact-free:
//!
//! * demote→promote fidelity — an entry pushed through the RAM and disk
//!   spill tiers (serialize → spill → deserialize) comes back identical
//!   to a never-evicted control, across random pruning keep sets, COW
//!   forks, and compact epochs;
//! * pruner budgets — a run never processes more entries than its
//!   budget allows (byte overshoot bounded by one entry), and the
//!   checkpointed cursor resumes a walk exactly where it stopped;
//! * serving acceptance (mock engine through the real `ReplicaPool`) —
//!   with a device prefix budget holding 1 of 4 distinct warm prefixes,
//!   every evicted prefix re-request is served from the warm tier (zero
//!   full re-prefills after warmup) and the promoted streams are
//!   token-for-token identical to a never-evicted control pool;
//! * `flush_all_tiers` drains device + pending + RAM + disk and resets
//!   the pruner checkpoint, so the next request is a true cold miss.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::kvcache::{
    BlockPool, LayerCache, PrefixCache, PrefixEntry, PrefixLease, PruneBudget,
    PruneCursor, SerializedEntry, TierConfig, TierHit, TieredStore,
};
use fastav::metrics::Registry;
use fastav::model::{av_prefix_len, GenerateResult, StepEvent};
use fastav::policy::PruningSpec;
use fastav::serving::{PoolConfig, PrefixCharge, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::util::proptest::{run_prop, Gen};

// ----------------------------------------------------------- helpers

/// Unique disk-tier backing path per test (the store unlinks it on
/// drop, but concurrent tests must never share a file).
fn tier_path(tag: &str) -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let n = SEQ.fetch_add(1, Ordering::SeqCst);
    std::env::temp_dir().join(format!(
        "fastav_tiered_{}_{}_{}.tier",
        std::process::id(),
        tag,
        n
    ))
}

fn ram_only(ram_bytes: usize) -> TierConfig {
    TierConfig { ram_bytes, disk_path: None, disk_bytes: 0 }
}

fn disk_only(tag: &str, disk_bytes: usize) -> TierConfig {
    TierConfig { ram_bytes: 0, disk_path: Some(tier_path(tag)), disk_bytes }
}

fn mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x100_0000_01b3)
}

/// Order- and bit-exact fingerprint of everything a `PrefixEntry`
/// carries; two entries stream identically iff this matches.
fn checksum(e: &PrefixEntry) -> u64 {
    let mut h = mix(0xcbf2_9ce4_8422_2325, e.prefix_len as u64);
    for &f in &e.h_keep {
        h = mix(h, u64::from(f.to_bits()));
    }
    for &p in &e.keep_positions {
        h = mix(h, p as u64);
    }
    for set in [&e.full_layers, &e.keep_layers] {
        for c in set.iter() {
            h = mix(h, c.len() as u64);
            for i in 0..c.len() {
                h = mix(h, c.positions()[i] as u64);
                for head in 0..c.n_heads {
                    for &f in &c.k_row(head, i) {
                        h = mix(h, u64::from(f.to_bits()));
                    }
                    for &f in &c.v_row(head, i) {
                        h = mix(h, u64::from(f.to_bits()));
                    }
                }
            }
        }
    }
    h
}

/// Random entry exercising the shapes a real publish produces: a full
/// front-layer cache, a keep cache that is a COW fork compacted to a
/// random pruning keep set (epoch bump + shared blocks), and pooled
/// score / position side arrays.
fn random_entry(pool: &BlockPool, g: &mut Gen, salt: u32) -> PrefixEntry {
    let n_heads = g.usize_in(1, 2);
    let d_head = g.usize_in(2, 4);
    let rows = g.usize_in(1, 40);
    let w = n_heads * d_head;
    let mut full = LayerCache::new_in(pool.clone(), n_heads, d_head, rows.max(1));
    for i in 0..rows {
        let k: Vec<f32> = (0..w)
            .map(|e| (salt as f32) * 10.0 + (i as f32) + (e as f32) * 0.25)
            .collect();
        let v: Vec<f32> = k.iter().map(|x| -0.5 * x).collect();
        full.append(&k, &v, i as i32);
    }
    // Random keep set (the pruning spec's effect on the KV rows).
    let mut keep_idx: Vec<usize> = (0..rows).filter(|_| g.bool()).collect();
    if keep_idx.is_empty() {
        keep_idx.push(0);
    }
    let mut keep = full.clone(); // COW fork: shares full's blocks
    keep.compact(&keep_idx); // epoch bump + tail-block fork
    if g.bool() {
        // A second compact epoch on an already-compacted cache.
        let n = keep.len();
        keep.compact(&(0..n).step_by(2).collect::<Vec<_>>());
    }
    PrefixEntry {
        prefix_len: rows,
        full_layers: vec![full],
        keep_layers: vec![keep],
        h_keep: (0..g.usize_in(0, 16)).map(|i| (i as f32) * 0.125 - 1.0).collect(),
        keep_positions: keep_idx.iter().map(|&i| i as i32).collect(),
        bytes: 0,
    }
    .finalize()
}

/// Drive the pruner until a run completes within budget (no backlog).
fn prune_to_quiescence(tier: &TieredStore) {
    for _ in 0..1000 {
        if !tier.prune_run(PruneBudget::default()).exhausted {
            return;
        }
    }
    panic!("pruner never quiesced");
}

// ------------------------------------- demote→promote fidelity (store)

#[test]
fn prop_demoted_entries_promote_identically_from_ram_and_disk() {
    run_prop("tier_roundtrip", 20, |g: &mut Gen| {
        let pool = BlockPool::new();
        let entry = Arc::new(random_entry(&pool, g, 7));
        let want = checksum(&entry);
        let tokens: Vec<u32> = (0..g.usize_in(1, 6) as u32).collect();

        // RAM tier: serialize on demotion, deserialize on promotion.
        let ram = TieredStore::new(ram_only(1 << 20));
        ram.stage_demotion(9, tokens.clone(), Arc::clone(&entry));
        prune_to_quiescence(&ram);
        assert_eq!(ram.stats().ram_entries, 1);
        let (back, hit) = ram.promote(&pool, 9, &tokens).expect("ram promotion");
        assert_eq!(hit, TierHit::Ram);
        assert_eq!(checksum(&back), want, "RAM round-trip drifted");

        // Disk tier: full encode → file → decode round-trip.
        let disk = TieredStore::new(disk_only("prop", 1 << 20));
        disk.stage_demotion(9, tokens.clone(), Arc::clone(&entry));
        prune_to_quiescence(&disk);
        assert_eq!(disk.stats().disk_entries, 1);
        let (back, hit) = disk.promote(&pool, 9, &tokens).expect("disk promotion");
        assert_eq!(hit, TierHit::Disk);
        assert_eq!(checksum(&back), want, "disk round-trip drifted");

        // Promotion removed the spill copies; the device tier re-owns.
        assert!(ram.peek(9, &tokens).is_none());
        assert!(disk.peek(9, &tokens).is_none());
    });
}

// ------------------------------------------------- pruner work budgets

#[test]
fn prune_run_never_exceeds_entry_budget_and_cursor_resumes() {
    let pool = BlockPool::new();
    let tier = TieredStore::new(ram_only(1 << 20));
    let mut g = Gen::new(42);
    for i in 0..7u32 {
        tier.stage_demotion(1, vec![i], Arc::new(random_entry(&pool, &mut g, i)));
    }
    let budget = PruneBudget { max_entries: 3, max_bytes: usize::MAX };
    let r1 = tier.prune_run(budget);
    assert_eq!(r1.entries, 3, "run capped at its entry budget");
    assert!(r1.exhausted, "backlog remains");
    assert_eq!(tier.stats().cursor, PruneCursor { stage: 0, ram_seq: 0 });
    let r2 = tier.prune_run(budget);
    assert_eq!((r2.entries, r2.exhausted), (3, true));
    let r3 = tier.prune_run(budget);
    assert_eq!(r3.entries, 1, "resumed walk finishes the tail");
    assert!(!r3.exhausted);
    assert_eq!(tier.stats().cursor, PruneCursor::default(), "checkpoint reset");
    assert_eq!(tier.stats().ram_entries, 7);
    assert_eq!(tier.stats().prune_runs, 3);
}

#[test]
fn prune_run_byte_budget_overshoot_is_bounded_by_one_entry() {
    let pool = BlockPool::new();
    let tier = TieredStore::new(ram_only(1 << 20));
    let mut g = Gen::new(7);
    let mut max_entry = 0usize;
    for i in 0..6u32 {
        let e = Arc::new(random_entry(&pool, &mut g, i));
        // The pruner charges serialized payload bytes, so bound the
        // permitted overshoot by the largest serialized entry.
        let payload = SerializedEntry::from_entry(2, &[i], &e).payload_bytes();
        max_entry = max_entry.max(payload);
        tier.stage_demotion(2, vec![i], e);
    }
    let budget = PruneBudget { max_entries: usize::MAX, max_bytes: 1 };
    let mut runs = 0;
    loop {
        let r = tier.prune_run(budget);
        assert!(
            r.bytes <= budget.max_bytes + max_entry,
            "byte budget overshot by more than one entry: {} vs {}",
            r.bytes,
            budget.max_bytes + max_entry
        );
        runs += 1;
        if !r.exhausted {
            break;
        }
        assert!(runs < 100, "pruner never finished");
    }
    // max_bytes = 1 stops every run after its first entry.
    assert_eq!(runs, 6);
    assert_eq!(tier.stats().ram_entries, 6);
}

// ------------------------------------------- serving acceptance (mock)

/// Prefix tokens per request; the last `SUFFIX` tokens are the question.
const P: usize = 24;
const SUFFIX: usize = 4;
const EST_BYTES: usize = 1000;
const CFG: u64 = 11;

/// The exact entry the mock publishes for a prefix: deterministic KV
/// rows derived from the prefix tokens, a compacted COW-forked keep
/// layer, and pooled score rows — so the checksum (and therefore the
/// generated stream) depends on every byte the tier must preserve.
fn mock_entry(pool: &BlockPool, tokens: &[u32]) -> PrefixEntry {
    let (n_heads, d_head) = (2usize, 3usize);
    let w = n_heads * d_head;
    let mut full = LayerCache::new_in(pool.clone(), n_heads, d_head, tokens.len());
    for (i, &t) in tokens.iter().enumerate() {
        let k: Vec<f32> = (0..w)
            .map(|e| (t as f32) + (i as f32) * 0.5 + (e as f32) * 0.25)
            .collect();
        let v: Vec<f32> = k.iter().map(|x| -0.5 * x).collect();
        full.append(&k, &v, i as i32);
    }
    let keep_idx: Vec<usize> = (0..tokens.len()).step_by(2).collect();
    let mut keep = full.clone();
    keep.compact(&keep_idx);
    PrefixEntry {
        prefix_len: tokens.len(),
        full_layers: vec![full],
        keep_layers: vec![keep],
        h_keep: tokens.iter().map(|&t| (t as f32) * 0.125).collect(),
        keep_positions: keep_idx.iter().map(|&i| i as i32).collect(),
        bytes: 0,
    }
    .finalize()
}

/// Bytes of one mock entry (all samples share the shape, so one
/// measurement sizes the device budget to hold exactly one of them).
fn mock_entry_bytes() -> usize {
    let pool = BlockPool::new();
    let tokens: Vec<u32> = (0..P as u32).collect();
    mock_entry(&pool, &tokens).bytes
}

struct TMGen {
    front_left: usize,
    back_left: usize,
    produced: usize,
    total: usize,
    seed: u64,
    hit: bool,
    reused: usize,
    tokens: Vec<u32>,
    _lease: Option<PrefixLease>,
}

/// Mock engine whose generated tokens are a function of the *entry
/// contents* it resumed from: a promotion that corrupted even one KV
/// float, position, or score produces a visibly different stream.
struct TierMockEngine {
    cache: Option<Arc<PrefixCache>>,
    front_token_steps: Arc<AtomicUsize>,
}

impl ReplicaEngine for TierMockEngine {
    type Gen = TMGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<TMGen> {
        let k = req.prompt.len();
        let p = av_prefix_len(&req.segments).filter(|&p| p < k);
        let (mut front, mut hit, mut reused, mut lease) = (k, false, 0, None);
        let mut seed = 0u64;
        if let (Some(cache), Some(p)) = (&self.cache, p) {
            let tokens = &req.prompt[..p];
            if let Some(l) = cache.lookup_exact(CFG, tokens) {
                seed = checksum(l.entry());
                front = k - p;
                hit = true;
                reused = p;
                lease = Some(l);
            } else {
                let entry = mock_entry(cache.pool(), tokens);
                seed = checksum(&entry);
                cache.insert(CFG, tokens, entry);
            }
        }
        Ok(TMGen {
            front_left: front,
            back_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
            seed,
            hit,
            reused,
            tokens: Vec::new(),
            _lease: lease,
        })
    }

    fn step(&mut self, gen: &mut TMGen) -> anyhow::Result<StepEvent> {
        if gen.front_left > 0 {
            gen.front_left -= 1;
            self.front_token_steps.fetch_add(1, Ordering::SeqCst);
            return Ok(StepEvent::Prefilled { layer: 0 });
        }
        if gen.back_left > 0 {
            gen.back_left -= 1;
            return Ok(StepEvent::Prefilled { layer: 1 });
        }
        if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        let t = (mix(gen.seed, gen.produced as u64) & 0xFFFF) as u32;
        gen.produced += 1;
        gen.tokens.push(t);
        Ok(StepEvent::Token(t))
    }

    fn is_done(&self, gen: &TMGen) -> bool {
        gen.front_left == 0 && gen.back_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: TMGen) -> GenerateResult {
        GenerateResult {
            tokens: gen.tokens,
            prompt_len: P + SUFFIX,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: EST_BYTES,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: gen.hit,
            prefix_tokens_reused: gen.reused,
        }
    }

    fn kv_bytes(&self, _gen: &TMGen) -> usize {
        EST_BYTES
    }

    fn estimate_bytes(&self, _req: &GenRequest) -> usize {
        EST_BYTES
    }

    fn attach_prefix_cache(&mut self, cache: Arc<PrefixCache>, _replica: usize) {
        self.cache = Some(cache);
    }

    fn prefix_probe(&self, req: &GenRequest) -> Option<PrefixCharge> {
        let cache = self.cache.as_ref()?;
        let p = av_prefix_len(&req.segments).filter(|&p| p < req.prompt.len())?;
        cache
            .peek(CFG, &req.prompt[..p])
            .map(|(key, bytes)| PrefixCharge { key, bytes })
    }
}

fn tier_request(sample: u32, question: u32, max_gen: usize) -> GenRequest {
    let mut prompt = vec![1u32];
    let mut segments = vec![Segment::Ctrl];
    let mut frame_of = vec![-1i32];
    for i in 0..P - 1 {
        prompt.push(sample * 1000 + i as u32);
        segments.push(Segment::Vis);
        frame_of.push((i / 8) as i32);
    }
    for t in [3, 192 + question, 250 + question, 3] {
        prompt.push(t);
        segments.push(Segment::Text);
        frame_of.push(-1);
    }
    GenRequest {
        prompt,
        segments,
        frame_of,
        spec: PruningSpec::fastav(32, 4, 2, 20.0),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

fn tier_pool(device_budget: usize, tier: TierConfig, steps: Arc<AtomicUsize>) -> ReplicaPool {
    ReplicaPool::start_with_factory(
        PoolConfig {
            replicas: 1,
            queue_cap: 64,
            max_inflight: 4,
            prefix_cache_bytes: device_budget,
            tier_ram_bytes: tier.ram_bytes,
            tier_disk_path: tier.disk_path,
            tier_disk_bytes: tier.disk_bytes,
            tier_prune_interval: Duration::from_millis(1),
            ..Default::default()
        },
        Arc::new(Registry::default()),
        move |_replica| {
            Ok(TierMockEngine { cache: None, front_token_steps: Arc::clone(&steps) })
        },
    )
    .expect("mock pool starts")
}

fn drain_tokens(rx: std::sync::mpsc::Receiver<Event>) -> Vec<u32> {
    let mut tokens = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Event::Token(t)) => tokens.push(t),
            Ok(Event::Done(_)) => return tokens,
            Ok(Event::Error(e)) => panic!("request failed: {}", e),
            Err(e) => panic!("stream stalled: {}", e),
        }
    }
}

/// Acceptance: with a device budget holding 1 of 4 distinct warm
/// prefixes, every evicted-prefix re-request is a warm-tier hit — zero
/// full re-prefills after warmup — and the promoted streams are
/// token-for-token identical to a never-evicted control pool.
fn warm_tier_acceptance(tier: TierConfig) {
    const SAMPLES: u32 = 4;
    const PASSES: u32 = 3;
    let k = P + SUFFIX;

    let tiered_steps = Arc::new(AtomicUsize::new(0));
    let tiered = tier_pool(mock_entry_bytes(), tier, Arc::clone(&tiered_steps));
    let control_steps = Arc::new(AtomicUsize::new(0));
    let control = tier_pool(0, ram_only(0), Arc::clone(&control_steps));

    let mut streams: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
    for pass in 0..PASSES {
        for sample in 1..=SAMPLES {
            let req = || tier_request(sample, pass, 5);
            let (_, rx_t) = tiered.submit(req()).unwrap();
            let got = drain_tokens(rx_t);
            let (_, rx_c) = control.submit(req()).unwrap();
            streams.push((got, drain_tokens(rx_c)));
        }
        // Let the background pruner serialize the pass's demotions so
        // later passes promote from real RAM/disk records, not just the
        // pending queue.
        std::thread::sleep(Duration::from_millis(50));
    }

    for (i, (tiered_s, control_s)) in streams.iter().enumerate() {
        assert_eq!(tiered_s, control_s, "stream {} diverged from control", i);
    }
    // Warmup pass pays 4 full prefills; after that only text suffixes —
    // zero full re-prefills even though only 1 of 4 prefixes fits.
    let post = (SAMPLES * (PASSES - 1)) as usize;
    assert_eq!(
        tiered_steps.load(Ordering::SeqCst),
        SAMPLES as usize * k + post * SUFFIX,
        "a tier miss forced a full re-prefill"
    );
    let stats = tiered.prefix_stats();
    assert_eq!(stats.hits as usize, post, "every re-request must hit warm state");
    let t = tiered.tier_stats().expect("tier attached");
    // Round-robin over 4 prefixes with a 1-entry device budget: every
    // post-warmup hit is a demote→promote round-trip, and the steady
    // 50 ms idle gaps let the pruner serialize each pass's demotions.
    assert_eq!(
        (t.promotions_ram + t.promotions_disk) as usize,
        post,
        "hits were not served by tier promotions"
    );
    assert!(
        t.demotions_ram + t.demotions_disk > 0,
        "pruner never serialized a demotion"
    );
    assert_eq!(t.drops_ram + t.drops_disk, 0, "no entry may be dropped");
    assert!(control.tier_stats().is_none(), "control pool runs device-only");
    tiered.shutdown();
    control.shutdown();
}

#[test]
fn evicted_prefixes_promote_from_ram_tier_with_zero_reprefills() {
    warm_tier_acceptance(ram_only(8 << 20));
}

#[test]
fn evicted_prefixes_promote_from_disk_tier_with_zero_reprefills() {
    warm_tier_acceptance(disk_only("accept", 8 << 20));
}

#[test]
fn flush_all_tiers_drains_device_ram_and_disk_and_resets_checkpoint() {
    const SAMPLES: u32 = 4;
    let steps = Arc::new(AtomicUsize::new(0));
    let pool = tier_pool(
        mock_entry_bytes(),
        ram_only(8 << 20),
        Arc::clone(&steps),
    );
    for sample in 1..=SAMPLES {
        let (_, rx) = pool.submit(tier_request(sample, 0, 2)).unwrap();
        drain_tokens(rx);
    }
    std::thread::sleep(Duration::from_millis(50));

    let report = pool.flush_all_tiers();
    let tier = report.tier.expect("tier attached");
    assert_eq!(report.device_entries, 1, "device held exactly one entry");
    assert_eq!(
        tier.pending_entries + tier.ram_entries + tier.disk_entries,
        (SAMPLES - 1) as usize,
        "spill tiers held the evicted prefixes"
    );
    assert!(report.device_bytes > 0);
    assert!(tier.pending_bytes + tier.ram_bytes + tier.disk_bytes > 0);

    let st = pool.tier_stats().expect("tier attached");
    assert_eq!(
        (st.pending_entries, st.ram_entries, st.disk_entries),
        (0, 0, 0),
        "flush drained every tier"
    );
    assert_eq!(st.cursor, PruneCursor::default(), "pruner checkpoint reset");

    // Post-flush, a repeated request is a genuine cold miss again.
    let before = steps.load(Ordering::SeqCst);
    let (_, rx) = pool.submit(tier_request(1, 1, 2)).unwrap();
    drain_tokens(rx);
    assert_eq!(
        steps.load(Ordering::SeqCst) - before,
        P + SUFFIX,
        "flushed prefix must pay a full prefill"
    );
    pool.shutdown();
}
