//! Fault-domain supervision, pinned on the mock pool under the seeded
//! chaos harness: an injected engine panic poisons (not kills) the
//! replica, the supervisor respawns it, stranded requests redirect, and
//! the conservation ledger balances across every injected fault. No AOT
//! artifacts needed — everything here is deterministic and
//! toolchain-runnable.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastav::coordinator::{Coordinator, Event, GenRequest, Priority};
use fastav::http::{api::make_handler, request, Server};
use fastav::metrics::Registry;
use fastav::model::{GenerateResult, StepEvent};
use fastav::policy::{PolicyRegistry, PruningSpec};
use fastav::serving::{
    ChaosEngine, FaultKind, FaultPlan, FaultRule, FaultSite, FaultState, FaultWhen,
    PoolConfig, ReplicaEngine, ReplicaHealth, ReplicaPool, SubmitError,
};
use fastav::tokens::{Layout, Segment};
use fastav::util::json::Json;
use fastav::util::proptest::{run_prop, Gen};

// ------------------------------------------------------------- helpers

/// Chaos-injected panics are *expected* here: silence the default
/// panic-hook stderr spew for replica threads (quantum isolation
/// catches the unwind; the hook still runs first). Everything else —
/// including real assertion failures on test threads — prints as usual.
fn quiet_replica_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let on_replica = std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with("replica-"));
            if !on_replica {
                prev(info);
            }
        }));
    });
}

/// A generation that takes `prefill_left + max_gen` quanta; token
/// values are position-dependent (`base*100 + n`) so streams can be
/// compared byte-for-byte across runs.
struct MockGen {
    prefill_left: usize,
    produced: usize,
    total: usize,
    kv_bytes: usize,
    base: u32,
}

struct MockEngine {
    step_cost: Duration,
    prefill: usize,
}

impl MockEngine {
    fn gen_for(&self, req: &GenRequest) -> MockGen {
        MockGen {
            prefill_left: self.prefill,
            produced: 0,
            total: req.max_gen.max(1),
            kv_bytes: req.prompt.len() * 1000,
            base: req.prompt.first().copied().unwrap_or(0),
        }
    }
}

fn mock_result(gen: &MockGen) -> GenerateResult {
    GenerateResult {
        tokens: (1..=gen.produced).map(|n| gen.base * 100 + n as u32).collect(),
        prompt_len: 4,
        flops: Default::default(),
        relative_flops: 0.0,
        peak_kv_bytes: gen.kv_bytes,
        prefill_seconds: 0.0,
        decode_seconds: 0.0,
        decode_steps: gen.produced.saturating_sub(1),
        live_counts: Vec::new(),
        prefix_hit: false,
        prefix_tokens_reused: 0,
    }
}

impl ReplicaEngine for MockEngine {
    type Gen = MockGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<MockGen> {
        Ok(self.gen_for(req))
    }

    fn step(&mut self, gen: &mut MockGen) -> anyhow::Result<StepEvent> {
        if !self.step_cost.is_zero() {
            std::thread::sleep(self.step_cost);
        }
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return Ok(StepEvent::Prefilled { layer: self.prefill - gen.prefill_left });
            }
        }
        if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        gen.produced += 1;
        Ok(StepEvent::Token(gen.base * 100 + gen.produced as u32))
    }

    fn is_done(&self, gen: &MockGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: MockGen) -> GenerateResult {
        mock_result(&gen)
    }

    fn kv_bytes(&self, gen: &MockGen) -> usize {
        gen.kv_bytes
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        req.prompt.len() * 1000
    }
}

fn mock_request(base: u32, max_gen: usize) -> GenRequest {
    GenRequest {
        prompt: vec![base, 2, 3, 4],
        segments: vec![Segment::Ctrl, Segment::Vis, Segment::Aud, Segment::Text],
        frame_of: vec![-1, 0, -1, -1],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority: Priority::Normal,
        deadline: None,
        profile: None,
    }
}

/// Pool config tuned for tests: near-instant respawn backoff.
fn chaos_cfg(replicas: usize) -> PoolConfig {
    PoolConfig {
        replicas,
        queue_cap: 32,
        max_inflight: 2,
        restart_backoff: Duration::from_millis(1),
        restart_backoff_max: Duration::from_millis(4),
        circuit_restarts: 100,
        circuit_window: Duration::from_secs(60),
        ..Default::default()
    }
}

/// Wait (bounded) for the pool to reach a quiescent, conserved state.
fn settled_stats(pool: &ReplicaPool) -> fastav::serving::PoolStats {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if (s.conserved() && s.in_flight == 0 && s.in_queue == 0)
            || t0.elapsed() > Duration::from_secs(10)
        {
            return s;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Drain a stream to its terminal event: the final token vector on
/// `Done`, the error message on `Error`. Panics on a stall — a request
/// that never reaches a terminal event is exactly the stranding bug
/// this suite exists to catch.
fn drain(rx: std::sync::mpsc::Receiver<Event>) -> Result<Vec<u32>, String> {
    loop {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Event::Token(_)) => {}
            Ok(Event::Done(res)) => return Ok(res.tokens),
            Ok(Event::Error(e)) => return Err(e),
            Err(e) => panic!("stream stalled (request stranded): {}", e),
        }
    }
}

// --------------------------------------------------------------- tests

/// The acceptance scenario: a seeded panic at the first step quantum
/// poisons the replica before any token streams. The supervisor
/// respawns the engine, the stranded requests redirect (here: back to
/// the same replica's still-open queue), and the *entire* workload
/// completes — zero stranded requests, balanced ledger,
/// `fastav_replica_restarts_total` > 0, and no admission-byte or
/// prefix-lease leak afterwards.
#[test]
fn injected_panic_respawns_replica_and_completes_workload() {
    quiet_replica_panics();
    let state = FaultState::new(FaultPlan {
        seed: 7,
        rules: vec![FaultRule {
            site: FaultSite::Step,
            when: FaultWhen::AtCall(1),
            kind: FaultKind::Panic,
            max_injections: 1,
        }],
    });
    let metrics = Arc::new(Registry::default());
    // KV budget fits exactly one 4000-byte request: the Defer/parked
    // path is exercised under the panic too.
    let cfg = PoolConfig { kv_budget_bytes: 4000, ..chaos_cfg(1) };
    let pool = {
        let state = Arc::clone(&state);
        ReplicaPool::start_with_factory(cfg, Arc::clone(&metrics), move |_r| {
            Ok(ChaosEngine::new(
                MockEngine { step_cost: Duration::from_micros(50), prefill: 2 },
                Arc::clone(&state),
            ))
        })
        .expect("pool starts")
    };

    let n = 5;
    let rxs: Vec<_> = (0..n)
        .map(|i| pool.submit(mock_request(i as u32 + 1, 3)).expect("accepted").1)
        .collect();
    for rx in rxs {
        let tokens = drain(rx).expect("request must survive the injected panic");
        assert_eq!(tokens.len(), 3);
    }

    let stats = settled_stats(&pool);
    assert!(stats.conserved(), "ledger out of balance: {:?}", stats);
    assert_eq!(stats.completed, n, "{:?}", stats);
    assert_eq!(stats.failed, 0, "{:?}", stats);
    assert!(stats.retried >= 1, "panic must have redirected a request: {:?}", stats);
    assert_eq!(state.panics(), 1, "exactly the seeded panic fired");
    assert!(metrics.counter("fastav_replica_restarts_total").get() >= 1);
    assert!(metrics.counter("fastav_replica_panics_total").get() >= 1);
    assert!(metrics.counter("fastav_requests_retried_total").get() >= 1);

    let status = pool.status();
    assert_eq!(status[0].health, ReplicaHealth::Healthy, "replica recovered");
    assert_eq!(status[0].restarts, 1);
    assert_eq!(status[0].panics, 1);

    // No admission-byte leak: the budget fits exactly one request, so a
    // fresh full-budget submission only completes if every stranded
    // generation released its charge.
    let (_, rx) = pool.submit(mock_request(9, 2)).expect("accepted");
    drain(rx).expect("post-chaos request must admit and complete");
    // No prefix-lease leak (the mock never takes leases; pinned anyway).
    assert_eq!(pool.prefix_stats().active_leases, 0);
}

/// Chaos storm: random bounded fault plans (transient errors and
/// panics at begin/step) over random pool shapes. Every accepted
/// request reaches exactly one terminal event and the ledger balances —
/// the invariant holds for *all* plans, not one golden schedule.
#[test]
fn prop_chaos_storm_every_request_reaches_one_terminal() {
    quiet_replica_panics();
    run_prop("chaos_storm", 8, |g: &mut Gen| {
        let mut rules = Vec::new();
        for _ in 0..g.usize_in(1, 3) {
            rules.push(FaultRule {
                site: *g.choose(&[FaultSite::Begin, FaultSite::Step]),
                when: FaultWhen::Every(g.usize_in(2, 7) as u64),
                kind: if g.bool() { FaultKind::Err } else { FaultKind::Panic },
                max_injections: g.usize_in(1, 4) as u64,
            });
        }
        let state = FaultState::new(FaultPlan { seed: g.u64(), rules });
        let cfg = PoolConfig {
            queue_cap: g.usize_in(4, 16),
            max_inflight: g.usize_in(1, 3),
            ..chaos_cfg(g.usize_in(1, 3))
        };
        let metrics = Arc::new(Registry::default());
        let pool = {
            let state = Arc::clone(&state);
            ReplicaPool::start_with_factory(cfg, Arc::clone(&metrics), move |_r| {
                Ok(ChaosEngine::new(
                    MockEngine { step_cost: Duration::from_micros(30), prefill: 2 },
                    Arc::clone(&state),
                ))
            })
            .expect("pool starts")
        };
        let n = g.usize_in(5, 25);
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        for i in 0..n {
            match pool.submit(mock_request(i as u32 + 1, g.usize_in(1, 5))) {
                Ok((_, rx)) => accepted.push(rx),
                Err(_) => rejected += 1,
            }
        }
        let mut terminal = 0u64;
        for rx in accepted {
            let _ = drain(rx); // Done and Error both count; a stall panics
            terminal += 1;
        }
        let stats = settled_stats(&pool);
        assert!(stats.conserved(), "not conserved: {:?}", stats);
        assert_eq!(stats.submitted, n as u64);
        assert_eq!(stats.rejected, rejected);
        assert_eq!(stats.terminal(), terminal);
        assert_eq!(pool.prefix_stats().active_leases, 0, "lease leak");
    });
}

// ---- poison-batch quarantine ----------------------------------------

/// Fused-batching mock: decode-ready from `begin` (no prefill), batch
/// width 8, and — when armed — a *transactional* failure whenever the
/// poison member (prompt base 99) is about to take its third token:
/// the fused dispatch errors before advancing anyone, and the solo
/// quarantine re-step of that member errors too. `begin` gates on `go`
/// so every submission is admitted before the first quantum (the first
/// pick is one fused batch of all four).
struct BatchMock {
    poison_armed: bool,
    go: Arc<AtomicBool>,
}

const POISON_BASE: u32 = 99;

impl BatchMock {
    fn poisoned_now(&self, gen: &MockGen) -> bool {
        self.poison_armed && gen.base == POISON_BASE && gen.produced == 2
    }
}

impl ReplicaEngine for BatchMock {
    type Gen = MockGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<MockGen> {
        while !self.go.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_micros(100));
        }
        Ok(MockGen {
            prefill_left: 0,
            produced: 0,
            total: req.max_gen.max(1),
            kv_bytes: req.prompt.len() * 1000,
            base: req.prompt.first().copied().unwrap_or(0),
        })
    }

    fn step(&mut self, gen: &mut MockGen) -> anyhow::Result<StepEvent> {
        if self.poisoned_now(gen) {
            anyhow::bail!("poison generation rejected by the kernel");
        }
        if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        gen.produced += 1;
        Ok(StepEvent::Token(gen.base * 100 + gen.produced as u32))
    }

    fn is_decoding(&self, gen: &MockGen) -> bool {
        !self.is_done(gen)
    }

    fn max_decode_batch(&self) -> usize {
        8
    }

    fn step_batch(&mut self, gens: &mut [&mut MockGen]) -> anyhow::Result<Vec<StepEvent>> {
        // Transactional: validate the whole batch before advancing any
        // member (the `step_batch` contract quarantine relies on).
        if gens.iter().any(|g| self.poisoned_now(g)) {
            anyhow::bail!("fused decode dispatch failed (bad member)");
        }
        let mut out = Vec::with_capacity(gens.len());
        for g in gens.iter_mut() {
            out.push(self.step(g)?);
        }
        Ok(out)
    }

    fn is_done(&self, gen: &MockGen) -> bool {
        gen.produced >= gen.total
    }

    fn finish(&mut self, gen: MockGen) -> GenerateResult {
        mock_result(&gen)
    }

    fn kv_bytes(&self, gen: &MockGen) -> usize {
        gen.kv_bytes
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        req.prompt.len() * 1000
    }
}

/// Run four requests (bases `1, 2, 3, 99`) through a one-replica fused
/// pool; returns each stream's terminal, by base.
fn batch_run(poison_armed: bool) -> (Vec<(u32, Result<Vec<u32>, String>)>, Arc<Registry>) {
    let metrics = Arc::new(Registry::default());
    let outcomes = {
        let metrics = Arc::clone(&metrics);
        let go = Arc::new(AtomicBool::new(false));
        let pool = ReplicaPool::start_with_factory(
            PoolConfig { max_inflight: 4, ..chaos_cfg(1) },
            metrics,
            {
                let go = Arc::clone(&go);
                move |_r| Ok(BatchMock { poison_armed, go: Arc::clone(&go) })
            },
        )
        .expect("pool starts");
        let rxs: Vec<_> = [1u32, 2, 3, POISON_BASE]
            .iter()
            .map(|&b| (b, pool.submit(mock_request(b, 8)).expect("accepted").1))
            .collect();
        go.store(true, Ordering::SeqCst);
        let outcomes: Vec<_> = rxs.into_iter().map(|(b, rx)| (b, drain(rx))).collect();
        let stats = settled_stats(&pool);
        assert!(stats.conserved(), "{:?}", stats);
        assert_eq!(pool.status()[0].restarts, 0, "quarantine must not respawn");
        outcomes
    };
    (outcomes, metrics)
}

/// A poison member inside a fused decode batch fails alone; its three
/// innocent batchmates complete with token streams byte-identical to a
/// fault-free control run, and the engine is never respawned.
#[test]
fn poison_batch_quarantine_fails_only_the_poison_member() {
    quiet_replica_panics();
    let (chaos, metrics) = batch_run(true);
    let (control, _) = batch_run(false);

    let failed: Vec<u32> =
        chaos.iter().filter(|(_, r)| r.is_err()).map(|(b, _)| *b).collect();
    assert_eq!(failed, vec![POISON_BASE], "exactly the poison member fails");
    let err = chaos.iter().find(|(b, _)| *b == POISON_BASE).unwrap().1.clone();
    assert!(
        err.unwrap_err().contains("poison generation"),
        "failure must carry the attributed engine error"
    );
    for (base, result) in &chaos {
        if *base == POISON_BASE {
            continue;
        }
        let mine = result.as_ref().expect("innocent batchmate completes");
        let control_tokens = control
            .iter()
            .find(|(b, _)| b == base)
            .and_then(|(_, r)| r.as_ref().ok())
            .expect("control run completes everything");
        assert_eq!(
            mine, control_tokens,
            "batchmate {} diverged from the fault-free run",
            base
        );
    }
    assert!(
        metrics.counter("fastav_requests_quarantined_total").get() >= 1,
        "quarantine path must have engaged"
    );
    assert_eq!(metrics.counter("fastav_replica_restarts_total").get(), 0);
}

// ---- circuit breaker / readiness ------------------------------------

fn test_registry() -> Arc<PolicyRegistry> {
    let calib = fastav::calibration::Calibration {
        model: "tiny".into(),
        samples: 8,
        threshold: 0.01,
        vis_cutoff: 5,
        keep_audio: 2,
        keep_frames: 0,
        budget: 6,
        profile: Vec::new(),
    };
    Arc::new(PolicyRegistry::builtin(&calib, 20.0))
}

fn layout() -> Layout {
    Layout { frames: 2, vis_per_frame: 4, aud_len: 6, aud_per_frame: 3, interleaved: false }
}

/// Unrecoverable replicas trip the circuit breaker into `Dead`; with
/// every replica dead, `submit` returns `SubmitError::Closed`
/// immediately (never hangs) and `GET /v1/health` flips from
/// `200 "ok"` to `503 "unavailable"`.
#[test]
fn all_replicas_dead_rejects_submits_and_reports_503() {
    quiet_replica_panics();
    let state = FaultState::new(FaultPlan {
        seed: 3,
        rules: vec![FaultRule {
            site: FaultSite::Begin,
            when: FaultWhen::Every(1),
            kind: FaultKind::Panic,
            max_injections: 0, // unlimited: the engine never recovers
        }],
    });
    let cfg = PoolConfig { circuit_restarts: 1, ..chaos_cfg(2) };
    let metrics = Arc::new(Registry::default());
    let pool = {
        let state = Arc::clone(&state);
        ReplicaPool::start_with_factory(cfg, Arc::clone(&metrics), move |_r| {
            Ok(ChaosEngine::new(
                MockEngine { step_cost: Duration::ZERO, prefill: 1 },
                Arc::clone(&state),
            ))
        })
        .expect("pool starts")
    };
    let coord = Arc::new(Coordinator::from_pool(pool));
    let handler = make_handler(Arc::clone(&coord), layout(), test_registry(), 3, 1);
    let server = Server::bind("127.0.0.1:0", 1, handler).unwrap();
    let addr = server.local_addr().to_string();
    let stop = server.shutdown_handle();
    let http = std::thread::spawn(move || server.serve());

    // Fresh pool: both replicas healthy, readiness is 200 "ok".
    let (code, body) = request(&addr, "GET", "/v1/health", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("status").as_str(), Some("ok"));
    assert_eq!(j.get("healthy").as_usize(), Some(2));

    // Feed the pool until every begin-panic has tripped both breakers.
    let t0 = Instant::now();
    while !coord.all_dead() {
        assert!(t0.elapsed() < Duration::from_secs(10), "breakers never tripped");
        match coord.submit_with_id(mock_request(1, 2)) {
            Ok((_, rx)) => {
                let _ = drain(rx); // must reach a terminal event regardless
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }

    // Dead pool: routing is closed, not hanging.
    match coord.submit_with_id(mock_request(1, 2)) {
        Err(SubmitError::Closed(_)) => {}
        Ok(_) => panic!("submit accepted by an all-dead pool"),
        Err(e) => panic!("expected Closed, got {:?}", e),
    }
    assert_eq!(coord.healthy_count(), 0);
    let stats = coord.pool_stats();
    assert!(stats.conserved(), "{:?}", stats);

    // Readiness flips to 503 "unavailable" — and only now.
    let (code, body) = request(&addr, "GET", "/v1/health", b"").unwrap();
    assert_eq!(code, 503);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("status").as_str(), Some("unavailable"));
    assert_eq!(j.get("dead").as_usize(), Some(2));

    // `/v1/pool` carries the supervision census + per-replica health.
    let (code, body) = request(&addr, "GET", "/v1/pool", b"").unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("supervision").get("dead").as_usize(), Some(2));
    assert!(j.get("supervision").get("panics_total").as_f64().unwrap() >= 2.0);
    for r in j.get("replicas").as_arr().unwrap() {
        assert_eq!(r.get("health").as_str(), Some("dead"));
    }

    stop.store(true, Ordering::SeqCst);
    let _ = http.join();
}

// ---- client disconnect ----------------------------------------------

/// Dropping the event receiver mid-stream sets the request's cancel
/// flag within one quantum: the disconnected client stops burning
/// engine steps instead of decoding to its generation cap.
#[test]
fn client_disconnect_cancels_within_a_step() {
    quiet_replica_panics();
    let metrics = Arc::new(Registry::default());
    let pool = ReplicaPool::start_with_factory(
        chaos_cfg(1),
        Arc::clone(&metrics),
        |_r| Ok(MockEngine { step_cost: Duration::from_micros(100), prefill: 1 }),
    )
    .expect("pool starts");
    let (_, rx) = pool.submit(mock_request(1, 50_000)).expect("accepted");
    // Wait for the stream to start, then walk away.
    match rx.recv_timeout(Duration::from_secs(10)).expect("first token") {
        Event::Token(_) => {}
        other => panic!("expected a token first, got {:?}", other),
    }
    drop(rx);
    let stats = settled_stats(&pool);
    assert_eq!(stats.canceled, 1, "{:?}", stats);
    assert!(stats.conserved(), "{:?}", stats);
    assert_eq!(metrics.counter("fastav_client_disconnects_total").get(), 1);
}
