//! Spec-equivalence and spec-compatibility properties of the policy
//! redesign, run against mock engines so no AOT artifacts are needed
//! (alongside `test_batching.rs`, whose harness style this follows):
//!
//! * **Spec equivalence:** a request built from a *profile-resolved*
//!   `PruningSpec` drives the pool to a token-for-token identical stream
//!   as the same request built the pre-refactor way (from the raw
//!   engine `PruningPlan`). The mock derives every token from the spec
//!   hash it saw at `begin`, so any drift between the two resolution
//!   paths — profile lookup vs `from_plan` — changes a stream.
//! * **Round-trip:** random-ish plans survive
//!   `PruningSpec::from_plan(..).to_plan()` unchanged, and JSON
//!   round-trips preserve the hash.
//! * **Classed batching:** fused decode batches never mix decode-prune
//!   specs with plain specs (the replica feeds
//!   `PruningSpec::decode_class` into the scheduler); streams and the
//!   conservation ledger stay identical to the unbatched run anyway.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::metrics::Registry;
use fastav::model::{GenerateResult, PruningPlan, StepEvent};
use fastav::policy::{PolicyRegistry, PruningSpec};
use fastav::pruning::{FineStrategy, GlobalStrategy};
use fastav::serving::{PoolConfig, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::util::proptest::{run_prop, Gen};

// ---------------------------------------------------------------- mock

/// Token stream derived from (spec hash, step): resolution drift between
/// two supposedly-equal specs changes every token.
fn spec_token(spec_hash: u64, step: usize) -> u32 {
    let x = spec_hash
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(step as u64)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    (x >> 33) as u32 % 1000
}

struct SpecGen {
    spec_hash: u64,
    class: u64,
    prefill_left: usize,
    produced: usize,
    total: usize,
}

/// Mock engine that fuses decode batches and asserts every fused batch
/// is class-homogeneous (the spec-compatibility contract).
struct SpecMock {
    max_batch: usize,
    mixed_class_batches: Arc<AtomicUsize>,
}

impl SpecMock {
    fn advance(&self, gen: &mut SpecGen) -> StepEvent {
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return StepEvent::Prefilled { layer: 0 };
            }
        } else if gen.produced >= gen.total {
            return StepEvent::Done;
        }
        let tok = spec_token(gen.spec_hash, gen.produced);
        gen.produced += 1;
        StepEvent::Token(tok)
    }
}

impl ReplicaEngine for SpecMock {
    type Gen = SpecGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<SpecGen> {
        Ok(SpecGen {
            spec_hash: req.spec.spec_hash(),
            class: req.spec.decode_class(),
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
        })
    }

    fn step(&mut self, gen: &mut SpecGen) -> anyhow::Result<StepEvent> {
        Ok(self.advance(gen))
    }

    fn is_decoding(&self, gen: &SpecGen) -> bool {
        gen.prefill_left == 0 && gen.produced > 0 && gen.produced < gen.total
    }

    fn max_decode_batch(&self) -> usize {
        self.max_batch
    }

    fn step_batch(&mut self, gens: &mut [&mut SpecGen]) -> anyhow::Result<Vec<StepEvent>> {
        if gens.len() >= 2 && gens.iter().any(|g| g.class != gens[0].class) {
            self.mixed_class_batches.fetch_add(1, Ordering::SeqCst);
        }
        Ok(gens.iter_mut().map(|g| self.advance(g)).collect())
    }

    fn is_done(&self, gen: &SpecGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: SpecGen) -> GenerateResult {
        GenerateResult {
            tokens: (0..gen.produced).map(|s| spec_token(gen.spec_hash, s)).collect(),
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: 1000,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, _gen: &SpecGen) -> usize {
        1000
    }

    fn estimate_bytes(&self, _req: &GenRequest) -> usize {
        1000
    }
}

fn spec_request(spec: PruningSpec, max_gen: usize) -> GenRequest {
    GenRequest::with_spec(
        vec![1, 2, 3, 4],
        vec![Segment::Ctrl, Segment::Vis, Segment::Aud, Segment::Text],
        vec![-1, 0, -1, -1],
        spec,
        max_gen,
    )
}

struct Run {
    pool: ReplicaPool,
    mixed: Arc<AtomicUsize>,
}

fn spec_pool(max_inflight: usize, max_batch: usize) -> Run {
    let mixed = Arc::new(AtomicUsize::new(0));
    let m2 = Arc::clone(&mixed);
    let pool = ReplicaPool::start_with_factory(
        PoolConfig { replicas: 1, queue_cap: 64, max_inflight, ..Default::default() },
        Arc::new(Registry::default()),
        move |_r| Ok(SpecMock { max_batch, mixed_class_batches: Arc::clone(&m2) }),
    )
    .expect("mock pool starts");
    Run { pool, mixed }
}

fn streams(receivers: Vec<std::sync::mpsc::Receiver<Event>>) -> Vec<Vec<u32>> {
    receivers
        .into_iter()
        .map(|rx| {
            let mut toks = Vec::new();
            loop {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(Event::Token(t)) => toks.push(t),
                    Ok(Event::Done(res)) => {
                        assert_eq!(res.tokens, toks);
                        return toks;
                    }
                    Ok(Event::Error(e)) => panic!("request failed: {}", e),
                    Err(e) => panic!("stream stalled: {}", e),
                }
            }
        })
        .collect()
}

fn drive(specs: &[PruningSpec], max_gen: usize) -> Vec<Vec<u32>> {
    let run = spec_pool(specs.len().max(2), 8);
    let receivers: Vec<_> = specs
        .iter()
        .map(|s| run.pool.submit(spec_request(s.clone(), max_gen)).unwrap().1)
        .collect();
    streams(receivers)
}

fn calib() -> fastav::calibration::Calibration {
    fastav::calibration::Calibration {
        model: "mock".into(),
        samples: 8,
        threshold: 0.01,
        vis_cutoff: 6,
        keep_audio: 3,
        keep_frames: 0,
        budget: 9,
        profile: Vec::new(),
    }
}

// --------------------------------------------------------------- tests

/// The acceptance property: a `/v2/generate`-style request resolved
/// through the default profile streams token-for-token identically to
/// the pre-refactor path that carried the raw global plan.
#[test]
fn default_profile_equals_global_plan_path() {
    let calib = calib();
    let registry = PolicyRegistry::builtin(&calib, 20.0);
    // Pre-refactor: make_handler closed over `calib.plan(p)` and every
    // request carried that plan. Post-refactor: requests resolve the
    // registry's default profile.
    let pre_refactor = PruningSpec::from_plan(calib.plan(20.0)).unwrap();
    let via_profile = registry.default_spec().clone();
    assert_eq!(via_profile, pre_refactor);
    let a = drive(&[pre_refactor], 8);
    let b = drive(&[via_profile], 8);
    assert_eq!(a, b, "profile resolution must not change the stream");
    // And a JSON round-trip of the profile (what /v2 echoes back /
    // what an operator pastes into --policies) is still the same policy.
    let round =
        PruningSpec::from_json(&registry.default_spec().to_json()).unwrap();
    assert_eq!(drive(&[round], 8), a);
}

#[test]
fn prop_spec_roundtrip_preserves_plan_and_stream() {
    run_prop("spec_roundtrip", 20, |g: &mut Gen| {
        let mut plan = PruningPlan::vanilla();
        plan.global = match g.usize_in(0, 4) {
            0 => GlobalStrategy::None,
            1 => GlobalStrategy::FastAvPosition {
                vis_cutoff: g.usize_in(0, 50),
                keep_audio: g.usize_in(0, 8),
                keep_frames: g.usize_in(0, 4),
            },
            2 => GlobalStrategy::Random,
            3 => GlobalStrategy::Vtw,
            _ => GlobalStrategy::StreamingWindow {
                sink: g.usize_in(0, 8),
                recent: g.usize_in(0, 8),
            },
        };
        plan.global_budget = g.usize_in(0, 64);
        plan.fine = if g.usize_in(0, 1) == 0 {
            FineStrategy::None
        } else {
            FineStrategy::LowAttentive
        };
        if plan.fine != FineStrategy::None {
            plan.fine_percent = g.usize_in(0, 100) as f64;
            plan.fine_during_decode = g.usize_in(0, 1) == 1;
        }
        plan.min_keep_vis = g.usize_in(0, 4);
        plan.min_keep_aud = g.usize_in(0, 4);
        plan.seed = g.usize_in(0, 1000) as u64;
        let spec = PruningSpec::from_plan(plan.clone()).expect("generated plan valid");
        assert_eq!(spec.to_plan(), plan, "from_plan/to_plan round-trip");
        let json_round = PruningSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(json_round, spec, "JSON round-trip");
        assert_eq!(json_round.spec_hash(), spec.spec_hash());
    });
}

#[test]
fn fused_batches_never_mix_decode_classes() {
    // 3 plain requests + 3 decode-pruning requests in one replica: the
    // classed scheduler must keep every fused batch class-homogeneous.
    let mut decode_plan = PruningPlan::fastav(32, 4, 2, 25.0);
    decode_plan.fine_during_decode = true;
    let decode_spec = PruningSpec::from_plan(decode_plan).unwrap();
    let plain_spec = PruningSpec::fastav(32, 4, 2, 25.0);
    assert_ne!(decode_spec.decode_class(), plain_spec.decode_class());

    let run = spec_pool(6, 8);
    let mut receivers = Vec::new();
    for i in 0..6 {
        let spec = if i % 2 == 0 { plain_spec.clone() } else { decode_spec.clone() };
        receivers.push(run.pool.submit(spec_request(spec, 24)).unwrap().1);
    }
    let streams = streams(receivers);
    for (i, s) in streams.iter().enumerate() {
        assert_eq!(s.len(), 24, "request {} stream truncated", i);
    }
    assert_eq!(
        run.mixed.load(Ordering::SeqCst),
        0,
        "a fused decode batch mixed incompatible spec classes"
    );
    // Equal-class requests still produced per-spec streams (hash-seeded).
    assert_eq!(streams[0], streams[2]);
    assert_ne!(streams[0], streams[1]);
}

/// Same-class mixed-profile traffic (no decode-time pruning) still fuses
/// and still streams exactly what the sequential path streams.
#[test]
fn mixed_profiles_without_decode_pruning_stream_identically_batched_or_not() {
    let specs: Vec<PruningSpec> = (0..6)
        .map(|i| {
            if i % 2 == 0 {
                PruningSpec::fastav(40, 4, 2, 20.0)
            } else {
                PruningSpec::off()
            }
        })
        .collect();
    let batched = drive(&specs, 16);
    // Sequential pool: force single-step decode.
    let run = spec_pool(6, 1);
    let receivers: Vec<_> = specs
        .iter()
        .map(|s| run.pool.submit(spec_request(s.clone(), 16)).unwrap().1)
        .collect();
    let sequential = streams(receivers);
    assert_eq!(batched, sequential);
}
