//! Request-lifecycle tracing properties against the mock pool (no AOT
//! artifacts): trace conservation — every accepted submission, whatever
//! its outcome, yields exactly one well-nested trace — the exact
//! root-duration == `fastav_generate_seconds` identity under a
//! [`MockClock`], Chrome-export shape through a real pool trace, and
//! the sampling-off path recording nothing while streams still work.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastav::coordinator::{Event, GenRequest, Priority};
use fastav::metrics::{labeled, Registry};
use fastav::model::{GenerateResult, StepEvent};
use fastav::policy::PruningSpec;
use fastav::serving::{PoolConfig, ReplicaEngine, ReplicaPool};
use fastav::tokens::Segment;
use fastav::trace::{Clock, CompletedTrace, MockClock, Outcome};
use fastav::util::json::Json;

// ---------------------------------------------------------------- mock

struct MockGen {
    prefill_left: usize,
    produced: usize,
    total: usize,
    kv_bytes: usize,
}

/// Engine stand-in. When `tick` is set, every quantum of engine work
/// advances the shared [`MockClock`] by that many nanoseconds *inside a
/// traced segment*, so span durations are exact and the engine-internal
/// segment path (upload + per-shard dispatch) is exercised end-to-end.
struct MockEngine {
    step_cost: Duration,
    tick: Option<(Arc<MockClock>, u64)>,
}

impl MockEngine {
    /// One quantum of "engine work" on the mock clock, reported through
    /// the thread-local segment collector exactly like the real engine.
    fn burn(&self) {
        let Some((clock, d)) = &self.tick else { return };
        let t0 = fastav::trace::seg_begin();
        let s0 = clock.now_ns();
        clock.advance_ns(*d);
        fastav::trace::seg_end("upload", None, t0);
        fastav::trace::push_seg("dispatch", Some(0), s0, clock.now_ns());
    }
}

impl ReplicaEngine for MockEngine {
    type Gen = MockGen;

    fn begin(&mut self, req: &GenRequest) -> anyhow::Result<MockGen> {
        self.burn();
        Ok(MockGen {
            prefill_left: 2,
            produced: 0,
            total: req.max_gen.max(1),
            kv_bytes: req.prompt.len() * 1000,
        })
    }

    fn step(&mut self, gen: &mut MockGen) -> anyhow::Result<StepEvent> {
        if !self.step_cost.is_zero() {
            std::thread::sleep(self.step_cost);
        }
        self.burn();
        if gen.prefill_left > 0 {
            gen.prefill_left -= 1;
            if gen.prefill_left > 0 {
                return Ok(StepEvent::Prefilled { layer: 2 - gen.prefill_left });
            }
        }
        if gen.produced >= gen.total {
            return Ok(StepEvent::Done);
        }
        gen.produced += 1;
        Ok(StepEvent::Token(7))
    }

    fn is_decoding(&self, gen: &MockGen) -> bool {
        // Without this override every quantum is classified (and traced)
        // as prefill; the replica tags quanta from the same eligibility
        // test it batches with.
        gen.prefill_left == 0 && gen.produced > 0 && gen.produced < gen.total
    }

    fn is_done(&self, gen: &MockGen) -> bool {
        gen.prefill_left == 0 && gen.produced >= gen.total
    }

    fn finish(&mut self, gen: MockGen) -> GenerateResult {
        GenerateResult {
            tokens: vec![7; gen.produced],
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 0.0,
            peak_kv_bytes: gen.kv_bytes,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: gen.produced.saturating_sub(1),
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        }
    }

    fn kv_bytes(&self, gen: &MockGen) -> usize {
        gen.kv_bytes
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        req.prompt.len() * 1000
    }
}

fn mock_request(max_gen: usize, priority: Priority) -> GenRequest {
    GenRequest {
        prompt: vec![1, 2, 3, 4],
        segments: vec![Segment::Ctrl, Segment::Vis, Segment::Aud, Segment::Text],
        frame_of: vec![-1, 0, -1, -1],
        spec: PruningSpec::off(),
        max_gen,
        sampling: Default::default(),
        priority,
        deadline: None,
        profile: None,
    }
}

/// A traced pool on a [`MockClock`]: every submission sampled, engine
/// quanta tick the clock by `tick_ns`.
fn traced_pool(
    cfg: PoolConfig,
    metrics: Arc<Registry>,
    clock: Arc<MockClock>,
    step_cost: Duration,
    tick_ns: u64,
) -> ReplicaPool {
    let engine_clock = Arc::clone(&clock);
    ReplicaPool::start_with_factory_clocked(
        cfg,
        metrics,
        move |_replica| {
            Ok(MockEngine {
                step_cost,
                tick: Some((Arc::clone(&engine_clock), tick_ns)),
            })
        },
        clock as Arc<dyn Clock>,
    )
    .expect("traced mock pool starts")
}

fn settled_stats(pool: &ReplicaPool) -> fastav::serving::PoolStats {
    let t0 = Instant::now();
    loop {
        let s = pool.stats();
        if (s.conserved() && s.in_flight == 0 && s.in_queue == 0)
            || t0.elapsed() > Duration::from_secs(10)
        {
            return s;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn drain(rx: std::sync::mpsc::Receiver<Event>) -> Result<usize, String> {
    let mut tokens = 0;
    loop {
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Event::Token(_)) => tokens += 1,
            Ok(Event::Done(_)) => return Ok(tokens),
            Ok(Event::Error(e)) => return Err(e),
            Err(e) => panic!("stream stalled: {}", e),
        }
    }
}

/// Structural well-nestedness: `spans[0]` is the root, every other span
/// sits inside its parent's interval, no interval is inverted.
fn assert_well_nested(t: &CompletedTrace) {
    assert_eq!(t.spans[0].name, "request");
    for (i, s) in t.spans.iter().enumerate() {
        assert!(s.start_ns <= s.end_ns, "span {} inverted", s.name);
        match s.parent {
            None => assert_eq!(i, 0, "only the root may be parentless"),
            Some(p) => {
                let p = &t.spans[p as usize];
                assert!(
                    p.start_ns <= s.start_ns && s.end_ns <= p.end_ns,
                    "span {} [{}, {}] escapes parent {} [{}, {}] (trace {})",
                    s.name,
                    s.start_ns,
                    s.end_ns,
                    p.name,
                    p.start_ns,
                    p.end_ns,
                    t.id
                );
            }
        }
    }
}

/// Per-track laminarity: any two spans sharing a track are either
/// disjoint or one contains the other — a track never shows two
/// half-overlapping intervals (what makes the Chrome/Perfetto lanes
/// render without artifacts).
fn assert_laminar_per_track(t: &CompletedTrace) {
    for (i, a) in t.spans.iter().enumerate() {
        for b in t.spans.iter().skip(i + 1) {
            if a.track != b.track {
                continue;
            }
            let disjoint = a.end_ns <= b.start_ns || b.end_ns <= a.start_ns;
            let nested = (a.start_ns <= b.start_ns && b.end_ns <= a.end_ns)
                || (b.start_ns <= a.start_ns && a.end_ns <= b.end_ns);
            assert!(
                disjoint || nested,
                "track {} spans {} [{}, {}] and {} [{}, {}] half-overlap (trace {})",
                a.track,
                a.name,
                a.start_ns,
                a.end_ns,
                b.name,
                b.start_ns,
                b.end_ns,
                t.id
            );
        }
    }
}

// --------------------------------------------------------------- tests

#[test]
fn sampling_off_records_nothing_and_streams_still_work() {
    // Default PoolConfig: trace_sample = 0.0.
    let pool = ReplicaPool::start_with_factory(
        PoolConfig { replicas: 1, queue_cap: 8, max_inflight: 2, ..Default::default() },
        Arc::new(Registry::default()),
        |_r| Ok(MockEngine { step_cost: Duration::from_micros(50), tick: None }),
    )
    .expect("pool starts");
    assert!(!pool.tracer().enabled());
    let rxs: Vec<_> = (0..3)
        .map(|_| pool.submit(mock_request(3, Priority::Normal)).unwrap())
        .collect();
    for (_, rx) in rxs {
        assert_eq!(drain(rx).expect("untraced request completes"), 3);
    }
    let stats = settled_stats(&pool);
    assert!(stats.conserved(), "{:?}", stats);
    assert_eq!(pool.tracer().total(), 0, "sampling off must record no traces");
}

#[test]
fn every_outcome_yields_exactly_one_well_nested_trace() {
    let clock = Arc::new(MockClock::new());
    let pool = traced_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 8,
            max_inflight: 1,
            kv_budget_bytes: 5000,
            trace_sample: 1.0,
            trace_ring: 64,
            ..Default::default()
        },
        Arc::new(Registry::default()),
        Arc::clone(&clock),
        Duration::from_micros(300),
        1_000,
    );
    let mut expected: Vec<(u64, Outcome)> = Vec::new();

    // Completed: a short request drained to Done.
    let (id, rx) = pool.submit(mock_request(2, Priority::Normal)).unwrap();
    assert_eq!(drain(rx).expect("completes"), 2);
    expected.push((id, Outcome::Completed));

    // Canceled: a long generation canceled mid-flight (or at pop — both
    // paths commit a Canceled trace).
    let (id, rx) = pool.submit(mock_request(64, Priority::Normal)).unwrap();
    pool.cancel(id);
    let err = drain(rx).expect_err("canceled request errors");
    assert!(err.contains("cancel"), "unexpected error: {}", err);
    expected.push((id, Outcome::Canceled));

    // Expired: the only slot is busy, so a 1 ms deadline can only lapse
    // in the queue.
    let (busy_id, busy) = pool.submit(mock_request(24, Priority::Normal)).unwrap();
    let mut doomed = mock_request(4, Priority::Normal);
    doomed.deadline = Some(Duration::from_millis(1));
    let (id, rx) = pool.submit(doomed).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let err = drain(rx).expect_err("deadline expires the queued request");
    assert!(err.contains("deadline"), "unexpected error: {}", err);
    expected.push((id, Outcome::Expired));
    drain(busy).expect("busy request completes");
    expected.push((busy_id, Outcome::Completed));

    // Failed: an estimate over the whole budget is rejected at admission.
    let mut big = mock_request(2, Priority::Normal);
    big.prompt = vec![1; 10]; // 10_000 estimated bytes > 5000 budget
    big.segments = vec![Segment::Text; 10];
    big.frame_of = vec![-1; 10];
    let (id, rx) = pool.submit(big).unwrap();
    let err = drain(rx).expect_err("oversize request fails");
    assert!(err.contains("budget"), "unexpected error: {}", err);
    expected.push((id, Outcome::Failed));

    let stats = settled_stats(&pool);
    assert!(stats.conserved(), "{:?}", stats);

    // Conservation: one trace per accepted submission, no extras.
    assert_eq!(pool.tracer().total(), expected.len());
    for (id, outcome) in &expected {
        let t = pool
            .tracer()
            .get(*id)
            .unwrap_or_else(|| panic!("request {} left no trace", id));
        assert_eq!(t.outcome, *outcome, "request {}", id);
        assert_eq!(t.id, *id);
        assert_well_nested(&t);
        assert_laminar_per_track(&t);
        // Every trace covers admission onward: the root spans all.
        assert!(t.spans.iter().all(|s| s.name != "request" || s.parent.is_none()));
    }

    // Completed traces carry the full lifecycle vocabulary, including
    // the engine-internal segments hung under their quanta.
    let done = pool.tracer().get(expected[0].0).unwrap();
    for phase in ["queue", "admit", "prefix_probe", "begin", "prefill_chunk", "decode_quantum"]
    {
        assert!(
            done.spans.iter().any(|s| s.name == phase),
            "completed trace missing {:?}: {:?}",
            phase,
            done.spans.iter().map(|s| s.name).collect::<Vec<_>>()
        );
    }
    assert_eq!(done.stats.tokens, 2);
    assert!(done.ttft_ns.is_some(), "completed trace must stamp TTFT");
    let quantum = done
        .spans
        .iter()
        .position(|s| s.name == "decode_quantum")
        .expect("decode quantum span");
    assert!(
        done.spans
            .iter()
            .any(|s| s.name == "upload" && s.parent == Some(quantum as u32)),
        "engine segment must hang under its quantum"
    );
    assert!(
        done.spans.iter().any(|s| s.name == "dispatch" && s.track == 1),
        "per-shard dispatch segment must land on the shard track"
    );
}

#[test]
fn root_duration_equals_generate_histogram_observation() {
    let clock = Arc::new(MockClock::new());
    let metrics = Arc::new(Registry::default());
    let pool = traced_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 8,
            max_inflight: 2,
            trace_sample: 1.0,
            trace_ring: 64,
            ..Default::default()
        },
        Arc::clone(&metrics),
        Arc::clone(&clock),
        Duration::from_micros(50),
        10_000, // 10 µs per quantum: histogram µs truncation is exact
    );
    let mut profiled = mock_request(3, Priority::Normal);
    profiled.profile = Some("balanced".to_string());
    let rxs = vec![
        pool.submit(mock_request(2, Priority::Normal)).unwrap(),
        pool.submit(profiled).unwrap(),
        pool.submit(mock_request(5, Priority::Normal)).unwrap(),
    ];
    let profiled_id = rxs[1].0;
    for (_, rx) in rxs {
        drain(rx).expect("completes");
    }
    settled_stats(&pool);

    let hist = metrics.histogram("fastav_generate_seconds");
    assert_eq!(hist.count(), 3);
    let traces = pool.tracer().recent(10);
    assert_eq!(traces.len(), 3);
    assert!(traces.iter().all(|t| t.outcome == Outcome::Completed));
    // The acceptance identity: each completed trace's root duration IS
    // the histogram observation (the replica loop observes commit's
    // return value), so the sums match to µs truncation exactly.
    let roots: f64 = traces.iter().map(|t| t.duration_seconds()).sum();
    assert!(
        (hist.sum_seconds() - roots).abs() < 5e-6,
        "histogram sum {} != Σ root durations {}",
        hist.sum_seconds(),
        roots
    );
    assert!(roots > 0.0, "mock clock ticks must produce nonzero durations");

    // Per-profile series: exactly the profiled request, same identity.
    let labeled_hist =
        metrics.histogram(&labeled("fastav_generate_seconds", "profile", "balanced"));
    assert_eq!(labeled_hist.count(), 1);
    let pt = pool.tracer().get(profiled_id).unwrap();
    assert_eq!(pt.profile.as_deref(), Some("balanced"));
    assert!((labeled_hist.sum_seconds() - pt.duration_seconds()).abs() < 2e-6);

    // TTFT fires once per request.
    assert_eq!(metrics.histogram("fastav_ttft_seconds").count(), 3);
}

#[test]
fn chrome_export_of_a_pool_trace_is_loadable() {
    let clock = Arc::new(MockClock::new());
    let pool = traced_pool(
        PoolConfig {
            replicas: 1,
            queue_cap: 4,
            max_inflight: 1,
            trace_sample: 1.0,
            trace_ring: 8,
            ..Default::default()
        },
        Arc::new(Registry::default()),
        Arc::clone(&clock),
        Duration::from_micros(50),
        1_000,
    );
    let (id, rx) = pool.submit(mock_request(2, Priority::Normal)).unwrap();
    drain(rx).expect("completes");
    let t = pool.tracer().get(id).expect("trace committed before Done");
    let v = Json::parse(&fastav::trace::export::chrome_json(&t).to_string())
        .expect("chrome export is valid JSON");
    let events = v.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());
    let mut saw_request = false;
    let mut saw_meta = false;
    for e in events {
        match e.get("ph").as_str() {
            Some("M") => {
                saw_meta = true;
                assert_eq!(e.get("name").as_str(), Some("thread_name"));
            }
            Some("X") => {
                assert!(e.get("ts").as_f64().is_some());
                assert!(e.get("dur").as_f64().unwrap() >= 0.0);
                assert!(e.get("pid").as_usize().is_some());
                assert!(e.get("tid").as_usize().is_some());
                assert_eq!(e.get("cat").as_str(), Some("fastav"));
                if e.get("name").as_str() == Some("request") {
                    saw_request = true;
                }
            }
            other => panic!("unexpected ph {:?}", other),
        }
    }
    assert!(saw_request, "root request span must export");
    assert!(saw_meta, "track metadata must export");
    // The engine's shard-0 dispatch segment lands on tid 1 ("shard 0").
    assert!(events
        .iter()
        .any(|e| e.get("name").as_str() == Some("dispatch")
            && e.get("tid").as_usize() == Some(1)));
}
