//! Minimal protobuf wire codec for the gRPC front door.
//!
//! The container policy forbids new dependencies, so instead of
//! `prost`/`tonic` this hand-rolls exactly the protobuf wire subset the
//! `fastav.v1.FastAV` service needs: varint (wire type 0), 64-bit fixed
//! (wire type 1, for `double`) and length-delimited (wire type 2)
//! fields. 32-bit fixed fields (wire type 5) are parsed and skipped.
//! Message schemas live in [`super::grpc`]; this module knows only the
//! wire format.

/// Append a base-128 varint.
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a varint at `*pos`, advancing it. `None` on truncation or a
/// varint longer than 10 bytes.
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v: u64 = 0;
    for i in 0..10 {
        let byte = *buf.get(*pos)?;
        *pos += 1;
        v |= u64::from(byte & 0x7f) << (7 * i);
        if byte & 0x80 == 0 {
            return Some(v);
        }
    }
    None
}

fn put_tag(buf: &mut Vec<u8>, field: u32, wire: u8) {
    put_varint(buf, (u64::from(field) << 3) | u64::from(wire));
}

/// Append a varint-typed field. Proto3 presence rules: zero values are
/// omitted, so callers that need "0 is meaningful" wrap in a submessage.
pub fn put_uint(buf: &mut Vec<u8>, field: u32, v: u64) {
    if v == 0 {
        return;
    }
    put_tag(buf, field, 0);
    put_varint(buf, v);
}

/// Append a bool field (omitted when false, proto3 default).
pub fn put_bool(buf: &mut Vec<u8>, field: u32, v: bool) {
    put_uint(buf, field, u64::from(v));
}

/// Append a `double` field (wire type 1, little-endian; omitted at 0).
pub fn put_double(buf: &mut Vec<u8>, field: u32, v: f64) {
    if v == 0.0 {
        return;
    }
    put_tag(buf, field, 1);
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a string field (omitted when empty, proto3 default).
pub fn put_str(buf: &mut Vec<u8>, field: u32, s: &str) {
    if s.is_empty() {
        return;
    }
    put_bytes(buf, field, s.as_bytes());
}

/// Append a length-delimited field (always emitted, even when empty —
/// used for submessages whose *presence* is the signal).
pub fn put_bytes(buf: &mut Vec<u8>, field: u32, b: &[u8]) {
    put_tag(buf, field, 2);
    put_varint(buf, b.len() as u64);
    buf.extend_from_slice(b);
}

/// Append `repeated uint32` in packed encoding (proto3 default).
pub fn put_packed_uints(buf: &mut Vec<u8>, field: u32, vals: &[u32]) {
    if vals.is_empty() {
        return;
    }
    let mut packed = Vec::with_capacity(vals.len() * 2);
    for &v in vals {
        put_varint(&mut packed, u64::from(v));
    }
    put_bytes(buf, field, &packed);
}

/// One decoded field of a message.
#[derive(Debug, Clone, PartialEq)]
pub enum FieldValue<'a> {
    Varint(u64),
    Fixed64(u64),
    Bytes(&'a [u8]),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Field<'a> {
    pub number: u32,
    pub value: FieldValue<'a>,
}

impl Field<'_> {
    pub fn as_uint(&self) -> Option<u64> {
        match self.value {
            FieldValue::Varint(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_double(&self) -> Option<f64> {
        match self.value {
            FieldValue::Fixed64(v) => Some(f64::from_bits(v)),
            _ => None,
        }
    }

    pub fn as_bytes(&self) -> Option<&[u8]> {
        match self.value {
            FieldValue::Bytes(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        std::str::from_utf8(self.as_bytes()?).ok()
    }
}

/// Decode a message into its fields. `None` on any wire-format error
/// (unknown wire type, truncated payload).
pub fn fields(buf: &[u8]) -> Option<Vec<Field<'_>>> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos < buf.len() {
        let tag = get_varint(buf, &mut pos)?;
        let number = u32::try_from(tag >> 3).ok()?;
        match tag & 0x7 {
            0 => {
                let v = get_varint(buf, &mut pos)?;
                out.push(Field { number, value: FieldValue::Varint(v) });
            }
            1 => {
                let end = pos.checked_add(8)?;
                let raw = buf.get(pos..end)?;
                pos = end;
                let v = u64::from_le_bytes(raw.try_into().ok()?);
                out.push(Field { number, value: FieldValue::Fixed64(v) });
            }
            2 => {
                let len = usize::try_from(get_varint(buf, &mut pos)?).ok()?;
                let end = pos.checked_add(len)?;
                let b = buf.get(pos..end)?;
                pos = end;
                out.push(Field { number, value: FieldValue::Bytes(b) });
            }
            5 => {
                // fixed32: skip (no field in our schemas uses it).
                pos = pos.checked_add(4)?;
                if pos > buf.len() {
                    return None;
                }
            }
            _ => return None,
        }
    }
    Some(out)
}

/// Decode a packed `repeated uint32` payload.
pub fn unpack_uints(b: &[u8]) -> Option<Vec<u32>> {
    let mut pos = 0usize;
    let mut out = Vec::new();
    while pos < b.len() {
        out.push(u32::try_from(get_varint(b, &mut pos)?).ok()?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_boundaries() {
        for v in [0u64, 1, 127, 128, 300, u64::from(u32::MAX), u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos), Some(v));
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn message_roundtrip_all_wire_types() {
        let mut buf = Vec::new();
        put_uint(&mut buf, 1, 42);
        put_str(&mut buf, 2, "hello");
        put_double(&mut buf, 3, 0.625);
        put_packed_uints(&mut buf, 4, &[7, 300, 0]);
        put_bool(&mut buf, 5, true);
        let fs = fields(&buf).unwrap();
        assert_eq!(fs.len(), 5);
        assert_eq!(fs[0].number, 1);
        assert_eq!(fs[0].as_uint(), Some(42));
        assert_eq!(fs[1].as_str(), Some("hello"));
        assert_eq!(fs[2].as_double(), Some(0.625));
        assert_eq!(unpack_uints(fs[3].as_bytes().unwrap()), Some(vec![7, 300, 0]));
        assert_eq!(fs[4].as_uint(), Some(1));
    }

    #[test]
    fn proto3_zero_defaults_are_omitted() {
        let mut buf = Vec::new();
        put_uint(&mut buf, 1, 0);
        put_str(&mut buf, 2, "");
        put_double(&mut buf, 3, 0.0);
        put_bool(&mut buf, 4, false);
        put_packed_uints(&mut buf, 5, &[]);
        assert!(buf.is_empty());
        // ...but an explicit empty submessage is still present.
        put_bytes(&mut buf, 6, &[]);
        let fs = fields(&buf).unwrap();
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].as_bytes(), Some(&[][..]));
    }

    #[test]
    fn truncated_and_bad_wire_types_rejected() {
        assert!(fields(&[0x08]).is_none()); // varint field, no value
        assert!(fields(&[0x0a, 0x05, 1, 2]).is_none()); // len 5, only 2 bytes
        assert!(fields(&[0x0b]).is_none()); // wire type 3 (group) unsupported
        assert!(fields(&[0x09, 1, 2, 3]).is_none()); // fixed64 truncated
    }
}
