//! Streaming delivery: per-request token channels from the replica loop
//! to the client (see `docs/STREAMING.md`).
//!
//! Every response used to buffer until `finish`, which hid the latency
//! win FastAV's pruning buys — time-to-first-token is the production
//! metric, and the begin/step/finish `Generation` state machine already
//! yields exactly one token per quantum. This module is the missing
//! transport: the replica loop pushes each decoded token (and the
//! terminal event) into a bounded per-request [`TokenChannel`]; the
//! coordinator hands the subscriber half back from
//! `Coordinator::submit_streaming`; the HTTP layer serves it as
//! `text/event-stream` (`POST /v2/generate` with `"stream": true`) and
//! the hand-rolled gRPC front door ([`grpc`]) serves the same contract
//! as unary + server-streaming RPCs.
//!
//! ## Backpressure = parking, never stalling
//!
//! The channel is **bounded** ([`TokenChannel::pair`]'s capacity = the
//! park threshold). A consumer that stops draining makes
//! [`StreamSender::ready`] report false; the replica loop then *parks*
//! the request — it skips decode quanta (its admission-held KV stays
//! charged) instead of blocking the quantum, so fused batchmates with
//! healthy consumers keep byte-identical token streams. The replica
//! checks `ready()` and delivers at most one token per generation per
//! quantum, so a send after a positive `ready()` never has to block;
//! the terminal event has its own dedicated slot outside the ring and
//! is *always* deliverable — retirement and KV release never wait for a
//! slow (or absent) consumer.
//!
//! ## Disconnect = cancel within one quantum
//!
//! Dropping the [`StreamReceiver`] (the HTTP writer drops it when the
//! socket write fails) closes the channel; the replica's next
//! `send_token` fails, which flips the request's cancellation flag —
//! exactly the buffered path's disconnect semantics, counted by the
//! same `fastav_client_disconnects_total`.

pub mod grpc;
pub mod http2;
pub mod pb;

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::Event;
use crate::model::GenerateResult;

/// The terminal event parked in the channel's dedicated slot.
#[derive(Debug)]
enum TerminalEvent {
    Done(Box<GenerateResult>),
    Error(String),
}

/// Shared state behind one per-request stream.
#[derive(Debug, Default)]
struct ChannelState {
    /// Undelivered tokens, oldest first.
    ring: VecDeque<u32>,
    /// Terminal slot: outside the ring capacity so `Done`/`Error` can
    /// always be delivered regardless of consumer drain state.
    terminal: Option<TerminalEvent>,
    /// The producing replica dropped its sender (pool shutdown without
    /// a terminal event — abnormal).
    sender_gone: bool,
    /// The consumer dropped its receiver (client disconnect).
    receiver_gone: bool,
}

/// A bounded single-producer/single-consumer token channel for one
/// request. The capacity bounds the *ring* of undelivered tokens (the
/// park threshold); the terminal event rides in its own slot.
#[derive(Debug)]
pub struct TokenChannel {
    cap: usize,
    state: Mutex<ChannelState>,
    /// Signaled on every push/terminal/close; the receiver waits on it.
    recv_cv: Condvar,
}

impl TokenChannel {
    /// Create a channel with `cap` (≥ 1) buffered tokens, returning the
    /// producer and consumer halves.
    pub fn pair(cap: usize) -> (StreamSender, StreamReceiver) {
        let chan = Arc::new(TokenChannel {
            cap: cap.max(1),
            state: Mutex::new(ChannelState::default()),
            recv_cv: Condvar::new(),
        });
        (
            StreamSender { chan: Arc::clone(&chan) },
            StreamReceiver { chan },
        )
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ChannelState> {
        // Plain data valid at every instruction boundary; a panicked
        // peer cannot have left it torn (same policy as `lock_clean`).
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// The consumer hung up: the token cannot be delivered and the request
/// should be canceled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Disconnected;

/// Producer half, held inside the replica's event sink.
#[derive(Debug)]
pub struct StreamSender {
    chan: Arc<TokenChannel>,
}

impl StreamSender {
    /// Whether the consumer can absorb another token: the ring is below
    /// capacity and the receiver is still attached. The replica loop
    /// treats `false` as "park this request for the quantum".
    pub fn ready(&self) -> bool {
        let s = self.chan.lock();
        !s.receiver_gone && s.ring.len() < self.chan.cap
    }

    /// Push one token. Never blocks: the replica checks [`Self::ready`]
    /// at quantum start and delivers at most one token per generation
    /// per quantum, so the ring can exceed `cap` by at most the ready
    /// overshoot of a single in-flight quantum — parking is a
    /// throughput valve, not a hard memory fence. Errs only when the
    /// receiver is gone (client disconnect).
    pub fn send_token(&self, t: u32) -> Result<(), Disconnected> {
        let mut s = self.chan.lock();
        if s.receiver_gone {
            return Err(Disconnected);
        }
        s.ring.push_back(t);
        self.chan.recv_cv.notify_one();
        Ok(())
    }

    /// Deliver the terminal result. Always succeeds (dedicated slot):
    /// retirement accounting must never depend on the consumer.
    pub fn send_done(&self, res: Box<GenerateResult>) {
        let mut s = self.chan.lock();
        if !s.receiver_gone {
            s.terminal = Some(TerminalEvent::Done(res));
        }
        self.chan.recv_cv.notify_one();
    }

    /// Deliver a terminal error (failed / canceled / expired).
    pub fn send_error(&self, msg: String) {
        let mut s = self.chan.lock();
        if !s.receiver_gone {
            s.terminal = Some(TerminalEvent::Error(msg));
        }
        self.chan.recv_cv.notify_one();
    }
}

impl Drop for StreamSender {
    fn drop(&mut self) {
        let mut s = self.chan.lock();
        s.sender_gone = true;
        self.chan.recv_cv.notify_one();
    }
}

/// One receive outcome. Tokens drain strictly before the terminal
/// event, so the consumer observes the exact emission order.
#[derive(Debug)]
pub enum StreamRecv {
    Token(u32),
    Done(Box<GenerateResult>),
    Error(String),
    /// Nothing arrived within the timeout; poll again.
    TimedOut,
    /// The producer vanished without a terminal event (pool torn down
    /// mid-request) — treat as an error upstream.
    SenderGone,
}

/// Consumer half, returned by `Coordinator::submit_streaming`. Dropping
/// it disconnects the stream (the replica cancels within one quantum).
#[derive(Debug)]
pub struct StreamReceiver {
    chan: Arc<TokenChannel>,
}

impl StreamReceiver {
    /// Wait up to `timeout` for the next event.
    pub fn recv(&self, timeout: Duration) -> StreamRecv {
        let mut s = self.chan.lock();
        loop {
            if let Some(t) = s.ring.pop_front() {
                return StreamRecv::Token(t);
            }
            if let Some(term) = s.terminal.take() {
                return match term {
                    TerminalEvent::Done(res) => StreamRecv::Done(res),
                    TerminalEvent::Error(e) => StreamRecv::Error(e),
                };
            }
            if s.sender_gone {
                return StreamRecv::SenderGone;
            }
            let (guard, wait) = self
                .chan
                .recv_cv
                .wait_timeout(s, timeout)
                .unwrap_or_else(|p| p.into_inner());
            s = guard;
            if wait.timed_out()
                && s.ring.is_empty()
                && s.terminal.is_none()
                && !s.sender_gone
            {
                return StreamRecv::TimedOut;
            }
        }
    }

    /// Tokens currently buffered and undelivered (observability/tests).
    pub fn pending(&self) -> usize {
        self.chan.lock().ring.len()
    }
}

impl Drop for StreamReceiver {
    fn drop(&mut self) {
        let mut s = self.chan.lock();
        s.receiver_gone = true;
        // Free buffered tokens immediately; the sender sees the
        // disconnect on its next send.
        s.ring.clear();
        s.terminal = None;
    }
}

/// Where a request's events go: the legacy unbounded buffered channel
/// (always ready — today's `submit` path, byte-unchanged), or a bounded
/// per-request token stream. The replica loop talks only to this enum,
/// so both paths share one delivery/retire/disconnect code path.
#[derive(Debug)]
pub enum EventSink {
    /// Unbounded mpsc to a buffering caller ([`crate::coordinator::Event`]).
    Buffered(Sender<Event>),
    /// Bounded per-request stream with park-based backpressure.
    Stream(StreamSender),
}

impl EventSink {
    /// Whether a token can be delivered this quantum without blocking.
    /// Buffered sinks are always ready (unbounded channel).
    pub fn ready(&self) -> bool {
        match self {
            EventSink::Buffered(_) => true,
            EventSink::Stream(s) => s.ready(),
        }
    }

    pub fn is_stream(&self) -> bool {
        matches!(self, EventSink::Stream(_))
    }

    /// Deliver one token; `Err` means the consumer is gone (the caller
    /// flips the request's cancel flag — client-disconnect semantics).
    pub fn send_token(&self, t: u32) -> Result<(), Disconnected> {
        match self {
            EventSink::Buffered(tx) => tx.send(Event::Token(t)).map_err(|_| Disconnected),
            EventSink::Stream(s) => s.send_token(t),
        }
    }

    /// Deliver the final result (never blocks; consumer may be gone).
    pub fn send_done(&self, res: Box<GenerateResult>) {
        match self {
            EventSink::Buffered(tx) => {
                let _ = tx.send(Event::Done(res));
            }
            EventSink::Stream(s) => s.send_done(res),
        }
    }

    /// Deliver a terminal error (never blocks; consumer may be gone).
    pub fn send_error(&self, msg: String) {
        match self {
            EventSink::Buffered(tx) => {
                let _ = tx.send(Event::Error(msg));
            }
            EventSink::Stream(s) => s.send_error(msg),
        }
    }
}

/// Pool-wide stream accounting (the `streams` block of `GET /v1/pool`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    /// Streaming requests submitted and not yet terminal.
    pub active: u64,
    /// Streams currently parked on a slow consumer (skipping quanta).
    pub parked: u64,
    /// Streams that reached any terminal state (done or error).
    pub completed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_result(tokens: Vec<u32>) -> Box<GenerateResult> {
        Box::new(GenerateResult {
            tokens,
            prompt_len: 4,
            flops: Default::default(),
            relative_flops: 1.0,
            peak_kv_bytes: 0,
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            decode_steps: 0,
            live_counts: Vec::new(),
            prefix_hit: false,
            prefix_tokens_reused: 0,
        })
    }

    #[test]
    fn tokens_then_terminal_in_order() {
        let (tx, rx) = TokenChannel::pair(8);
        tx.send_token(1).unwrap();
        tx.send_token(2).unwrap();
        tx.send_done(mock_result(vec![1, 2]));
        assert!(matches!(rx.recv(Duration::from_millis(10)), StreamRecv::Token(1)));
        assert!(matches!(rx.recv(Duration::from_millis(10)), StreamRecv::Token(2)));
        match rx.recv(Duration::from_millis(10)) {
            StreamRecv::Done(res) => assert_eq!(res.tokens, vec![1, 2]),
            other => panic!("expected Done, got {:?}", other),
        }
    }

    #[test]
    fn ready_reflects_capacity_and_drain() {
        let (tx, rx) = TokenChannel::pair(2);
        assert!(tx.ready());
        tx.send_token(7).unwrap();
        assert!(tx.ready());
        tx.send_token(8).unwrap();
        assert!(!tx.ready(), "ring at capacity parks the producer");
        assert!(matches!(rx.recv(Duration::from_millis(10)), StreamRecv::Token(7)));
        assert!(tx.ready(), "drain unparks");
    }

    #[test]
    fn terminal_always_deliverable_when_full() {
        let (tx, rx) = TokenChannel::pair(1);
        tx.send_token(5).unwrap();
        assert!(!tx.ready());
        // The terminal slot bypasses the full ring.
        tx.send_error("deadline exceeded".into());
        assert!(matches!(rx.recv(Duration::from_millis(10)), StreamRecv::Token(5)));
        match rx.recv(Duration::from_millis(10)) {
            StreamRecv::Error(e) => assert_eq!(e, "deadline exceeded"),
            other => panic!("expected Error, got {:?}", other),
        }
    }

    #[test]
    fn receiver_drop_disconnects_sender() {
        let (tx, rx) = TokenChannel::pair(4);
        tx.send_token(1).unwrap();
        drop(rx);
        assert!(!tx.ready());
        assert_eq!(tx.send_token(2), Err(Disconnected));
    }

    #[test]
    fn sender_drop_without_terminal_is_visible() {
        let (tx, rx) = TokenChannel::pair(4);
        tx.send_token(9).unwrap();
        drop(tx);
        assert!(matches!(rx.recv(Duration::from_millis(10)), StreamRecv::Token(9)));
        assert!(matches!(rx.recv(Duration::from_millis(10)), StreamRecv::SenderGone));
    }

    #[test]
    fn recv_times_out_when_idle() {
        let (_tx, rx) = TokenChannel::pair(4);
        assert!(matches!(rx.recv(Duration::from_millis(5)), StreamRecv::TimedOut));
    }

    #[test]
    fn buffered_sink_always_ready_and_forwards() {
        let (tx, rx) = std::sync::mpsc::channel();
        let sink = EventSink::Buffered(tx);
        assert!(sink.ready());
        assert!(!sink.is_stream());
        sink.send_token(3).unwrap();
        sink.send_done(mock_result(vec![3]));
        assert!(matches!(rx.recv().unwrap(), Event::Token(3)));
        assert!(matches!(rx.recv().unwrap(), Event::Done(_)));
    }

    #[test]
    fn buffered_sink_disconnect_on_dropped_receiver() {
        let (tx, rx) = std::sync::mpsc::channel::<Event>();
        drop(rx);
        let sink = EventSink::Buffered(tx);
        assert_eq!(sink.send_token(1), Err(Disconnected));
    }
}
