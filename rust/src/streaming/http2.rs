//! HTTP/2 cleartext (h2c) framing + HPACK subset for the gRPC front
//! door — enough of RFC 9113/7541 for prior-knowledge gRPC clients and
//! our own test client, with zero dependencies.
//!
//! Supported: the client connection preface, SETTINGS (+ack), HEADERS
//! with END_HEADERS in one frame, DATA, RST_STREAM, PING (+reply),
//! GOAWAY, WINDOW_UPDATE (parsed, flow control is not enforced — gRPC
//! messages here are tiny relative to the 64 KiB default window).
//! HPACK: static-table indexed fields and plain (non-Huffman) literals;
//! we *emit* only "literal without indexing — new name" so any
//! spec-compliant peer can decode us without a dynamic table.
//! Unsupported (GOAWAY'd): CONTINUATION, Huffman-coded literals,
//! dynamic-table references, PUSH_PROMISE, padding/priority flags.

use std::io::{self, Read, Write};

/// Client connection preface (RFC 9113 §3.4).
pub const PREFACE: &[u8] = b"PRI * HTTP/2.0\r\n\r\nSM\r\n\r\n";

pub const FRAME_DATA: u8 = 0x0;
pub const FRAME_HEADERS: u8 = 0x1;
pub const FRAME_RST_STREAM: u8 = 0x3;
pub const FRAME_SETTINGS: u8 = 0x4;
pub const FRAME_PING: u8 = 0x6;
pub const FRAME_GOAWAY: u8 = 0x7;
pub const FRAME_WINDOW_UPDATE: u8 = 0x8;

pub const FLAG_END_STREAM: u8 = 0x1;
pub const FLAG_ACK: u8 = 0x1;
pub const FLAG_END_HEADERS: u8 = 0x4;

/// Largest frame payload we accept (the RFC default max frame size).
pub const MAX_FRAME: usize = 16_384;

/// gRPC error codes we emit in `grpc-status` trailers.
pub const GRPC_OK: u64 = 0;
pub const GRPC_INVALID_ARGUMENT: u64 = 3;
pub const GRPC_RESOURCE_EXHAUSTED: u64 = 8;
pub const GRPC_INTERNAL: u64 = 13;
pub const GRPC_UNAVAILABLE: u64 = 14;
pub const GRPC_UNIMPLEMENTED: u64 = 12;

/// One HTTP/2 frame (header fields + payload).
#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: u8,
    pub flags: u8,
    pub stream: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn end_stream(&self) -> bool {
        self.flags & FLAG_END_STREAM != 0
    }

    pub fn ack(&self) -> bool {
        self.flags & FLAG_ACK != 0
    }
}

/// Serialize one frame (9-byte header + payload).
pub fn write_frame(
    w: &mut impl Write,
    kind: u8,
    flags: u8,
    stream: u32,
    payload: &[u8],
) -> io::Result<()> {
    let len = payload.len();
    debug_assert!(len <= MAX_FRAME);
    let mut head = [0u8; 9];
    head[0] = ((len >> 16) & 0xff) as u8;
    head[1] = ((len >> 8) & 0xff) as u8;
    head[2] = (len & 0xff) as u8;
    head[3] = kind;
    head[4] = flags;
    head[5..9].copy_from_slice(&(stream & 0x7fff_ffff).to_be_bytes());
    w.write_all(&head)?;
    w.write_all(payload)
}

/// Try to parse one complete frame from the front of `buf`, draining the
/// consumed bytes. `Ok(None)` = need more data; `Err` = protocol error.
pub fn parse_frame(buf: &mut Vec<u8>) -> io::Result<Option<Frame>> {
    if buf.len() < 9 {
        return Ok(None);
    }
    let len = ((buf[0] as usize) << 16) | ((buf[1] as usize) << 8) | buf[2] as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame exceeds max size"));
    }
    if buf.len() < 9 + len {
        return Ok(None);
    }
    let kind = buf[3];
    let flags = buf[4];
    let stream = u32::from_be_bytes([buf[5], buf[6], buf[7], buf[8]]) & 0x7fff_ffff;
    let payload = buf[9..9 + len].to_vec();
    buf.drain(..9 + len);
    Ok(Some(Frame { kind, flags, stream, payload }))
}

/// Read frames until `want` returns true for one, replying to PING and
/// ignoring SETTINGS/WINDOW_UPDATE along the way (client-side helper).
pub fn read_frame_until(
    r: &mut impl Read,
    w: &mut impl Write,
    buf: &mut Vec<u8>,
    mut want: impl FnMut(&Frame) -> bool,
) -> io::Result<Frame> {
    let mut chunk = [0u8; 4096];
    loop {
        while let Some(f) = parse_frame(buf)? {
            match f.kind {
                FRAME_SETTINGS if !f.ack() => {
                    write_frame(w, FRAME_SETTINGS, FLAG_ACK, 0, &[])?;
                }
                FRAME_PING if !f.ack() => {
                    write_frame(w, FRAME_PING, FLAG_ACK, 0, &f.payload)?;
                }
                FRAME_GOAWAY => {
                    return Err(io::Error::new(io::ErrorKind::ConnectionAborted, "GOAWAY"));
                }
                _ if want(&f) => return Ok(f),
                _ => {}
            }
        }
        let n = r.read(&mut chunk)?;
        if n == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed"));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

// ---------------------------------------------------------------------------
// HPACK subset (RFC 7541).

/// The HPACK static table (RFC 7541 appendix A), 1-indexed.
const STATIC_TABLE: &[(&str, &str)] = &[
    (":authority", ""),
    (":method", "GET"),
    (":method", "POST"),
    (":path", "/"),
    (":path", "/index.html"),
    (":scheme", "http"),
    (":scheme", "https"),
    (":status", "200"),
    (":status", "204"),
    (":status", "206"),
    (":status", "304"),
    (":status", "400"),
    (":status", "404"),
    (":status", "500"),
    ("accept-charset", ""),
    ("accept-encoding", "gzip, deflate"),
    ("accept-language", ""),
    ("accept-ranges", ""),
    ("accept", ""),
    ("access-control-allow-origin", ""),
    ("age", ""),
    ("allow", ""),
    ("authorization", ""),
    ("cache-control", ""),
    ("content-disposition", ""),
    ("content-encoding", ""),
    ("content-language", ""),
    ("content-length", ""),
    ("content-location", ""),
    ("content-range", ""),
    ("content-type", ""),
    ("cookie", ""),
    ("date", ""),
    ("etag", ""),
    ("expect", ""),
    ("expires", ""),
    ("from", ""),
    ("host", ""),
    ("if-match", ""),
    ("if-modified-since", ""),
    ("if-none-match", ""),
    ("if-range", ""),
    ("if-unmodified-since", ""),
    ("last-modified", ""),
    ("link", ""),
    ("location", ""),
    ("max-forwards", ""),
    ("proxy-authenticate", ""),
    ("proxy-authorization", ""),
    ("range", ""),
    ("referer", ""),
    ("refresh", ""),
    ("retry-after", ""),
    ("server", ""),
    ("set-cookie", ""),
    ("strict-transport-security", ""),
    ("transfer-encoding", ""),
    ("user-agent", ""),
    ("vary", ""),
    ("via", ""),
    ("www-authenticate", ""),
];

/// HPACK prefix-integer encode (RFC 7541 §5.1) with `prefix` bits and
/// the leading pattern `pattern` in the top bits.
fn put_int(buf: &mut Vec<u8>, pattern: u8, prefix: u8, mut v: usize) {
    let max = (1usize << prefix) - 1;
    if v < max {
        buf.push(pattern | v as u8);
        return;
    }
    buf.push(pattern | max as u8);
    v -= max;
    while v >= 128 {
        buf.push((v & 0x7f) as u8 | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

fn get_int(block: &[u8], pos: &mut usize, prefix: u8) -> Option<usize> {
    let max = (1usize << prefix) - 1;
    let first = *block.get(*pos)? as usize & max;
    *pos += 1;
    if first < max {
        return Some(first);
    }
    let mut v = max;
    let mut shift = 0u32;
    loop {
        let byte = *block.get(*pos)?;
        *pos += 1;
        v = v.checked_add(((byte & 0x7f) as usize).checked_shl(shift)?)?;
        if byte & 0x80 == 0 {
            return Some(v);
        }
        shift = shift.checked_add(7)?;
        if shift > 28 {
            return None;
        }
    }
}

fn put_hpack_str(buf: &mut Vec<u8>, s: &str) {
    // H bit clear: plain octets, never Huffman.
    put_int(buf, 0x00, 7, s.len());
    buf.extend_from_slice(s.as_bytes());
}

fn get_hpack_str(block: &[u8], pos: &mut usize) -> Option<String> {
    let huffman = *block.get(*pos)? & 0x80 != 0;
    let len = get_int(block, pos, 7)?;
    if huffman {
        // Deliberately unsupported — peers negotiate plain literals by
        // our never advertising Huffman; compliant encoders may still
        // send it, in which case the connection is GOAWAY'd.
        return None;
    }
    let end = pos.checked_add(len)?;
    let s = std::str::from_utf8(block.get(*pos..end)?).ok()?.to_string();
    *pos = end;
    Some(s)
}

/// Encode one header as "literal header field without indexing — new
/// name" (pattern `0000`), plain strings. Stateless: no dynamic table.
pub fn put_header(buf: &mut Vec<u8>, name: &str, value: &str) {
    buf.push(0x00);
    put_hpack_str(buf, name);
    put_hpack_str(buf, value);
}

/// Decode a header block. Handles static-table indexed fields and all
/// three literal forms (with-indexing literals are decoded but *not*
/// added to a dynamic table — a later index into that table fails,
/// which our stateless emitters never produce). `None` on Huffman
/// strings, dynamic-table references, or malformed input.
pub fn parse_headers(block: &[u8]) -> Option<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos < block.len() {
        let b = block[pos];
        if b & 0x80 != 0 {
            // Indexed header field.
            let idx = get_int(block, &mut pos, 7)?;
            let (n, v) = static_entry(idx)?;
            out.push((n.to_string(), v.to_string()));
        } else if b & 0xe0 == 0x20 {
            // Dynamic table size update: accept and ignore.
            let _ = get_int(block, &mut pos, 5)?;
        } else {
            // Literal: 01 = incremental indexing (6-bit name index),
            // 0000 = without indexing, 0001 = never indexed (4-bit).
            let name_prefix = if b & 0xc0 == 0x40 { 6 } else { 4 };
            let idx = get_int(block, &mut pos, name_prefix)?;
            let name = if idx == 0 {
                get_hpack_str(block, &mut pos)?
            } else {
                static_entry(idx)?.0.to_string()
            };
            let value = get_hpack_str(block, &mut pos)?;
            out.push((name, value));
        }
    }
    Some(out)
}

fn static_entry(idx: usize) -> Option<(&'static str, &'static str)> {
    STATIC_TABLE.get(idx.checked_sub(1)?).copied()
}

/// Find a header value (names are already lowercase on the wire).
pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FRAME_DATA, FLAG_END_STREAM, 3, b"hello").unwrap();
        let mut buf = wire.clone();
        let f = parse_frame(&mut buf).unwrap().unwrap();
        assert_eq!((f.kind, f.flags, f.stream), (FRAME_DATA, FLAG_END_STREAM, 3));
        assert_eq!(f.payload, b"hello");
        assert!(buf.is_empty());
    }

    #[test]
    fn partial_frame_waits_for_more() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FRAME_HEADERS, 0, 1, &[1, 2, 3, 4]).unwrap();
        let mut buf = wire[..7].to_vec();
        assert!(parse_frame(&mut buf).unwrap().is_none());
        buf.extend_from_slice(&wire[7..]);
        assert!(parse_frame(&mut buf).unwrap().is_some());
    }

    #[test]
    fn hpack_literal_roundtrip() {
        let mut block = Vec::new();
        put_header(&mut block, ":method", "POST");
        put_header(&mut block, ":path", "/fastav.v1.FastAV/Generate");
        put_header(&mut block, "content-type", "application/grpc");
        let hs = parse_headers(&block).unwrap();
        assert_eq!(header(&hs, ":method"), Some("POST"));
        assert_eq!(header(&hs, ":path"), Some("/fastav.v1.FastAV/Generate"));
        assert_eq!(header(&hs, "content-type"), Some("application/grpc"));
    }

    #[test]
    fn hpack_static_indexed_and_name_indexed() {
        // 0x83 = indexed field 3 (:method POST); literal with
        // incremental indexing using static name index 4 (:path).
        let mut block = vec![0x83];
        block.push(0x44); // 01 pattern, name index 4
        put_hpack_str(&mut block, "/x");
        let hs = parse_headers(&block).unwrap();
        assert_eq!(header(&hs, ":method"), Some("POST"));
        assert_eq!(header(&hs, ":path"), Some("/x"));
    }

    #[test]
    fn hpack_huffman_rejected() {
        // H bit set on the name string.
        let block = vec![0x00, 0x81, 0xff, 0x01, b'x'];
        assert!(parse_headers(&block).is_none());
    }

    #[test]
    fn hpack_long_int_boundary() {
        let mut block = Vec::new();
        let long = "v".repeat(300); // forces multi-byte length
        put_header(&mut block, "x-long", &long);
        let hs = parse_headers(&block).unwrap();
        assert_eq!(header(&hs, "x-long"), Some(long.as_str()));
    }
}
