//! gRPC front door: `fastav.v1.FastAV/Generate` (unary) and
//! `/GenerateStream` (server-streaming) over hand-rolled h2c
//! ([`super::http2`]) + protobuf ([`super::pb`]) — the same
//! policy-resolution and channel layer as the HTTP surface, no new
//! dependencies.
//!
//! ## Service contract (`docs/STREAMING.md` has the full schema)
//!
//! ```proto
//! service FastAV {
//!   rpc Generate(GenerateRequest) returns (GenerateResponse);
//!   rpc GenerateStream(GenerateRequest) returns (stream StreamChunk);
//! }
//! ```
//!
//! `GenerateStream` emits a `policy` chunk first (the resolved spec),
//! then one `token` chunk per decoded token as the replica produces it,
//! then a terminal `done` (the full `GenerateResponse`) or `error`
//! chunk, followed by `grpc-status` trailers. RST_STREAM from the
//! client (or a dead socket) cancels the request within one quantum.
//!
//! Scope: prior-knowledge h2c only (no upgrade, no TLS); one RPC is
//! served at a time per connection (concurrent streams on a single
//! connection are serialized — open one connection per in-flight RPC,
//! as the bundled client does).

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::http2::{self, Frame};
use super::pb;
use super::StreamRecv;
use crate::coordinator::{Coordinator, Event};
use crate::http::api::{assemble_request, ApiVersion, Assembled};
use crate::policy::PolicyRegistry;
use crate::serving::SubmitError;
use crate::tokens::Layout;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

pub const PATH_GENERATE: &str = "/fastav.v1.FastAV/Generate";
pub const PATH_GENERATE_STREAM: &str = "/fastav.v1.FastAV/GenerateStream";

pub const GRPC_CANCELLED: u64 = 1;

/// Everything the RPC handlers need to serve a request.
pub struct GrpcCtx {
    pub coord: Arc<Coordinator>,
    pub layout: Layout,
    pub registry: Arc<PolicyRegistry>,
    pub max_gen: usize,
    pub base_seed: u64,
}

// ---------------------------------------------------------------------------
// Message schemas (proto3 semantics; hand-encoded via `pb`).

#[derive(Debug, Default, Clone, PartialEq)]
pub struct GenerateRequestPb {
    pub dataset: String,   // 1 (empty = avqa)
    pub index: u64,        // 2
    pub profile: String,   // 3 (empty = registry default)
    pub max_gen: u32,      // 4 (0 = server default)
    pub question: String,  // 5 (empty = the sample's own question)
    pub high_priority: bool, // 6
    pub deadline_ms: u64,  // 7 (0 = none)
}

pub fn encode_generate_request(r: &GenerateRequestPb) -> Vec<u8> {
    let mut b = Vec::new();
    pb::put_str(&mut b, 1, &r.dataset);
    pb::put_uint(&mut b, 2, r.index);
    pb::put_str(&mut b, 3, &r.profile);
    pb::put_uint(&mut b, 4, u64::from(r.max_gen));
    pb::put_str(&mut b, 5, &r.question);
    pb::put_bool(&mut b, 6, r.high_priority);
    pb::put_uint(&mut b, 7, r.deadline_ms);
    b
}

pub fn decode_generate_request(buf: &[u8]) -> Option<GenerateRequestPb> {
    let mut r = GenerateRequestPb::default();
    for f in pb::fields(buf)? {
        match f.number {
            1 => r.dataset = f.as_str()?.to_string(),
            2 => r.index = f.as_uint()?,
            3 => r.profile = f.as_str()?.to_string(),
            4 => r.max_gen = u32::try_from(f.as_uint()?).ok()?,
            5 => r.question = f.as_str()?.to_string(),
            6 => r.high_priority = f.as_uint()? != 0,
            7 => r.deadline_ms = f.as_uint()?,
            _ => {}
        }
    }
    Some(r)
}

#[derive(Debug, Default, Clone, PartialEq)]
pub struct PolicyPb {
    pub request_id: u64,  // 1
    pub profile: String,  // 2
    pub spec_hash: String, // 3
    pub spec_json: String, // 4 (canonical spec, JSON-encoded)
}

fn encode_policy(p: &PolicyPb) -> Vec<u8> {
    let mut b = Vec::new();
    pb::put_uint(&mut b, 1, p.request_id);
    pb::put_str(&mut b, 2, &p.profile);
    pb::put_str(&mut b, 3, &p.spec_hash);
    pb::put_str(&mut b, 4, &p.spec_json);
    b
}

fn decode_policy(buf: &[u8]) -> Option<PolicyPb> {
    let mut p = PolicyPb::default();
    for f in pb::fields(buf)? {
        match f.number {
            1 => p.request_id = f.as_uint()?,
            2 => p.profile = f.as_str()?.to_string(),
            3 => p.spec_hash = f.as_str()?.to_string(),
            4 => p.spec_json = f.as_str()?.to_string(),
            _ => {}
        }
    }
    Some(p)
}

#[derive(Debug, Default, Clone, PartialEq)]
pub struct GenerateResponsePb {
    pub request_id: u64,          // 1
    pub tokens: Vec<u32>,         // 2 (packed)
    pub answer: String,           // 3
    pub expected: String,         // 4
    pub correct: bool,            // 5
    pub relative_flops: f64,      // 6
    pub subtask: String,          // 7
    pub policy: Option<PolicyPb>, // 8
    pub prefill_seconds: f64,     // 9
    pub decode_seconds: f64,      // 10
    pub peak_kv_bytes: u64,       // 11
    pub prefix_hit: bool,         // 12
    pub prefix_tokens_reused: u64, // 13
}

pub fn encode_generate_response(r: &GenerateResponsePb) -> Vec<u8> {
    let mut b = Vec::new();
    pb::put_uint(&mut b, 1, r.request_id);
    pb::put_packed_uints(&mut b, 2, &r.tokens);
    pb::put_str(&mut b, 3, &r.answer);
    pb::put_str(&mut b, 4, &r.expected);
    pb::put_bool(&mut b, 5, r.correct);
    pb::put_double(&mut b, 6, r.relative_flops);
    pb::put_str(&mut b, 7, &r.subtask);
    if let Some(p) = &r.policy {
        pb::put_bytes(&mut b, 8, &encode_policy(p));
    }
    pb::put_double(&mut b, 9, r.prefill_seconds);
    pb::put_double(&mut b, 10, r.decode_seconds);
    pb::put_uint(&mut b, 11, r.peak_kv_bytes);
    pb::put_bool(&mut b, 12, r.prefix_hit);
    pb::put_uint(&mut b, 13, r.prefix_tokens_reused);
    b
}

pub fn decode_generate_response(buf: &[u8]) -> Option<GenerateResponsePb> {
    let mut r = GenerateResponsePb::default();
    for f in pb::fields(buf)? {
        match f.number {
            1 => r.request_id = f.as_uint()?,
            2 => r.tokens = pb::unpack_uints(f.as_bytes()?)?,
            3 => r.answer = f.as_str()?.to_string(),
            4 => r.expected = f.as_str()?.to_string(),
            5 => r.correct = f.as_uint()? != 0,
            6 => r.relative_flops = f.as_double()?,
            7 => r.subtask = f.as_str()?.to_string(),
            8 => r.policy = Some(decode_policy(f.as_bytes()?)?),
            9 => r.prefill_seconds = f.as_double()?,
            10 => r.decode_seconds = f.as_double()?,
            11 => r.peak_kv_bytes = f.as_uint()?,
            12 => r.prefix_hit = f.as_uint()? != 0,
            13 => r.prefix_tokens_reused = f.as_uint()?,
            _ => {}
        }
    }
    Some(r)
}

/// One server-streaming chunk: exactly one of the variants is set
/// (token rides in a submessage so `token == 0` stays representable).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamChunkPb {
    Policy(PolicyPb),                 // 1
    Token { value: u32, index: u32 }, // 2 { value = 1, index = 2 }
    Done(GenerateResponsePb),         // 3
    Error(String),                    // 4
}

pub fn encode_stream_chunk(c: &StreamChunkPb) -> Vec<u8> {
    let mut b = Vec::new();
    match c {
        StreamChunkPb::Policy(p) => pb::put_bytes(&mut b, 1, &encode_policy(p)),
        StreamChunkPb::Token { value, index } => {
            let mut t = Vec::new();
            pb::put_uint(&mut t, 1, u64::from(*value));
            pb::put_uint(&mut t, 2, u64::from(*index));
            pb::put_bytes(&mut b, 2, &t);
        }
        StreamChunkPb::Done(r) => pb::put_bytes(&mut b, 3, &encode_generate_response(r)),
        StreamChunkPb::Error(e) => pb::put_str(&mut b, 4, e),
    }
    b
}

pub fn decode_stream_chunk(buf: &[u8]) -> Option<StreamChunkPb> {
    let fs = pb::fields(buf)?;
    let f = fs.first()?;
    match f.number {
        1 => Some(StreamChunkPb::Policy(decode_policy(f.as_bytes()?)?)),
        2 => {
            let mut value = 0u32;
            let mut index = 0u32;
            for tf in pb::fields(f.as_bytes()?)? {
                match tf.number {
                    1 => value = u32::try_from(tf.as_uint()?).ok()?,
                    2 => index = u32::try_from(tf.as_uint()?).ok()?,
                    _ => {}
                }
            }
            Some(StreamChunkPb::Token { value, index })
        }
        3 => Some(StreamChunkPb::Done(decode_generate_response(f.as_bytes()?)?)),
        4 => Some(StreamChunkPb::Error(f.as_str()?.to_string())),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Server.

/// The gRPC listener (mirrors `http::Server`'s accept/shutdown shape).
pub struct GrpcServer {
    listener: TcpListener,
    pool: ThreadPool,
    ctx: Arc<GrpcCtx>,
    shutdown: Arc<AtomicBool>,
}

impl GrpcServer {
    pub fn bind(addr: &str, workers: usize, ctx: GrpcCtx) -> io::Result<GrpcServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(GrpcServer {
            listener,
            pool: ThreadPool::new(workers.max(1)),
            ctx: Arc::new(ctx),
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound listener has an addr")
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Accept loop; returns when the shutdown handle flips.
    pub fn serve(&self) {
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let ctx = Arc::clone(&self.ctx);
                    let _ = stream.set_nonblocking(false);
                    self.pool.execute(move || {
                        let _ = handle_conn(stream, &ctx);
                    });
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => return,
            }
        }
    }
}

/// Connection state: the socket plus a reassembly buffer and a queue of
/// parsed-but-unhandled frames (filled by non-blocking polls during
/// streaming responses).
struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
    queue: VecDeque<Frame>,
}

impl Conn {
    /// Blocking: return the next frame.
    fn next_frame(&mut self) -> io::Result<Frame> {
        loop {
            if let Some(f) = self.queue.pop_front() {
                return Ok(f);
            }
            while let Some(f) = http2::parse_frame(&mut self.buf)? {
                self.queue.push_back(f);
            }
            if self.queue.is_empty() {
                let mut chunk = [0u8; 4096];
                let n = self.stream.read(&mut chunk)?;
                if n == 0 {
                    return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed"));
                }
                self.buf.extend_from_slice(&chunk[..n]);
            }
        }
    }

    /// Non-blocking: pull whatever frames have arrived into the queue.
    fn poll_frames(&mut self) -> io::Result<()> {
        self.stream.set_nonblocking(true)?;
        let mut chunk = [0u8; 4096];
        let res = loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => break Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed")),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break Ok(()),
                Err(e) => break Err(e),
            }
        };
        self.stream.set_nonblocking(false)?;
        res?;
        while let Some(f) = http2::parse_frame(&mut self.buf)? {
            self.queue.push_back(f);
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, ctx: &GrpcCtx) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut conn = Conn { stream, buf: Vec::new(), queue: VecDeque::new() };
    // Client connection preface, then our (empty) SETTINGS.
    let mut preface = vec![0u8; http2::PREFACE.len()];
    conn.stream.read_exact(&mut preface)?;
    if preface != http2::PREFACE {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad h2c preface"));
    }
    http2::write_frame(&mut conn.stream, http2::FRAME_SETTINGS, 0, 0, &[])?;

    // One in-flight request per connection: HEADERS then DATA until
    // END_STREAM, dispatch, repeat.
    let mut cur_stream = 0u32;
    let mut cur_headers: Vec<(String, String)> = Vec::new();
    let mut cur_data: Vec<u8> = Vec::new();
    loop {
        let f = conn.next_frame()?;
        match f.kind {
            http2::FRAME_SETTINGS if !f.ack() => {
                http2::write_frame(&mut conn.stream, http2::FRAME_SETTINGS, http2::FLAG_ACK, 0, &[])?;
            }
            http2::FRAME_PING if !f.ack() => {
                http2::write_frame(&mut conn.stream, http2::FRAME_PING, http2::FLAG_ACK, 0, &f.payload)?;
            }
            http2::FRAME_GOAWAY => return Ok(()),
            http2::FRAME_HEADERS => {
                if f.flags & http2::FLAG_END_HEADERS == 0 {
                    // CONTINUATION unsupported.
                    goaway(&mut conn.stream)?;
                    return Ok(());
                }
                let Some(hs) = http2::parse_headers(&f.payload) else {
                    goaway(&mut conn.stream)?;
                    return Ok(());
                };
                cur_stream = f.stream;
                cur_headers = hs;
                cur_data.clear();
                if f.end_stream() {
                    dispatch(&mut conn, ctx, cur_stream, &cur_headers, &cur_data)?;
                }
            }
            http2::FRAME_DATA if f.stream == cur_stream => {
                cur_data.extend_from_slice(&f.payload);
                if f.end_stream() {
                    dispatch(&mut conn, ctx, cur_stream, &cur_headers, &cur_data)?;
                }
            }
            http2::FRAME_RST_STREAM => {
                if f.stream == cur_stream {
                    cur_data.clear();
                    cur_stream = 0;
                }
            }
            _ => {} // WINDOW_UPDATE, stray DATA, SETTINGS ack: ignore.
        }
    }
}

fn goaway(w: &mut impl Write) -> io::Result<()> {
    // last-stream-id 0 + PROTOCOL_ERROR (0x1).
    let mut p = vec![0u8; 8];
    p[7] = 1;
    http2::write_frame(w, http2::FRAME_GOAWAY, 0, 0, &p)
}

/// Split one gRPC length-prefixed message stream into payloads.
fn split_grpc_messages(data: &[u8]) -> Option<Vec<&[u8]>> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos < data.len() {
        let compressed = *data.get(pos)?;
        if compressed != 0 {
            return None; // no compression support
        }
        let len = u32::from_be_bytes(data.get(pos + 1..pos + 5)?.try_into().ok()?) as usize;
        let end = pos.checked_add(5 + len)?;
        out.push(data.get(pos + 5..end)?);
        pos = end;
    }
    Some(out)
}

fn write_response_headers(w: &mut impl Write, stream: u32) -> io::Result<()> {
    let mut block = Vec::new();
    http2::put_header(&mut block, ":status", "200");
    http2::put_header(&mut block, "content-type", "application/grpc");
    http2::write_frame(w, http2::FRAME_HEADERS, http2::FLAG_END_HEADERS, stream, &block)
}

fn write_grpc_message(w: &mut impl Write, stream: u32, msg: &[u8]) -> io::Result<()> {
    let mut payload = Vec::with_capacity(5 + msg.len());
    payload.push(0u8);
    payload.extend_from_slice(&(msg.len() as u32).to_be_bytes());
    payload.extend_from_slice(msg);
    // Our messages are far below MAX_FRAME; split defensively anyway.
    for part in payload.chunks(http2::MAX_FRAME) {
        http2::write_frame(w, http2::FRAME_DATA, 0, stream, part)?;
    }
    Ok(())
}

fn write_trailers(w: &mut impl Write, stream: u32, status: u64, message: &str) -> io::Result<()> {
    let mut block = Vec::new();
    http2::put_header(&mut block, "grpc-status", &status.to_string());
    if !message.is_empty() {
        // Keep it header-safe; full percent-encoding is unnecessary for
        // our ASCII error strings.
        let msg: String = message
            .chars()
            .map(|c| if c == '\r' || c == '\n' { ' ' } else { c })
            .collect();
        http2::put_header(&mut block, "grpc-message", &msg);
    }
    http2::write_frame(
        w,
        http2::FRAME_HEADERS,
        http2::FLAG_END_HEADERS | http2::FLAG_END_STREAM,
        stream,
        &block,
    )
}

/// Trailers-only error response (headers + trailers, no messages).
fn fail(conn: &mut Conn, stream: u32, status: u64, message: &str) -> io::Result<()> {
    write_response_headers(&mut conn.stream, stream)?;
    write_trailers(&mut conn.stream, stream, status, message)
}

fn dispatch(
    conn: &mut Conn,
    ctx: &GrpcCtx,
    stream: u32,
    headers: &[(String, String)],
    data: &[u8],
) -> io::Result<()> {
    let path = http2::header(headers, ":path").unwrap_or("").to_string();
    if http2::header(headers, ":method") != Some("POST") {
        return fail(conn, stream, http2::GRPC_UNIMPLEMENTED, "POST required");
    }
    let Some(msgs) = split_grpc_messages(data) else {
        return fail(conn, stream, http2::GRPC_INVALID_ARGUMENT, "bad gRPC framing");
    };
    let Some(req) = msgs.first().and_then(|m| decode_generate_request(m)) else {
        return fail(conn, stream, http2::GRPC_INVALID_ARGUMENT, "bad GenerateRequest");
    };
    match path.as_str() {
        PATH_GENERATE => serve_unary(conn, ctx, stream, &req),
        PATH_GENERATE_STREAM => serve_streaming(conn, ctx, stream, &req),
        _ => fail(
            conn,
            stream,
            http2::GRPC_UNIMPLEMENTED,
            &format!("unknown method {}", path),
        ),
    }
}

/// Resolve the pb request through the shared HTTP assembly path (same
/// policy resolution, clamps, and per-profile accounting).
fn assemble(ctx: &GrpcCtx, req: &GenerateRequestPb) -> Result<Assembled, (u64, String)> {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if !req.dataset.is_empty() {
        fields.push(("dataset", Json::str(&req.dataset)));
    }
    fields.push(("index", Json::num(req.index as f64)));
    if !req.profile.is_empty() {
        fields.push(("profile", Json::str(&req.profile)));
    }
    if req.max_gen > 0 {
        fields.push(("max_gen", Json::num(f64::from(req.max_gen))));
    }
    if !req.question.is_empty() {
        fields.push(("question", Json::str(&req.question)));
    }
    if req.high_priority {
        fields.push(("priority", Json::str("high")));
    }
    if req.deadline_ms > 0 {
        fields.push(("deadline_ms", Json::num(req.deadline_ms as f64)));
    }
    let body = Json::obj(fields);
    assemble_request(
        &ctx.coord,
        &body,
        &ctx.layout,
        &ctx.registry,
        ctx.max_gen,
        ctx.base_seed,
        ApiVersion::V2,
    )
    .map_err(|resp| {
        (
            http2::GRPC_INVALID_ARGUMENT,
            String::from_utf8_lossy(&resp.body).to_string(),
        )
    })
}

fn policy_pb(id: u64, asm: &Assembled) -> PolicyPb {
    PolicyPb {
        request_id: id,
        profile: asm.profile.clone(),
        spec_hash: asm.spec.spec_hash_hex(),
        spec_json: asm.spec.to_json().to_string(),
    }
}

fn response_pb(id: u64, asm: &Assembled, res: &crate::model::GenerateResult) -> GenerateResponsePb {
    GenerateResponsePb {
        request_id: id,
        tokens: res.tokens.clone(),
        answer: crate::tokens::render_answer(&res.tokens),
        expected: crate::tokens::render_answer(&asm.sample.answer),
        correct: crate::eval::exact_match(&res.tokens, &asm.sample.answer),
        relative_flops: res.relative_flops,
        subtask: asm.sample.subtask.name().to_string(),
        policy: Some(policy_pb(id, asm)),
        prefill_seconds: res.prefill_seconds,
        decode_seconds: res.decode_seconds,
        peak_kv_bytes: res.peak_kv_bytes as u64,
        prefix_hit: res.prefix_hit,
        prefix_tokens_reused: res.prefix_tokens_reused as u64,
    }
}

fn map_submit_err(e: &SubmitError) -> (u64, &'static str) {
    match e {
        SubmitError::Full(_) => (http2::GRPC_RESOURCE_EXHAUSTED, "queue full"),
        SubmitError::Closed(_) => (http2::GRPC_UNAVAILABLE, "shutting down"),
    }
}

fn serve_unary(conn: &mut Conn, ctx: &GrpcCtx, stream: u32, req: &GenerateRequestPb) -> io::Result<()> {
    let asm = match assemble(ctx, req) {
        Ok(a) => a,
        Err((status, msg)) => return fail(conn, stream, status, &msg),
    };
    let (id, rx) = match ctx.coord.submit_with_id(asm.request.clone()) {
        Ok(ok) => ok,
        Err(e) => {
            let (status, msg) = map_submit_err(&e);
            return fail(conn, stream, status, msg);
        }
    };
    for ev in rx {
        match ev {
            Event::Token(_) => {}
            Event::Done(res) => {
                let msg = encode_generate_response(&response_pb(id, &asm, &res));
                write_response_headers(&mut conn.stream, stream)?;
                write_grpc_message(&mut conn.stream, stream, &msg)?;
                return write_trailers(&mut conn.stream, stream, http2::GRPC_OK, "");
            }
            Event::Error(e) => return fail(conn, stream, http2::GRPC_INTERNAL, &e),
        }
    }
    fail(conn, stream, http2::GRPC_UNAVAILABLE, "worker dropped the request")
}

fn serve_streaming(
    conn: &mut Conn,
    ctx: &GrpcCtx,
    stream: u32,
    req: &GenerateRequestPb,
) -> io::Result<()> {
    let asm = match assemble(ctx, req) {
        Ok(a) => a,
        Err((status, msg)) => return fail(conn, stream, status, &msg),
    };
    let (id, rx) = match ctx.coord.submit_streaming(asm.request.clone()) {
        Ok(ok) => ok,
        Err(e) => {
            let (status, msg) = map_submit_err(&e);
            return fail(conn, stream, status, msg);
        }
    };
    write_response_headers(&mut conn.stream, stream)?;
    let policy = encode_stream_chunk(&StreamChunkPb::Policy(policy_pb(id, &asm)));
    if write_grpc_message(&mut conn.stream, stream, &policy).is_err() {
        // Dropping rx disconnects the channel; cancel makes it prompt.
        ctx.coord.cancel(id);
        return Err(io::Error::new(io::ErrorKind::BrokenPipe, "client gone"));
    }
    let mut index = 0u32;
    loop {
        // Surface client frames between events: RST_STREAM cancels the
        // request within one quantum; PING keeps the connection honest.
        if conn.poll_frames().is_err() {
            ctx.coord.cancel(id);
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "client gone"));
        }
        let mut rst = false;
        conn.queue.retain(|f| match f.kind {
            http2::FRAME_RST_STREAM if f.stream == stream => {
                rst = true;
                false
            }
            http2::FRAME_WINDOW_UPDATE => false,
            _ => true,
        });
        // Drain deferred PINGs (retain can't write; answer them here).
        let pings: Vec<Frame> = {
            let mut p = Vec::new();
            conn.queue.retain(|f| {
                if f.kind == http2::FRAME_PING && !f.ack() {
                    p.push(f.clone());
                    false
                } else {
                    true
                }
            });
            p
        };
        for f in pings {
            http2::write_frame(&mut conn.stream, http2::FRAME_PING, http2::FLAG_ACK, 0, &f.payload)?;
        }
        if rst {
            ctx.coord.cancel(id);
            drop(rx);
            return write_trailers(&mut conn.stream, stream, GRPC_CANCELLED, "canceled by client");
        }
        match rx.recv(Duration::from_millis(50)) {
            StreamRecv::TimedOut => continue,
            StreamRecv::Token(t) => {
                let chunk = encode_stream_chunk(&StreamChunkPb::Token { value: t, index });
                index += 1;
                if write_grpc_message(&mut conn.stream, stream, &chunk).is_err() {
                    ctx.coord.cancel(id);
                    return Err(io::Error::new(io::ErrorKind::BrokenPipe, "client gone"));
                }
            }
            StreamRecv::Done(res) => {
                let chunk =
                    encode_stream_chunk(&StreamChunkPb::Done(response_pb(id, &asm, &res)));
                write_grpc_message(&mut conn.stream, stream, &chunk)?;
                return write_trailers(&mut conn.stream, stream, http2::GRPC_OK, "");
            }
            StreamRecv::Error(e) => {
                let chunk = encode_stream_chunk(&StreamChunkPb::Error(e.clone()));
                write_grpc_message(&mut conn.stream, stream, &chunk)?;
                return write_trailers(&mut conn.stream, stream, http2::GRPC_INTERNAL, &e);
            }
            StreamRecv::SenderGone => {
                let msg = "worker dropped the request";
                let chunk = encode_stream_chunk(&StreamChunkPb::Error(msg.to_string()));
                write_grpc_message(&mut conn.stream, stream, &chunk)?;
                return write_trailers(&mut conn.stream, stream, http2::GRPC_UNAVAILABLE, msg);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Minimal client (tests + examples; one connection per RPC).

/// A finished RPC as the client saw it.
#[derive(Debug, Default)]
pub struct GrpcReply {
    /// Decoded gRPC message payloads, in arrival order.
    pub messages: Vec<Vec<u8>>,
    /// `grpc-status` trailer (0 = OK; [`GRPC_CANCELLED`] when we
    /// canceled locally before trailers arrived).
    pub status: u64,
    pub message: String,
}

/// Unary/collecting call: send one request message, gather every
/// response message until trailers.
pub fn call(addr: &str, path: &str, request: &[u8]) -> io::Result<GrpcReply> {
    call_streaming(addr, path, request, |_| true)
}

/// Streaming call: `on_msg` sees each message as it arrives; returning
/// `false` cancels the RPC (RST_STREAM) — the mid-stream-cancel path.
pub fn call_streaming(
    addr: &str,
    path: &str,
    request: &[u8],
    mut on_msg: impl FnMut(&[u8]) -> bool,
) -> io::Result<GrpcReply> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.write_all(http2::PREFACE)?;
    http2::write_frame(&mut stream, http2::FRAME_SETTINGS, 0, 0, &[])?;
    let mut block = Vec::new();
    http2::put_header(&mut block, ":method", "POST");
    http2::put_header(&mut block, ":scheme", "http");
    http2::put_header(&mut block, ":path", path);
    http2::put_header(&mut block, ":authority", "localhost");
    http2::put_header(&mut block, "content-type", "application/grpc");
    http2::put_header(&mut block, "te", "trailers");
    http2::write_frame(&mut stream, http2::FRAME_HEADERS, http2::FLAG_END_HEADERS, 1, &block)?;
    let mut payload = Vec::with_capacity(5 + request.len());
    payload.push(0u8);
    payload.extend_from_slice(&(request.len() as u32).to_be_bytes());
    payload.extend_from_slice(request);
    http2::write_frame(&mut stream, http2::FRAME_DATA, http2::FLAG_END_STREAM, 1, &payload)?;

    let mut reply = GrpcReply::default();
    let mut buf = Vec::new();
    let mut msgbuf: Vec<u8> = Vec::new();
    loop {
        let mut r = stream.try_clone()?;
        let f = http2::read_frame_until(&mut r, &mut stream, &mut buf, |f| {
            f.stream == 1 && (f.kind == http2::FRAME_DATA || f.kind == http2::FRAME_HEADERS)
        })?;
        match f.kind {
            http2::FRAME_DATA => {
                msgbuf.extend_from_slice(&f.payload);
                while msgbuf.len() >= 5 {
                    let len = u32::from_be_bytes(msgbuf[1..5].try_into().unwrap()) as usize;
                    if msgbuf.len() < 5 + len {
                        break;
                    }
                    let msg: Vec<u8> = msgbuf[5..5 + len].to_vec();
                    msgbuf.drain(..5 + len);
                    let keep = on_msg(&msg);
                    reply.messages.push(msg);
                    if !keep {
                        // RST_STREAM error code CANCEL (0x8).
                        http2::write_frame(
                            &mut stream,
                            http2::FRAME_RST_STREAM,
                            0,
                            1,
                            &8u32.to_be_bytes(),
                        )?;
                        reply.status = GRPC_CANCELLED;
                        reply.message = "canceled by client".to_string();
                        return Ok(reply);
                    }
                }
            }
            http2::FRAME_HEADERS => {
                let hs = http2::parse_headers(&f.payload)
                    .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad headers"))?;
                if let Some(status) = http2::header(&hs, "grpc-status") {
                    reply.status = status.parse().unwrap_or(http2::GRPC_INTERNAL);
                    reply.message =
                        http2::header(&hs, "grpc-message").unwrap_or("").to_string();
                    return Ok(reply);
                }
                if let Some(code) = http2::header(&hs, ":status") {
                    if code != "200" {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("HTTP status {}", code),
                        ));
                    }
                }
            }
            _ => unreachable!("filtered by read_frame_until"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_request_roundtrip() {
        let req = GenerateRequestPb {
            dataset: "avqa".into(),
            index: 17,
            profile: "tight".into(),
            max_gen: 4,
            question: "what_sound".into(),
            high_priority: true,
            deadline_ms: 1500,
        };
        let wire = encode_generate_request(&req);
        assert_eq!(decode_generate_request(&wire), Some(req));
    }

    #[test]
    fn stream_chunk_variants_roundtrip() {
        let chunks = [
            StreamChunkPb::Policy(PolicyPb {
                request_id: 3,
                profile: "default".into(),
                spec_hash: "abc".into(),
                spec_json: "{}".into(),
            }),
            StreamChunkPb::Token { value: 0, index: 0 },
            StreamChunkPb::Token { value: 42, index: 7 },
            StreamChunkPb::Error("boom".into()),
        ];
        for c in &chunks {
            let wire = encode_stream_chunk(c);
            assert_eq!(decode_stream_chunk(&wire).as_ref(), Some(c));
        }
    }

    #[test]
    fn generate_response_roundtrip_with_policy() {
        let resp = GenerateResponsePb {
            request_id: 9,
            tokens: vec![5, 0, 31],
            answer: "scene_07".into(),
            expected: "scene_07".into(),
            correct: true,
            relative_flops: 0.58,
            subtask: "what_scene".into(),
            policy: Some(PolicyPb {
                request_id: 9,
                profile: "default".into(),
                spec_hash: "ff00".into(),
                spec_json: "{\"global\":\"fastav\"}".into(),
            }),
            prefill_seconds: 0.5,
            decode_seconds: 0.25,
            peak_kv_bytes: 4096,
            prefix_hit: true,
            prefix_tokens_reused: 12,
        };
        let wire = encode_generate_response(&resp);
        assert_eq!(decode_generate_response(&wire), Some(resp));
    }

    #[test]
    fn grpc_message_split_and_framing() {
        let mut data = Vec::new();
        for msg in [&b"aa"[..], &b"bbbb"[..]] {
            data.push(0u8);
            data.extend_from_slice(&(msg.len() as u32).to_be_bytes());
            data.extend_from_slice(msg);
        }
        let msgs = split_grpc_messages(&data).unwrap();
        assert_eq!(msgs, vec![&b"aa"[..], &b"bbbb"[..]]);
        // Compressed flag or truncation rejected.
        assert!(split_grpc_messages(&[1, 0, 0, 0, 0]).is_none());
        assert!(split_grpc_messages(&[0, 0, 0, 0, 9, 1]).is_none());
    }
}
