//! Synthetic audio-visual benchmark generators — rust mirror of
//! `python/compile/avsynth.py`.
//!
//! Both implementations must generate **bit-identical** samples from the
//! same `(base_seed, dataset, index)` triple: python generates training
//! batches at build time, rust generates serving/eval workloads at run
//! time, and pruning-accuracy results are only meaningful if the trained
//! distribution matches the served distribution exactly. The contract is
//! pinned by `testdata/avsynth_vectors.json` (hashes of full samples,
//! written by the python suite and asserted here).

use crate::tokens::{self as V, Layout, Segment};
use crate::util::rng::{derive_seed, SplitMix64};

pub const EVIDENCE_FRAMES: usize = 2;
pub const EVIDENCE_AUD_SLOTS: usize = 4;
pub const BEAT_REGION: usize = 12;
pub const MAX_BEATS: u64 = 5;

/// Dataset identifiers (seed-derivation streams; mirrors python).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    Train,
    Avqa,
    MusicAvqa,
    AvhBench,
    Calib,
}

impl Dataset {
    pub fn stream(self) -> u64 {
        match self {
            Dataset::Train => 0,
            Dataset::Avqa => 1,
            Dataset::MusicAvqa => 2,
            Dataset::AvhBench => 3,
            Dataset::Calib => 4,
        }
    }

    pub fn parse(name: &str) -> Option<Dataset> {
        Some(match name {
            "train" => Dataset::Train,
            "avqa" => Dataset::Avqa,
            "musicavqa" => Dataset::MusicAvqa,
            "avhbench" => Dataset::AvhBench,
            "calib" => Dataset::Calib,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Dataset::Train => "train",
            Dataset::Avqa => "avqa",
            Dataset::MusicAvqa => "musicavqa",
            Dataset::AvhBench => "avhbench",
            Dataset::Calib => "calib",
        }
    }
}

/// Question subtask (also the evaluation grouping key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Subtask {
    WhatScene,
    WhatSound,
    SceneSound,
    HowManyBeats,
    WhichInstrument,
    Hallucination,
    Matching,
    Captioning,
}

impl Subtask {
    pub fn name(self) -> &'static str {
        match self {
            Subtask::WhatScene => "what_scene",
            Subtask::WhatSound => "what_sound",
            Subtask::SceneSound => "scene_sound",
            Subtask::HowManyBeats => "how_many_beats",
            Subtask::WhichInstrument => "which_instrument",
            Subtask::Hallucination => "hallucination",
            Subtask::Matching => "matching",
            Subtask::Captioning => "captioning",
        }
    }
}

/// One synthetic AV sample (mirrors avsynth.Sample).
#[derive(Debug, Clone)]
pub struct Sample {
    pub dataset: Dataset,
    pub subtask: Subtask,
    pub index: u64,
    pub prompt: Vec<u32>,
    pub answer: Vec<u32>, // includes trailing EOS
    pub segments: Vec<Segment>,
    pub frame_of: Vec<i32>, // -1 when not frame-scoped
    pub scene: u32,
    pub sound: u32,
    pub beats: u32,
}

fn fill_streams(
    rng: &mut SplitMix64,
    cfg: &Layout,
    scene: u32,
    sound: u32,
    beats: u64,
) -> (Vec<Vec<u32>>, Vec<u32>) {
    let mut vis = Vec::with_capacity(cfg.frames);
    for f in 0..cfg.frames {
        let mut frame: Vec<u32> = (0..cfg.vis_per_frame)
            .map(|_| V::VIS_NOISE_BASE + rng.next_below(V::VIS_NOISE_COUNT as u64) as u32)
            .collect();
        if f < EVIDENCE_FRAMES {
            let slot = rng.next_below(cfg.vis_per_frame as u64) as usize;
            frame[slot] = V::scene_token(scene);
        }
        vis.push(frame);
    }

    let n_aud = cfg.audio_tokens();
    let mut aud: Vec<u32> = (0..n_aud)
        .map(|_| V::AUD_NOISE_BASE + rng.next_below(V::AUD_NOISE_COUNT as u64) as u32)
        .collect();
    let slot = rng.next_below(EVIDENCE_AUD_SLOTS.min(n_aud) as u64) as usize;
    aud[slot] = V::sound_token(sound);
    if beats > 0 {
        let region = BEAT_REGION.min(n_aud);
        let mut placed = 0;
        while placed < beats {
            let b = rng.next_below(region as u64) as usize;
            if aud[b] == V::BEAT || b == slot {
                continue;
            }
            aud[b] = V::BEAT;
            placed += 1;
        }
    }
    (vis, aud)
}

fn assemble(
    cfg: &Layout,
    vis: &[Vec<u32>],
    aud: &[u32],
    question: &[u32],
) -> (Vec<u32>, Vec<Segment>, Vec<i32>) {
    let mut prompt = vec![V::BOS];
    let mut segs = vec![Segment::Ctrl];
    let mut frames = vec![-1i32];
    if cfg.interleaved {
        let ap = cfg.aud_per_frame;
        for f in 0..cfg.frames {
            for &t in &vis[f] {
                prompt.push(t);
                segs.push(Segment::Vis);
                frames.push(f as i32);
            }
            for &a in &aud[f * ap..(f + 1) * ap] {
                prompt.push(a);
                segs.push(Segment::Aud);
                frames.push(f as i32);
            }
        }
    } else {
        for f in 0..cfg.frames {
            for &t in &vis[f] {
                prompt.push(t);
                segs.push(Segment::Vis);
                frames.push(f as i32);
            }
        }
        for &a in aud {
            prompt.push(a);
            segs.push(Segment::Aud);
            frames.push(-1);
        }
    }
    for &t in question {
        prompt.push(t);
        segs.push(Segment::Text);
        frames.push(-1);
    }
    (prompt, segs, frames)
}

fn question(qword: u32, arg: Option<u32>) -> Vec<u32> {
    let mut q = vec![V::SEP, qword];
    if let Some(a) = arg {
        q.push(a);
    }
    q.push(V::SEP);
    q
}

/// Generate sample `index` of `dataset` deterministically (bit-identical
/// to the python implementation).
pub fn gen_sample(cfg: &Layout, dataset: Dataset, index: u64, base_seed: u64) -> Sample {
    let mut rng = SplitMix64::new(derive_seed(base_seed, dataset.stream(), index));

    let scene = rng.next_below(V::NUM_CLASSES as u64) as u32;
    let mut sound = rng.next_below(V::NUM_CLASSES as u64) as u32;
    let mut beats: i64 = -1;

    let pick = match dataset {
        // Weighted mixture (mirrors python): retrieval tasks weight 1,
        // hallucination/matching weight 4, captioning 1 (total 14).
        Dataset::Train | Dataset::Calib => {
            let r = rng.next_below(14);
            let bounds = [1u64, 2, 3, 4, 5, 9, 13, 14];
            let picks = [0u64, 1, 2, 3, 4, 5, 6, 8];
            let mut chosen = 8;
            for (b, p) in bounds.iter().zip(picks.iter()) {
                if r < *b {
                    chosen = *p;
                    break;
                }
            }
            chosen
        }
        Dataset::Avqa => rng.next_below(3),
        Dataset::MusicAvqa => 3 + rng.next_below(2),
        Dataset::AvhBench => {
            let p = 5 + rng.next_below(3);
            if p == 7 {
                8
            } else {
                p
            }
        }
    };

    let (subtask, q, answer): (Subtask, Vec<u32>, Vec<u32>) = match pick {
        0 => (
            Subtask::WhatScene,
            question(V::Q_WHAT_SCENE, None),
            vec![V::scene_token(scene), V::EOS],
        ),
        1 => (
            Subtask::WhatSound,
            question(V::Q_WHAT_SOUND, None),
            vec![V::sound_token(sound), V::EOS],
        ),
        2 => (
            Subtask::SceneSound,
            question(V::Q_SCENE_SOUND, None),
            vec![V::scene_token(scene), V::sound_token(sound), V::EOS],
        ),
        3 => {
            let b = rng.next_below(MAX_BEATS + 1);
            beats = b as i64;
            (
                Subtask::HowManyBeats,
                question(V::Q_HOW_MANY_BEATS, None),
                vec![V::digit_token(b as u32), V::EOS],
            )
        }
        4 => (
            Subtask::WhichInstrument,
            question(V::Q_WHICH_INSTRUMENT, None),
            vec![V::sound_token(sound), V::EOS],
        ),
        5 => {
            let ask_sound = rng.chance(0.5);
            let present = rng.chance(0.5);
            let actual = if ask_sound { sound } else { scene };
            let probe = if present {
                actual
            } else {
                (actual + 1 + rng.next_below(V::NUM_CLASSES as u64 - 1) as u32) % V::NUM_CLASSES
            };
            let tok = if ask_sound { V::sound_token(probe) } else { V::scene_token(probe) };
            let qw = if ask_sound { V::Q_IS_THERE_SOUND } else { V::Q_IS_THERE_SCENE };
            (
                Subtask::Hallucination,
                question(qw, Some(tok)),
                vec![if present { V::YES } else { V::NO }, V::EOS],
            )
        }
        6 => {
            let matched = rng.chance(0.5);
            if matched {
                sound = scene;
            } else {
                sound = (scene + 1 + rng.next_below(V::NUM_CLASSES as u64 - 1) as u32)
                    % V::NUM_CLASSES;
            }
            (
                Subtask::Matching,
                question(V::Q_AV_MATCH, None),
                vec![if matched { V::YES } else { V::NO }, V::EOS],
            )
        }
        8 => (
            Subtask::Captioning,
            question(V::Q_DESCRIBE, None),
            vec![V::scene_token(scene), V::sound_token(sound), V::EOS],
        ),
        _ => unreachable!("pick {}", pick),
    };

    let beats_u = if beats < 0 { 0 } else { beats as u64 };
    let (vis, aud) = fill_streams(&mut rng, cfg, scene, sound, beats_u);
    let (prompt, segments, frame_of) = assemble(cfg, &vis, &aud, &q);
    Sample {
        dataset,
        subtask,
        index,
        prompt,
        answer,
        segments,
        frame_of,
        scene,
        sound,
        beats: beats_u as u32,
    }
}

/// Argless retrieval questions that can be re-asked about any sample —
/// the serving-side "N questions per sample" workload the AV-prefix
/// cache accelerates. Kept out of [`gen_sample`] so the cross-language
/// bit-identity contract (pinned by `testdata/avsynth_vectors.json`) is
/// untouched: the AV streams stay exactly as generated; only the
/// trailing question text (and the derived answer) are rewritten.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuestionKind {
    WhatScene,
    WhatSound,
    SceneSound,
}

impl QuestionKind {
    pub fn parse(name: &str) -> Option<QuestionKind> {
        Some(match name {
            "what_scene" => QuestionKind::WhatScene,
            "what_sound" => QuestionKind::WhatSound,
            "scene_sound" => QuestionKind::SceneSound,
            _ => return None,
        })
    }

    /// Round-robin variant for workload drivers.
    pub fn nth(i: usize) -> QuestionKind {
        match i % 3 {
            0 => QuestionKind::WhatScene,
            1 => QuestionKind::WhatSound,
            _ => QuestionKind::SceneSound,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuestionKind::WhatScene => "what_scene",
            QuestionKind::WhatSound => "what_sound",
            QuestionKind::SceneSound => "scene_sound",
        }
    }
}

impl Sample {
    /// The same sample asking a different question: identical AV prefix
    /// (tokens, segments, frame map), new trailing question text, and
    /// the ground-truth answer recomputed from the sample's latent
    /// scene/sound.
    pub fn with_question(&self, q: QuestionKind) -> Sample {
        let (subtask, qword, answer) = match q {
            QuestionKind::WhatScene => (
                Subtask::WhatScene,
                V::Q_WHAT_SCENE,
                vec![V::scene_token(self.scene), V::EOS],
            ),
            QuestionKind::WhatSound => (
                Subtask::WhatSound,
                V::Q_WHAT_SOUND,
                vec![V::sound_token(self.sound), V::EOS],
            ),
            QuestionKind::SceneSound => (
                Subtask::SceneSound,
                V::Q_SCENE_SOUND,
                vec![V::scene_token(self.scene), V::sound_token(self.sound), V::EOS],
            ),
        };
        // The question is the trailing run of Text tokens.
        let text_start = self
            .segments
            .iter()
            .position(|&g| g == Segment::Text)
            .unwrap_or(self.prompt.len());
        let mut out = self.clone();
        out.subtask = subtask;
        out.answer = answer;
        out.prompt.truncate(text_start);
        out.segments.truncate(text_start);
        out.frame_of.truncate(text_start);
        for t in question(qword, None) {
            out.prompt.push(t);
            out.segments.push(Segment::Text);
            out.frame_of.push(-1);
        }
        out
    }
}

/// Structural hash used by the cross-language reference vectors:
/// `h = (h * 31 + token) mod 2^32` over `prompt ++ answer`.
pub fn sample_hash(s: &Sample) -> u32 {
    let mut h: u32 = 0;
    for &t in s.prompt.iter().chain(s.answer.iter()) {
        h = h.wrapping_mul(31).wrapping_add(t);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokens::{salmsim_layout, vl2sim_layout};
    use crate::util::json::Json;

    const BASE_SEED: u64 = 1234;

    #[test]
    fn deterministic() {
        let l = vl2sim_layout();
        let a = gen_sample(&l, Dataset::Avqa, 17, BASE_SEED);
        let b = gen_sample(&l, Dataset::Avqa, 17, BASE_SEED);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }

    #[test]
    fn segment_map_lengths() {
        let l = vl2sim_layout();
        let s = gen_sample(&l, Dataset::AvhBench, 5, BASE_SEED);
        assert_eq!(s.prompt.len(), s.segments.len());
        assert_eq!(s.prompt.len(), s.frame_of.len());
        assert!(s.prompt.len() <= l.prompt_len_max());
    }

    #[test]
    fn sequential_vis_before_aud() {
        let l = vl2sim_layout();
        let s = gen_sample(&l, Dataset::Avqa, 2, BASE_SEED);
        let last_vis = s.segments.iter().rposition(|&g| g == Segment::Vis).unwrap();
        let first_aud = s.segments.iter().position(|&g| g == Segment::Aud).unwrap();
        assert!(last_vis < first_aud);
    }

    #[test]
    fn interleaved_frames_contiguous() {
        let l = salmsim_layout();
        let s = gen_sample(&l, Dataset::Avqa, 5, BASE_SEED);
        let f0: Vec<usize> =
            (0..s.prompt.len()).filter(|&i| s.frame_of[i] == 0).collect();
        assert_eq!(f0.len(), l.vis_per_frame + l.aud_per_frame);
        let contiguous: Vec<usize> = (f0[0]..=*f0.last().unwrap()).collect();
        assert_eq!(f0, contiguous);
    }

    #[test]
    fn with_question_preserves_av_prefix() {
        let l = vl2sim_layout();
        let s = gen_sample(&l, Dataset::Avqa, 9, BASE_SEED);
        let p = s.segments.iter().position(|&g| g == Segment::Text).unwrap();
        for (i, q) in [
            QuestionKind::WhatScene,
            QuestionKind::WhatSound,
            QuestionKind::SceneSound,
        ]
        .into_iter()
        .enumerate()
        {
            assert_eq!(QuestionKind::nth(i), q);
            let v = s.with_question(q);
            // Identical AV prefix — the property the prefix cache keys on.
            assert_eq!(&v.prompt[..p], &s.prompt[..p]);
            assert_eq!(&v.segments[..p], &s.segments[..p]);
            assert_eq!(&v.frame_of[..p], &s.frame_of[..p]);
            assert_eq!(v.prompt.len(), v.segments.len());
            assert_eq!(v.prompt.len(), v.frame_of.len());
            // Question text swapped in, answer re-derived from latents.
            assert!(v.segments[p..].iter().all(|&g| g == Segment::Text));
            assert_eq!(*v.answer.last().unwrap(), V::EOS);
            match q {
                QuestionKind::WhatScene => {
                    assert_eq!(v.answer[0], V::scene_token(s.scene))
                }
                QuestionKind::WhatSound => {
                    assert_eq!(v.answer[0], V::sound_token(s.sound))
                }
                QuestionKind::SceneSound => {
                    assert_eq!(v.answer[0], V::scene_token(s.scene));
                    assert_eq!(v.answer[1], V::sound_token(s.sound));
                }
            }
            assert_eq!(QuestionKind::parse(q.name()), Some(q));
        }
    }

    #[test]
    fn matching_answer_consistent() {
        let l = vl2sim_layout();
        for i in 0..60 {
            let s = gen_sample(&l, Dataset::AvhBench, i, BASE_SEED);
            if s.subtask == Subtask::Matching {
                let want = if s.scene == s.sound { V::YES } else { V::NO };
                assert_eq!(s.answer[0], want);
            }
        }
    }

    #[test]
    fn beats_counted() {
        let l = vl2sim_layout();
        for i in 0..60 {
            let s = gen_sample(&l, Dataset::MusicAvqa, i, BASE_SEED);
            if s.subtask == Subtask::HowManyBeats {
                let n = s
                    .prompt
                    .iter()
                    .zip(&s.segments)
                    .filter(|&(&t, &g)| t == V::BEAT && g == Segment::Aud)
                    .count() as u32;
                assert_eq!(s.answer[0], V::digit_token(n));
                assert_eq!(n, s.beats);
            }
        }
    }

    #[test]
    fn cross_language_reference_vectors() {
        // Written by python/tests/test_avsynth.py::test_pinned_sample_prefix.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/testdata/avsynth_vectors.json");
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => {
                eprintln!("skipping: {} not generated yet (run pytest first)", path);
                return;
            }
        };
        let vectors = Json::parse(&text).unwrap();
        let vl2 = vl2sim_layout();
        let salm = salmsim_layout();
        let mut checked = 0;
        for v in vectors.as_arr().unwrap() {
            let layout = match v.get("layout").as_str().unwrap() {
                "vl2sim" => &vl2,
                "salmsim" => &salm,
                other => panic!("unknown layout {}", other),
            };
            let ds = Dataset::parse(v.get("dataset").as_str().unwrap()).unwrap();
            let idx = v.get("index").as_usize().unwrap() as u64;
            let s = gen_sample(layout, ds, idx, BASE_SEED);
            assert_eq!(s.prompt.len(), v.get("prompt_len").as_usize().unwrap(),
                "prompt_len mismatch for {:?} {}", ds, idx);
            assert_eq!(sample_hash(&s) as usize, v.get("hash").as_usize().unwrap(),
                "hash mismatch for {:?} {}", ds, idx);
            assert_eq!(s.subtask.name(), v.get("subtask").as_str().unwrap());
            let want_answer: Vec<u32> = v
                .get("answer")
                .as_arr()
                .unwrap()
                .iter()
                .map(|j| j.as_usize().unwrap() as u32)
                .collect();
            assert_eq!(s.answer, want_answer);
            checked += 1;
        }
        assert_eq!(checked, 18);
    }

    #[test]
    fn evidence_placement_early() {
        let l = vl2sim_layout();
        for i in 0..30 {
            let s = gen_sample(&l, Dataset::Avqa, i, BASE_SEED);
            let tok = V::scene_token(s.scene);
            let frames: std::collections::BTreeSet<i32> = s
                .prompt
                .iter()
                .enumerate()
                .filter(|&(j, &t)| t == tok && s.segments[j] == Segment::Vis)
                .map(|(j, _)| s.frame_of[j])
                .collect();
            let want: std::collections::BTreeSet<i32> =
                (0..EVIDENCE_FRAMES as i32).collect();
            assert_eq!(frames, want);
        }
    }

    #[test]
    fn answers_end_with_eos() {
        let l = vl2sim_layout();
        for ds in [Dataset::Avqa, Dataset::MusicAvqa, Dataset::AvhBench] {
            for i in 0..20 {
                let s = gen_sample(&l, ds, i, BASE_SEED);
                assert_eq!(*s.answer.last().unwrap(), V::EOS);
                assert!(s.answer.len() >= 2 && s.answer.len() <= 4);
            }
        }
    }
}
