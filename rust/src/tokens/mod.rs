//! Vocabulary constants and modality segment layout.
//!
//! Mirrors `python/compile/vocab.py` and the layout half of
//! `python/compile/avsynth.py`; the cross-language contract is pinned by
//! `testdata/avsynth_vectors.json` (written by the python test suite,
//! checked by [`crate::avsynth`] tests).

/// Vocabulary size shared by all model configs.
pub const VOCAB_SIZE: usize = 256;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const YES: u32 = 4;
pub const NO: u32 = 5;

pub const NUM_CLASSES: u32 = 16;
pub const SCENE_BASE: u32 = 16;
pub const SOUND_BASE: u32 = 32;
pub const DIGIT_BASE: u32 = 48;

pub const VIS_NOISE_BASE: u32 = 64;
pub const VIS_NOISE_COUNT: u32 = 64;
pub const AUD_NOISE_BASE: u32 = 128;
pub const AUD_NOISE_COUNT: u32 = 64;

pub const Q_WHAT_SCENE: u32 = 192;
pub const Q_WHAT_SOUND: u32 = 193;
pub const Q_SCENE_SOUND: u32 = 194;
pub const Q_HOW_MANY_BEATS: u32 = 195;
pub const Q_WHICH_INSTRUMENT: u32 = 196;
pub const Q_IS_THERE_SCENE: u32 = 197;
pub const Q_IS_THERE_SOUND: u32 = 198;
pub const Q_AV_MATCH: u32 = 199;
pub const Q_DESCRIBE: u32 = 200;

pub const BEAT: u32 = 208;

pub fn scene_token(c: u32) -> u32 {
    debug_assert!(c < NUM_CLASSES);
    SCENE_BASE + c
}

pub fn sound_token(c: u32) -> u32 {
    debug_assert!(c < NUM_CLASSES);
    SOUND_BASE + c
}

pub fn digit_token(k: u32) -> u32 {
    debug_assert!(k <= 9);
    DIGIT_BASE + k
}

pub fn is_scene_token(t: u32) -> bool {
    (SCENE_BASE..SCENE_BASE + NUM_CLASSES).contains(&t)
}

pub fn is_sound_token(t: u32) -> bool {
    (SOUND_BASE..SOUND_BASE + NUM_CLASSES).contains(&t)
}

/// Modality of a prompt token (mirrors avsynth.SEG_* codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Segment {
    Ctrl = 0,
    Vis = 1,
    Aud = 2,
    Text = 3,
}

impl Segment {
    pub fn from_code(c: u8) -> Segment {
        match c {
            0 => Segment::Ctrl,
            1 => Segment::Vis,
            2 => Segment::Aud,
            3 => Segment::Text,
            _ => panic!("bad segment code {}", c),
        }
    }
}

/// Modality layout of a prompt (mirrors avsynth.LayoutCfg).
#[derive(Debug, Clone, PartialEq)]
pub struct Layout {
    pub frames: usize,
    pub vis_per_frame: usize,
    pub aud_len: usize,       // sequential layout: total audio tokens
    pub aud_per_frame: usize, // interleaved layout: audio tokens per frame
    pub interleaved: bool,
}

impl Layout {
    pub fn audio_tokens(&self) -> usize {
        if self.interleaved {
            self.frames * self.aud_per_frame
        } else {
            self.aud_len
        }
    }

    pub fn vis_tokens(&self) -> usize {
        self.frames * self.vis_per_frame
    }

    /// BOS + modality tokens + `[SEP, qword, arg, SEP]`.
    pub fn prompt_len_max(&self) -> usize {
        1 + self.vis_tokens() + self.audio_tokens() + 4
    }
}

/// Canonical layouts (mirrors avsynth.VL2SIM_LAYOUT etc.).
pub fn vl2sim_layout() -> Layout {
    Layout { frames: 8, vis_per_frame: 8, aud_len: 24, aud_per_frame: 3, interleaved: false }
}

pub fn salmsim_layout() -> Layout {
    Layout { frames: 8, vis_per_frame: 8, aud_len: 24, aud_per_frame: 3, interleaved: true }
}

pub fn vl2sim_long_layout() -> Layout {
    Layout { frames: 24, vis_per_frame: 16, aud_len: 96, aud_per_frame: 3, interleaved: false }
}

/// Human-readable rendering of a token id (logging / HTTP responses).
pub fn token_name(t: u32) -> String {
    match t {
        PAD => "<pad>".into(),
        BOS => "<bos>".into(),
        EOS => "<eos>".into(),
        SEP => "<sep>".into(),
        YES => "yes".into(),
        NO => "no".into(),
        t if is_scene_token(t) => format!("scene_{}", t - SCENE_BASE),
        t if is_sound_token(t) => format!("sound_{}", t - SOUND_BASE),
        t if (DIGIT_BASE..DIGIT_BASE + 10).contains(&t) => format!("{}", t - DIGIT_BASE),
        Q_WHAT_SCENE => "what-scene?".into(),
        Q_WHAT_SOUND => "what-sound?".into(),
        Q_SCENE_SOUND => "scene-and-sound?".into(),
        Q_HOW_MANY_BEATS => "how-many-beats?".into(),
        Q_WHICH_INSTRUMENT => "which-instrument?".into(),
        Q_IS_THERE_SCENE => "is-there-scene?".into(),
        Q_IS_THERE_SOUND => "is-there-sound?".into(),
        Q_AV_MATCH => "av-match?".into(),
        Q_DESCRIBE => "describe".into(),
        BEAT => "<beat>".into(),
        t if (VIS_NOISE_BASE..VIS_NOISE_BASE + VIS_NOISE_COUNT).contains(&t) => {
            format!("v{}", t - VIS_NOISE_BASE)
        }
        t if (AUD_NOISE_BASE..AUD_NOISE_BASE + AUD_NOISE_COUNT).contains(&t) => {
            format!("a{}", t - AUD_NOISE_BASE)
        }
        t => format!("<{}>", t),
    }
}

/// Render an answer token sequence (drops the trailing EOS).
pub fn render_answer(tokens: &[u32]) -> String {
    tokens
        .iter()
        .filter(|&&t| t != EOS && t != PAD)
        .map(|&t| token_name(t))
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_ranges_disjoint() {
        // Every classifier matches a disjoint range.
        for t in 0..VOCAB_SIZE as u32 {
            let classes = [
                is_scene_token(t),
                is_sound_token(t),
                (DIGIT_BASE..DIGIT_BASE + 10).contains(&t),
                (VIS_NOISE_BASE..VIS_NOISE_BASE + VIS_NOISE_COUNT).contains(&t),
                (AUD_NOISE_BASE..AUD_NOISE_BASE + AUD_NOISE_COUNT).contains(&t),
            ];
            assert!(classes.iter().filter(|&&c| c).count() <= 1, "token {}", t);
        }
    }

    #[test]
    fn layout_lengths() {
        let l = vl2sim_layout();
        assert_eq!(l.vis_tokens(), 64);
        assert_eq!(l.audio_tokens(), 24);
        assert_eq!(l.prompt_len_max(), 93);
        assert!(l.prompt_len_max() <= 128);

        let s = salmsim_layout();
        assert_eq!(s.audio_tokens(), 24);
        assert_eq!(s.prompt_len_max(), 93);

        let long = vl2sim_long_layout();
        assert!(long.prompt_len_max() <= 512);
    }

    #[test]
    fn segment_roundtrip() {
        for c in 0..4u8 {
            assert_eq!(Segment::from_code(c) as u8, c);
        }
    }

    #[test]
    fn token_names_render() {
        assert_eq!(token_name(YES), "yes");
        assert_eq!(token_name(scene_token(3)), "scene_3");
        assert_eq!(token_name(digit_token(7)), "7");
        assert_eq!(render_answer(&[scene_token(1), sound_token(2), EOS]), "scene_1 sound_2");
    }
}
