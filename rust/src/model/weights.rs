//! Weights loading: `weights.bin` + `manifest.json` → host tensors and
//! prebuilt XLA literals (the rust half of `python/compile/export.py`).
//!
//! Stacked per-layer tensors keep their `[L, ...]` leading axis in the
//! file, so the contiguous `[0..mid)` slab feeds the fused front-half
//! artifact without copying, and row `l` feeds single-layer artifacts.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use super::config::ModelConfig;
use crate::runtime::literals::lit_f32;
use crate::util::json::Json;

/// Per-layer parameter names in artifact ABI order (mirrors python
/// `LAYER_PARAM_NAMES`).
pub const LAYER_PARAM_NAMES: [&str; 9] =
    ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"];

/// One named tensor: shape + the elements (host copy).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// All model weights on the host.
#[derive(Debug)]
pub struct Weights {
    pub emb: Tensor,
    pub ln_f: Tensor,
    /// Stacked per-layer tensors, keyed in LAYER_PARAM_NAMES order.
    pub layers: Vec<Tensor>,
}

impl Weights {
    /// Load from a model weights directory.
    pub fn load(dir: &Path) -> Result<Weights> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("manifest in {:?} (run `make artifacts`)", dir))?;
        let manifest =
            Json::parse(&manifest_text).map_err(|e| anyhow!("manifest.json: {}", e))?;
        let raw = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("weights.bin in {:?}", dir))?;
        if raw.len() % 4 != 0 {
            bail!("weights.bin size {} not a multiple of 4", raw.len());
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut tensors = std::collections::BTreeMap::new();
        for t in manifest
            .get("tensors")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: tensors[] missing"))?
        {
            let name = t
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("tensor name"))?
                .to_string();
            let shape: Vec<usize> = t
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("tensor shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?;
            let offset = t
                .get("offset")
                .as_usize()
                .ok_or_else(|| anyhow!("tensor offset"))?;
            let n: usize = shape.iter().product();
            if offset + n > floats.len() {
                bail!("tensor {} [{}..{}] exceeds file ({})", name, offset, offset + n, floats.len());
            }
            tensors.insert(
                name.clone(),
                Tensor { name, shape, data: floats[offset..offset + n].to_vec() },
            );
        }

        let take = |name: &str| -> Result<Tensor> {
            tensors
                .get(name)
                .cloned()
                .ok_or_else(|| anyhow!("manifest missing tensor '{}'", name))
        };
        let emb = take("emb")?;
        let ln_f = take("ln_f")?;
        let layers = LAYER_PARAM_NAMES
            .iter()
            .map(|p| take(&format!("layers.{}", p)))
            .collect::<Result<Vec<_>>>()?;
        Ok(Weights { emb, ln_f, layers })
    }

    /// Validate shapes against a model config.
    pub fn check(&self, cfg: &ModelConfig) -> Result<()> {
        if self.emb.shape != [cfg.vocab, cfg.d_model] {
            bail!("emb shape {:?} != [{}, {}]", self.emb.shape, cfg.vocab, cfg.d_model);
        }
        if self.ln_f.shape != [cfg.d_model] {
            bail!("ln_f shape {:?}", self.ln_f.shape);
        }
        for t in &self.layers {
            if t.shape[0] != cfg.n_layers {
                bail!("{} leading dim {} != n_layers {}", t.name, t.shape[0], cfg.n_layers);
            }
        }
        Ok(())
    }

    /// Embedding row for a token id.
    pub fn embed(&self, token: u32) -> &[f32] {
        let d = self.emb.shape[1];
        let i = token as usize;
        &self.emb.data[i * d..(i + 1) * d]
    }

    /// Gather embeddings for a prompt into `dst` (bucket-padded `[n, d]`).
    pub fn embed_into(&self, tokens: &[u32], dst: &mut [f32]) {
        let d = self.emb.shape[1];
        assert!(tokens.len() * d <= dst.len());
        for (i, &t) in tokens.iter().enumerate() {
            dst[i * d..(i + 1) * d].copy_from_slice(self.embed(t));
        }
    }
}

/// Prebuilt literals for every artifact parameter slot — built once at
/// engine startup, reused across all requests. A **mesh-only** build
/// (`with_fused == false`, used at tp_degree > 1) skips every literal
/// only the fused single-device artifacts consume — the front slab, the
/// per-layer full-head QKV projections (dispatched from
/// [`ShardWeightLiterals`] column slices instead), and the tied
/// unembedding — which would otherwise roughly double the resident
/// weight bytes per device group.
pub struct WeightLiterals {
    /// 9 stacked `[mid, ...]` literals for `prefill_front` (empty on a
    /// mesh-only build — the sharded front runs per layer).
    pub front: Vec<Literal>,
    /// 9 stacked `[L, ...]` literals for `calib_probe` (always built;
    /// calibration/rollout probes are unsharded on any mesh).
    pub full_stack: Vec<Literal>,
    /// `per_layer[l]` = single-layer literals for back/decode layers:
    /// `[ln1, wq, wk, wv, wo, ln2, wg, wu, wd]` on a fused build,
    /// `[ln1, wo, ln2, wg, wu, wd]` on a mesh-only build. `[0]` is
    /// always ln1 and the last 5 are always the combine-stage params.
    pub per_layer: Vec<Vec<Literal>>,
    /// `ln_f` for the logits head (fused and per-shard alike).
    pub ln_f: Literal,
    /// Tied unembedding for the fused logits head; `None` on a
    /// mesh-only build (logits dispatch per-shard emb column slices).
    pub emb: Option<Literal>,
}

impl WeightLiterals {
    /// Full (fused single-device) build.
    pub fn build(w: &Weights, cfg: &ModelConfig) -> Result<WeightLiterals> {
        Self::build_with(w, cfg, true)
    }

    /// Build with or without the fused-artifact literals (see type doc).
    pub fn build_with(
        w: &Weights,
        cfg: &ModelConfig,
        with_fused: bool,
    ) -> Result<WeightLiterals> {
        let l = cfg.n_layers;
        let mid = cfg.mid_layer;
        let mut front = Vec::with_capacity(9);
        let mut full_stack = Vec::with_capacity(9);
        let mut per_layer: Vec<Vec<Literal>> = (0..l).map(|_| Vec::with_capacity(9)).collect();
        for (i, t) in w.layers.iter().enumerate() {
            let row = t.elems() / t.shape[0];
            let inner: Vec<usize> = t.shape[1..].to_vec();
            if with_fused {
                // Front slab: first `mid` rows, contiguous.
                let mut front_shape = vec![mid];
                front_shape.extend(&inner);
                front.push(lit_f32(&front_shape, &t.data[..mid * row])?);
            }
            full_stack.push(lit_f32(&t.shape, &t.data)?);
            // LAYER_PARAM_NAMES order: wq/wk/wv are tensors 1..=3 — on a
            // mesh-only build they ship as per-shard column slices only.
            if !with_fused && (1..=3).contains(&i) {
                continue;
            }
            for (li, slot) in per_layer.iter_mut().enumerate() {
                slot.push(lit_f32(&inner, &t.data[li * row..(li + 1) * row])?);
            }
        }
        Ok(WeightLiterals {
            front,
            full_stack,
            per_layer,
            ln_f: lit_f32(&w.ln_f.shape, &w.ln_f.data)?,
            emb: if with_fused {
                Some(lit_f32(&w.emb.shape, &w.emb.data)?)
            } else {
                None
            },
        })
    }
}

/// Per-shard weight literals for the device-mesh (tensor-parallel) path:
/// shard `s` of `D` owns attention heads `[s·H/D, (s+1)·H/D)`, i.e.
/// output columns `[s·d/D, (s+1)·d/D)` of wq/wk/wv, and columns
/// `[s·d/D, (s+1)·d/D)` of the tied unembedding for the logits partial.
/// Everything else a shard artifact needs (ln1) and the whole combine
/// stage (wo, ln2, wg, wu, wd) reuse [`WeightLiterals::per_layer`].
pub struct ShardWeightLiterals {
    /// `qkv[l][s]` = [wq_s, wk_s, wv_s], each `[d, d/D]`.
    pub qkv: Vec<Vec<Vec<Literal>>>,
    /// `emb[s]` = `[vocab, d/D]` column slice for `logits_shard<s>of<D>`.
    pub emb: Vec<Literal>,
}

/// Column slice `[c0, c0+w)` of a row-major `[rows, cols]` matrix.
fn col_slice(data: &[f32], rows: usize, cols: usize, c0: usize, w: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), rows * cols);
    let mut out = Vec::with_capacity(rows * w);
    for r in 0..rows {
        out.extend_from_slice(&data[r * cols + c0..r * cols + c0 + w]);
    }
    out
}

impl ShardWeightLiterals {
    pub fn build(w: &Weights, cfg: &ModelConfig, tp: usize) -> Result<ShardWeightLiterals> {
        if tp < 2 {
            bail!("shard literals need tp >= 2, got {}", tp);
        }
        if cfg.n_heads % tp != 0 || cfg.d_model % tp != 0 {
            bail!(
                "tp {} must divide n_heads {} and d_model {}",
                tp,
                cfg.n_heads,
                cfg.d_model
            );
        }
        let (d, l) = (cfg.d_model, cfg.n_layers);
        let dc = d / tp;
        // LAYER_PARAM_NAMES order: wq/wk/wv are tensors 1..=3.
        let mut qkv: Vec<Vec<Vec<Literal>>> = (0..l)
            .map(|_| (0..tp).map(|_| Vec::with_capacity(3)).collect())
            .collect();
        for t in &w.layers[1..=3] {
            let row = t.elems() / t.shape[0]; // d * d
            for (li, per_shard) in qkv.iter_mut().enumerate() {
                let layer = &t.data[li * row..(li + 1) * row];
                for (s, slot) in per_shard.iter_mut().enumerate() {
                    let cols = col_slice(layer, d, d, s * dc, dc);
                    slot.push(lit_f32(&[d, dc], &cols)?);
                }
            }
        }
        let emb = (0..tp)
            .map(|s| {
                let cols = col_slice(&w.emb.data, cfg.vocab, d, s * dc, dc);
                lit_f32(&[cfg.vocab, dc], &cols)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardWeightLiterals { qkv, emb })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    struct TempDir(std::path::PathBuf);

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    /// Write a synthetic 2-layer weights dir: d=4, ff=8, vocab=6.
    fn fake_weights(tag: &str) -> TempDir {
        let dir = std::env::temp_dir().join(format!("fastav-w-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (vocab, d, ff, l) = (6usize, 4usize, 8usize, 2usize);
        let specs: Vec<(&str, Vec<usize>)> = vec![
            ("emb", vec![vocab, d]),
            ("ln_f", vec![d]),
            ("layers.ln1", vec![l, d]),
            ("layers.wq", vec![l, d, d]),
            ("layers.wk", vec![l, d, d]),
            ("layers.wv", vec![l, d, d]),
            ("layers.wo", vec![l, d, d]),
            ("layers.ln2", vec![l, d]),
            ("layers.wg", vec![l, d, ff]),
            ("layers.wu", vec![l, d, ff]),
            ("layers.wd", vec![l, ff, d]),
        ];
        let mut bin = std::fs::File::create(dir.join("weights.bin")).unwrap();
        let mut tensors = Vec::new();
        let mut offset = 0usize;
        for (i, (name, shape)) in specs.iter().enumerate() {
            let n: usize = shape.iter().product();
            // Deterministic fill: tensor index + element index / 1000.
            for e in 0..n {
                bin.write_all(&((i as f32) + e as f32 / 1000.0).to_le_bytes()).unwrap();
            }
            let dims: Vec<String> = shape.iter().map(|s| s.to_string()).collect();
            tensors.push(format!(
                r#"{{"name":"{}","shape":[{}],"offset":{}}}"#,
                name,
                dims.join(","),
                offset
            ));
            offset += n;
        }
        let manifest = format!(
            r#"{{"tensors":[{}],"total_elements":{}}}"#,
            tensors.join(","),
            offset
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        TempDir(dir)
    }

    #[test]
    fn loads_and_indexes() {
        let d = fake_weights("load");
        let w = Weights::load(&d.0).unwrap();
        assert_eq!(w.emb.shape, vec![6, 4]);
        assert_eq!(w.layers.len(), 9);
        assert_eq!(w.layers[0].name, "layers.ln1");
        // embed() slices the right row: row 2 of emb starts at elem 8.
        let row = w.embed(2);
        assert!((row[0] - 0.008).abs() < 1e-6);
    }

    #[test]
    fn embed_into_pads() {
        let d = fake_weights("embed");
        let w = Weights::load(&d.0).unwrap();
        let mut dst = vec![0.0f32; 4 * 4];
        w.embed_into(&[1, 3], &mut dst);
        assert!((dst[0] - 0.004).abs() < 1e-6); // emb row 1 elem 0
        assert_eq!(dst[8..], vec![0.0; 8][..]); // padding untouched
    }

    /// Config matching the `fake_weights` geometry (d=4, H=2, L=2).
    fn fake_cfg() -> ModelConfig {
        ModelConfig {
            name: "fake".into(),
            vocab: 6,
            d_model: 4,
            n_heads: 2,
            d_head: 2,
            n_layers: 2,
            mid_layer: 1,
            d_ff: 8,
            rope_theta: 10000.0,
            rollout_alpha: 0.6,
            layout: crate::tokens::Layout {
                frames: 1,
                vis_per_frame: 1,
                aud_len: 1,
                aud_per_frame: 1,
                interleaved: false,
            },
            prefill_buckets: vec![8],
            seq_buckets: vec![8],
            calib_buckets: vec![8],
            batch_buckets: vec![],
            tp_degree: 2,
            weights_dir: "fake".into(),
            kernel_impl: "jnp".into(),
        }
    }

    #[test]
    fn shard_literals_slice_head_columns() {
        let d = fake_weights("shards");
        let w = Weights::load(&d.0).unwrap();
        let cfg = fake_cfg();
        let sw = ShardWeightLiterals::build(&w, &cfg, 2).unwrap();
        assert_eq!(sw.qkv.len(), 2); // layers
        assert_eq!(sw.qkv[0].len(), 2); // shards
        assert_eq!(sw.qkv[0][0].len(), 3); // wq/wk/wv
        // wq layer 0 shard 1: columns 2..4 of the [4, 4] matrix. The fake
        // fill is tensor_index + elem/1000 with wq at tensor index 3.
        let wq_s1 = sw.qkv[0][1][0].to_vec::<f32>().unwrap();
        assert_eq!(wq_s1.len(), 4 * 2);
        assert!((wq_s1[0] - 3.002).abs() < 1e-6); // row 0, col 2
        assert!((wq_s1[2] - 3.006).abs() < 1e-6); // row 1, col 2
        // emb shard 0: columns 0..2 of the [6, 4] embedding (tensor 0).
        let emb0 = sw.emb[0].to_vec::<f32>().unwrap();
        assert_eq!(emb0.len(), 6 * 2);
        assert!((emb0[2] - 0.004).abs() < 1e-6); // row 1, col 0
        // tp must divide the head count.
        assert!(ShardWeightLiterals::build(&w, &cfg, 3).is_err());
    }

    #[test]
    fn mesh_build_skips_fused_only_literals() {
        let d = fake_weights("lean");
        let w = Weights::load(&d.0).unwrap();
        let cfg = fake_cfg();
        let full = WeightLiterals::build(&w, &cfg).unwrap();
        assert_eq!(full.per_layer[0].len(), 9);
        assert!(full.emb.is_some());
        assert_eq!(full.front.len(), 9);
        let lean = WeightLiterals::build_with(&w, &cfg, false).unwrap();
        assert_eq!(lean.per_layer[0].len(), 6, "QKV dropped on mesh builds");
        assert!(lean.emb.is_none());
        assert!(lean.front.is_empty());
        assert_eq!(lean.full_stack.len(), 9, "calib stack kept");
        // [0] is ln1 and the last five are the combine-stage params in
        // both layouts (the contract the engine's tail slices rely on).
        assert_eq!(
            lean.per_layer[1][0].to_vec::<f32>().unwrap(),
            full.per_layer[1][0].to_vec::<f32>().unwrap()
        );
        for (a, b) in full.per_layer[1][4..].iter().zip(&lean.per_layer[1][1..]) {
            assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        }
    }

    #[test]
    fn missing_tensor_errors() {
        let d = fake_weights("missing");
        // Corrupt the manifest: drop 'emb'.
        let m = std::fs::read_to_string(d.0.join("manifest.json")).unwrap();
        std::fs::write(d.0.join("manifest.json"), m.replace("\"emb\"", "\"em\"")).unwrap();
        assert!(Weights::load(&d.0).is_err());
    }

    #[test]
    fn out_of_range_offset_errors() {
        let d = fake_weights("range");
        let m = std::fs::read_to_string(d.0.join("manifest.json")).unwrap();
        std::fs::write(
            d.0.join("manifest.json"),
            m.replace(r#""offset":0"#, r#""offset":999999"#),
        )
        .unwrap();
        assert!(Weights::load(&d.0).is_err());
    }
}
