//! Weights loading: `weights.bin` + `manifest.json` → host tensors and
//! prebuilt XLA literals (the rust half of `python/compile/export.py`).
//!
//! Stacked per-layer tensors keep their `[L, ...]` leading axis in the
//! file, so the contiguous `[0..mid)` slab feeds the fused front-half
//! artifact without copying, and row `l` feeds single-layer artifacts.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use super::config::ModelConfig;
use crate::runtime::literals::lit_f32;
use crate::util::json::Json;

/// Per-layer parameter names in artifact ABI order (mirrors python
/// `LAYER_PARAM_NAMES`).
pub const LAYER_PARAM_NAMES: [&str; 9] =
    ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd"];

/// One named tensor: shape + the elements (host copy).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// All model weights on the host.
#[derive(Debug)]
pub struct Weights {
    pub emb: Tensor,
    pub ln_f: Tensor,
    /// Stacked per-layer tensors, keyed in LAYER_PARAM_NAMES order.
    pub layers: Vec<Tensor>,
}

impl Weights {
    /// Load from a model weights directory.
    pub fn load(dir: &Path) -> Result<Weights> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("manifest in {:?} (run `make artifacts`)", dir))?;
        let manifest =
            Json::parse(&manifest_text).map_err(|e| anyhow!("manifest.json: {}", e))?;
        let raw = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("weights.bin in {:?}", dir))?;
        if raw.len() % 4 != 0 {
            bail!("weights.bin size {} not a multiple of 4", raw.len());
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut tensors = std::collections::BTreeMap::new();
        for t in manifest
            .get("tensors")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: tensors[] missing"))?
        {
            let name = t
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("tensor name"))?
                .to_string();
            let shape: Vec<usize> = t
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("tensor shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?;
            let offset = t
                .get("offset")
                .as_usize()
                .ok_or_else(|| anyhow!("tensor offset"))?;
            let n: usize = shape.iter().product();
            if offset + n > floats.len() {
                bail!("tensor {} [{}..{}] exceeds file ({})", name, offset, offset + n, floats.len());
            }
            tensors.insert(
                name.clone(),
                Tensor { name, shape, data: floats[offset..offset + n].to_vec() },
            );
        }

        let take = |name: &str| -> Result<Tensor> {
            tensors
                .get(name)
                .cloned()
                .ok_or_else(|| anyhow!("manifest missing tensor '{}'", name))
        };
        let emb = take("emb")?;
        let ln_f = take("ln_f")?;
        let layers = LAYER_PARAM_NAMES
            .iter()
            .map(|p| take(&format!("layers.{}", p)))
            .collect::<Result<Vec<_>>>()?;
        Ok(Weights { emb, ln_f, layers })
    }

    /// Validate shapes against a model config.
    pub fn check(&self, cfg: &ModelConfig) -> Result<()> {
        if self.emb.shape != [cfg.vocab, cfg.d_model] {
            bail!("emb shape {:?} != [{}, {}]", self.emb.shape, cfg.vocab, cfg.d_model);
        }
        if self.ln_f.shape != [cfg.d_model] {
            bail!("ln_f shape {:?}", self.ln_f.shape);
        }
        for t in &self.layers {
            if t.shape[0] != cfg.n_layers {
                bail!("{} leading dim {} != n_layers {}", t.name, t.shape[0], cfg.n_layers);
            }
        }
        Ok(())
    }

    /// Embedding row for a token id.
    pub fn embed(&self, token: u32) -> &[f32] {
        let d = self.emb.shape[1];
        let i = token as usize;
        &self.emb.data[i * d..(i + 1) * d]
    }

    /// Gather embeddings for a prompt into `dst` (bucket-padded `[n, d]`).
    pub fn embed_into(&self, tokens: &[u32], dst: &mut [f32]) {
        let d = self.emb.shape[1];
        assert!(tokens.len() * d <= dst.len());
        for (i, &t) in tokens.iter().enumerate() {
            dst[i * d..(i + 1) * d].copy_from_slice(self.embed(t));
        }
    }
}

/// Prebuilt literals for every artifact parameter slot — built once at
/// engine startup, reused across all requests.
pub struct WeightLiterals {
    /// 9 stacked `[mid, ...]` literals for `prefill_front`.
    pub front: Vec<Literal>,
    /// 9 stacked `[L, ...]` literals for `calib_probe`.
    pub full_stack: Vec<Literal>,
    /// `per_layer[l]` = 9 single-layer literals for back/decode layers.
    pub per_layer: Vec<Vec<Literal>>,
    /// `ln_f` and `emb` for the logits head.
    pub ln_f: Literal,
    pub emb: Literal,
}

impl WeightLiterals {
    pub fn build(w: &Weights, cfg: &ModelConfig) -> Result<WeightLiterals> {
        let l = cfg.n_layers;
        let mid = cfg.mid_layer;
        let mut front = Vec::with_capacity(9);
        let mut full_stack = Vec::with_capacity(9);
        let mut per_layer: Vec<Vec<Literal>> = (0..l).map(|_| Vec::with_capacity(9)).collect();
        for t in &w.layers {
            let row = t.elems() / t.shape[0];
            let inner: Vec<usize> = t.shape[1..].to_vec();
            // Front slab: first `mid` rows, contiguous.
            let mut front_shape = vec![mid];
            front_shape.extend(&inner);
            front.push(lit_f32(&front_shape, &t.data[..mid * row])?);
            full_stack.push(lit_f32(&t.shape, &t.data)?);
            for (li, slot) in per_layer.iter_mut().enumerate() {
                slot.push(lit_f32(&inner, &t.data[li * row..(li + 1) * row])?);
            }
        }
        Ok(WeightLiterals {
            front,
            full_stack,
            per_layer,
            ln_f: lit_f32(&w.ln_f.shape, &w.ln_f.data)?,
            emb: lit_f32(&w.emb.shape, &w.emb.data)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    struct TempDir(std::path::PathBuf);

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    /// Write a synthetic 2-layer weights dir: d=4, ff=8, vocab=6.
    fn fake_weights(tag: &str) -> TempDir {
        let dir = std::env::temp_dir().join(format!("fastav-w-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (vocab, d, ff, l) = (6usize, 4usize, 8usize, 2usize);
        let specs: Vec<(&str, Vec<usize>)> = vec![
            ("emb", vec![vocab, d]),
            ("ln_f", vec![d]),
            ("layers.ln1", vec![l, d]),
            ("layers.wq", vec![l, d, d]),
            ("layers.wk", vec![l, d, d]),
            ("layers.wv", vec![l, d, d]),
            ("layers.wo", vec![l, d, d]),
            ("layers.ln2", vec![l, d]),
            ("layers.wg", vec![l, d, ff]),
            ("layers.wu", vec![l, d, ff]),
            ("layers.wd", vec![l, ff, d]),
        ];
        let mut bin = std::fs::File::create(dir.join("weights.bin")).unwrap();
        let mut tensors = Vec::new();
        let mut offset = 0usize;
        for (i, (name, shape)) in specs.iter().enumerate() {
            let n: usize = shape.iter().product();
            // Deterministic fill: tensor index + element index / 1000.
            for e in 0..n {
                bin.write_all(&((i as f32) + e as f32 / 1000.0).to_le_bytes()).unwrap();
            }
            let dims: Vec<String> = shape.iter().map(|s| s.to_string()).collect();
            tensors.push(format!(
                r#"{{"name":"{}","shape":[{}],"offset":{}}}"#,
                name,
                dims.join(","),
                offset
            ));
            offset += n;
        }
        let manifest = format!(
            r#"{{"tensors":[{}],"total_elements":{}}}"#,
            tensors.join(","),
            offset
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        TempDir(dir)
    }

    #[test]
    fn loads_and_indexes() {
        let d = fake_weights("load");
        let w = Weights::load(&d.0).unwrap();
        assert_eq!(w.emb.shape, vec![6, 4]);
        assert_eq!(w.layers.len(), 9);
        assert_eq!(w.layers[0].name, "layers.ln1");
        // embed() slices the right row: row 2 of emb starts at elem 8.
        let row = w.embed(2);
        assert!((row[0] - 0.008).abs() < 1e-6);
    }

    #[test]
    fn embed_into_pads() {
        let d = fake_weights("embed");
        let w = Weights::load(&d.0).unwrap();
        let mut dst = vec![0.0f32; 4 * 4];
        w.embed_into(&[1, 3], &mut dst);
        assert!((dst[0] - 0.004).abs() < 1e-6); // emb row 1 elem 0
        assert_eq!(dst[8..], vec![0.0; 8][..]); // padding untouched
    }

    #[test]
    fn missing_tensor_errors() {
        let d = fake_weights("missing");
        // Corrupt the manifest: drop 'emb'.
        let m = std::fs::read_to_string(d.0.join("manifest.json")).unwrap();
        std::fs::write(d.0.join("manifest.json"), m.replace("\"emb\"", "\"em\"")).unwrap();
        assert!(Weights::load(&d.0).is_err());
    }

    #[test]
    fn out_of_range_offset_errors() {
        let d = fake_weights("range");
        let m = std::fs::read_to_string(d.0.join("manifest.json")).unwrap();
        std::fs::write(
            d.0.join("manifest.json"),
            m.replace(r#""offset":0"#, r#""offset":999999"#),
        )
        .unwrap();
        assert!(Weights::load(&d.0).is_err());
    }
}
