//! Model layer: configuration, weights, and the staged execution engine.
//!
//! * [`config`]  — `model.json` parsing (hyperparameters + bucket grid).
//! * [`weights`] — `weights.bin`/`manifest.json` loading + prebuilt
//!   parameter literals.
//! * [`engine`]  — the request-path core: prefill front, global + fine
//!   pruning, back layers, decode loop, FLOPs/latency accounting.

pub mod config;
pub mod engine;
pub mod weights;

pub use config::ModelConfig;
pub use engine::{
    av_prefix_len, plan_effective_keep_len, plan_prefix_fingerprint, request_prefix_affinity,
    CalibProbe, GenerateOptions, GenerateResult, Generation, ModelEngine, PruningPlan,
    RequestInput, Sampling, StepEvent,
};
pub use weights::{ShardWeightLiterals, WeightLiterals, Weights};
