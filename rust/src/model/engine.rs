//! The staged execution engine: front half → global prune → back layers
//! with fine pruning → decode loop over per-layer KV caches.
//!
//! This is the request-path core. Every matrix multiplication happens
//! inside AOT-compiled XLA artifacts; this module owns control flow,
//! pruning decisions, cache bookkeeping, FLOPs/latency accounting, and
//! the embedding gather (a host-side table lookup).
//!
//! Pruning-start-layer generality (paper Fig. 4): the front half is a
//! fused artifact split at layer `g` — `prefill_front_<n>` for the default
//! `g == mid_layer`, `frontsplit<g>_<n>` otherwise. Global pruning always
//! happens at the split boundary; fine pruning follows in each later
//! layer.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::config::ModelConfig;
use super::weights::{ShardWeightLiterals, WeightLiterals, Weights};
use crate::flops::FlopsTally;
use crate::kvcache::prefix::{hash_mix, hash_tokens};
use crate::kvcache::{
    BlockPool, CacheSet, GatherBuf, LayerCache, PrefixCache, PrefixEntry, PrefixLease,
    ShardedLayerCache,
};
use crate::pruning::{
    fine_keep, global_keep, validate_keep, FineStrategy, GlobalInputs, GlobalStrategy,
};
use crate::runtime::literals::{lit_f32, lit_i32, lit_i32_scalar, to_vec_f32};
use crate::runtime::{ArtifactDir, DeviceMesh, ShardDispatch};
use crate::tokens::{Segment, EOS};

/// Salt mixed into `plan.seed` for the global stage's RNG, shared by
/// every site that computes a global keep set host-side (the prefill
/// path, the prefix-resume path, and the admission keep-budget estimate)
/// so they can never drift apart.
pub(crate) const GLOBAL_SEED_SALT: u64 = 0x61E0;

/// Complete pruning configuration for one request — the *resolved*,
/// engine-level form. The serving API carries the validated/hashable
/// [`crate::policy::PruningSpec`] wrapper and resolves it to this at the
/// engine boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct PruningPlan {
    pub global: GlobalStrategy,
    /// AV-token keep budget for the budget-matched ablation strategies.
    pub global_budget: usize,
    pub fine: FineStrategy,
    /// The paper's P (percent of remaining AV tokens dropped per layer).
    pub fine_percent: f64,
    pub seed: u64,
    /// Layer boundary where the global stage applies; `None` = cfg.mid_layer.
    pub global_layer: Option<usize>,
    /// Extension (LazyLLM-inspired, the paper's future-work direction):
    /// keep fine-pruning *during decode* using each step's importance row,
    /// compacting per-layer caches as generation proceeds.
    pub fine_during_decode: bool,
    /// Modality keep floors applied after the global stage (the
    /// earliest-position pruned tokens of a modality are added back
    /// until the floor is met). `0` = no floor.
    pub min_keep_vis: usize,
    pub min_keep_aud: usize,
}

impl PruningPlan {
    /// Vanilla inference: no pruning at all.
    pub fn vanilla() -> PruningPlan {
        PruningPlan {
            global: GlobalStrategy::None,
            global_budget: 0,
            fine: FineStrategy::None,
            fine_percent: 0.0,
            seed: 0,
            global_layer: None,
            fine_during_decode: false,
            min_keep_vis: 0,
            min_keep_aud: 0,
        }
    }

    /// The deployed FastAV policy (calibrated positional global pruning +
    /// low-attentive fine pruning at `p` percent).
    pub fn fastav(
        vis_cutoff: usize,
        keep_audio: usize,
        keep_frames: usize,
        p: f64,
    ) -> PruningPlan {
        PruningPlan {
            global: GlobalStrategy::FastAvPosition { vis_cutoff, keep_audio, keep_frames },
            fine: FineStrategy::LowAttentive,
            fine_percent: p,
            ..PruningPlan::vanilla()
        }
    }

    /// Whether the global stage consumes layer-`g` attention scores
    /// (those strategies run layer `g` unpruned first and apply the keep
    /// set after it).
    pub fn needs_scores(&self) -> bool {
        matches!(
            self.global,
            GlobalStrategy::TopAttentive
                | GlobalStrategy::LowAttentive
                | GlobalStrategy::FastV { .. }
        )
    }

    /// Whether the global stage consumes the attention-rollout probe.
    pub fn needs_rollout(&self) -> bool {
        matches!(
            self.global,
            GlobalStrategy::TopInformative | GlobalStrategy::LowInformative
        )
    }

    /// Whether this plan's AV-prefix KV is query-independent and may be
    /// published to / resumed from the shared prefix cache. Score- and
    /// rollout-guided global stages look at the question, so their keep
    /// sets are per-request and must never produce a positional-keep
    /// prefix entry.
    pub fn prefix_shareable(&self) -> bool {
        plan_prefix_fingerprint(self).is_some()
    }

    /// Build the [`GlobalInputs`] this plan feeds to
    /// [`crate::pruning::global_keep`] when no scores/rollout are needed.
    fn global_inputs<'a>(
        &self,
        segments: &'a [Segment],
        frame_of: &'a [i32],
        scores: Option<&'a [f32]>,
        rollout: Option<&'a [f32]>,
    ) -> GlobalInputs<'a> {
        GlobalInputs {
            segments,
            frame_of,
            scores,
            rollout,
            budget: self.global_budget,
            seed: self.seed ^ GLOBAL_SEED_SALT,
            min_keep_vis: self.min_keep_vis,
            min_keep_aud: self.min_keep_aud,
        }
    }
}

/// Host-side size of the live set entering the back layers under a
/// query-independent plan: the global keep-set length over this prompt
/// layout (the spec's *effective keep budget* — what serving admission
/// charges KV against). `None` when the global stage needs scores or
/// rollout, i.e. the keep set cannot be known before running the model.
pub fn plan_effective_keep_len(
    plan: &PruningPlan,
    segments: &[Segment],
    frame_of: &[i32],
) -> Option<usize> {
    plan_prefix_fingerprint(plan)?;
    let keep = global_keep(&plan.global, &plan.global_inputs(segments, frame_of, None, None));
    Some(keep.len())
}

/// Number of leading prompt tokens before the first text (question)
/// token — the shared audio-visual prefix. `None` when the prompt has no
/// AV prefix (starts with text), no text at all (nothing to resume), or
/// prunable AV tokens *after* the first text token: the resume path
/// replays the suffix verbatim (ctrl/text tokens are never pruned), so
/// a mixed suffix would diverge from the cold path's global keep set.
/// Standard avsynth layouts always end with the question, so this only
/// excludes hand-built mixed prompts.
pub fn av_prefix_len(segments: &[Segment]) -> Option<usize> {
    let p = segments.iter().position(|&s| s == Segment::Text)?;
    if p == 0 {
        return None;
    }
    if segments[p..]
        .iter()
        .any(|&s| s == Segment::Vis || s == Segment::Aud)
    {
        return None;
    }
    Some(p)
}

/// Fingerprint of everything about a pruning plan that decides the
/// post-global-prune AV-prefix KV, or `None` when the plan's global
/// stage is query-dependent (attention/rollout-guided strategies look at
/// the question, so their keep sets — unlike the deployed positional
/// policy's — are not shareable across requests).
pub fn plan_prefix_fingerprint(plan: &PruningPlan) -> Option<u64> {
    let strat: u64 = match plan.global {
        GlobalStrategy::None => 1,
        GlobalStrategy::Vtw => 2,
        GlobalStrategy::Random => 3,
        GlobalStrategy::FastAvPosition { vis_cutoff, keep_audio, keep_frames } => {
            hash_mix(&[4, vis_cutoff as u64, keep_audio as u64, keep_frames as u64])
        }
        GlobalStrategy::StreamingWindow { sink, recent } => {
            hash_mix(&[5, sink as u64, recent as u64])
        }
        // Query-guided global stages (scores/rollout) are per-question.
        GlobalStrategy::TopAttentive
        | GlobalStrategy::LowAttentive
        | GlobalStrategy::TopInformative
        | GlobalStrategy::LowInformative
        | GlobalStrategy::FastV { .. } => return None,
    };
    Some(hash_mix(&[
        strat,
        plan.global_budget as u64,
        plan.seed,
        plan.global_layer.map(|g| g as u64 + 1).unwrap_or(0),
        // Modality keep floors change the keep set, so they are part of
        // the prefix identity (specs differing only in the *fine* stage
        // still share entries — fine pruning happens after the split).
        plan.min_keep_vis as u64,
        plan.min_keep_aud as u64,
    ]))
}

/// Dispatch-affinity key for a request: requests sharing it produce the
/// same AV-prefix entry, so the pool routes them to the replica that
/// built it. `None` when the request cannot use the prefix cache.
pub fn request_prefix_affinity(
    prompt: &[u32],
    segments: &[Segment],
    plan: &PruningPlan,
) -> Option<u64> {
    let fp = plan_prefix_fingerprint(plan)?;
    let p = av_prefix_len(segments)?;
    if p >= prompt.len() {
        return None;
    }
    Some(hash_mix(&[fp, hash_tokens(0, &prompt[..p])]))
}

/// Token-selection parameters. `temperature == 0` is greedy (argmax);
/// otherwise softmax sampling at the given temperature, optionally
/// truncated to the `top_k` highest-probability tokens. Deterministic
/// under a fixed `seed`.
#[derive(Debug, Clone, PartialEq)]
pub struct Sampling {
    pub temperature: f64,
    pub top_k: usize,
    pub seed: u64,
}

impl Default for Sampling {
    fn default() -> Self {
        Sampling { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

/// Generation request options.
#[derive(Debug, Clone)]
pub struct GenerateOptions {
    pub plan: PruningPlan,
    pub max_gen: usize,
    pub sampling: Sampling,
}

impl Default for GenerateOptions {
    fn default() -> Self {
        GenerateOptions {
            plan: PruningPlan::vanilla(),
            max_gen: 4,
            sampling: Sampling::default(),
        }
    }
}

/// Select the next token from logits under the sampling parameters.
/// Pure function (unit-tested); `step` decorrelates successive draws.
pub fn select_token(logits: &[f32], s: &Sampling, step: usize) -> u32 {
    if s.temperature <= 0.0 {
        let mut best = 0usize;
        for i in 1..logits.len() {
            if logits[i] > logits[best] {
                best = i;
            }
        }
        return best as u32;
    }
    // Rank candidates, truncate to top_k (0 = no truncation).
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    let k = if s.top_k == 0 { idx.len() } else { s.top_k.min(idx.len()) };
    let idx = &idx[..k];
    let max = logits[idx[0]] as f64;
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - max) / s.temperature).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut rng = crate::util::rng::SplitMix64::new(
        s.seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let mut r = rng.next_f64() * total;
    for (w, &i) in weights.iter().zip(idx) {
        r -= w;
        if r <= 0.0 {
            return i as u32;
        }
    }
    idx[k - 1] as u32
}

/// Host-side all-reduce: accumulate one shard's partial output literal
/// into `acc`. Every mesh combine (logits partials, importance rows)
/// goes through this one reduction so the single-token, batched, and
/// prefill-shaped paths can never drift numerically.
fn add_partial(acc: &mut [f32], part: &xla::Literal) -> Result<()> {
    let part = to_vec_f32(part)?;
    if acc.len() != part.len() {
        // A silent zip-truncation here would sum only a prefix and emit
        // wrong logits with no diagnostic (stale/re-lowered artifacts).
        bail!(
            "shard partial has {} elements, expected {} (artifact set \
             out of sync with model.json?)",
            part.len(),
            acc.len()
        );
    }
    for (a, p) in acc.iter_mut().zip(part) {
        *a += p;
    }
    Ok(())
}

/// One prompt with its modality metadata.
pub struct RequestInput<'a> {
    pub prompt: &'a [u32],
    pub segments: &'a [Segment],
    pub frame_of: &'a [i32],
}

impl<'a> RequestInput<'a> {
    pub fn from_sample(s: &'a crate::avsynth::Sample) -> RequestInput<'a> {
        RequestInput { prompt: &s.prompt, segments: &s.segments, frame_of: &s.frame_of }
    }
}

/// Everything measured about one generation.
#[derive(Debug, Clone)]
pub struct GenerateResult {
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub flops: FlopsTally,
    pub relative_flops: f64,
    pub peak_kv_bytes: usize,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub decode_steps: usize,
    /// Live token count entering each layer during prefill.
    pub live_counts: Vec<usize>,
    /// Whether the AV-prefix KV was reused from the prefix cache.
    pub prefix_hit: bool,
    /// Prefix tokens whose front-half prefill was skipped on a hit.
    pub prefix_tokens_reused: usize,
}

/// Rollout/attention probe output (calibration path).
#[derive(Debug, Clone)]
pub struct CalibProbe {
    pub n_layers: usize,
    pub bucket: usize,
    pub prompt_len: usize,
    /// `[L, n, n]` row-major rollout stacks (R^1..R^L).
    pub rollout: Vec<f32>,
    /// `[L, n, n]` head-averaged raw attention per layer.
    pub attn: Vec<f32>,
}

impl CalibProbe {
    /// Rollout value R^layer[row, col] (`layer` counts layers applied, 1-based).
    pub fn rollout_at(&self, layer: usize, row: usize, col: usize) -> f32 {
        let n = self.bucket;
        self.rollout[((layer - 1) * n + row) * n + col]
    }

    pub fn attn_at(&self, layer: usize, row: usize, col: usize) -> f32 {
        let n = self.bucket;
        self.attn[((layer - 1) * n + row) * n + col]
    }

    /// Influence of every prompt token on the final query after `layer`
    /// layers (the last live row of R^layer) — the "informativeness" signal.
    pub fn last_row(&self, layer: usize) -> Vec<f32> {
        (0..self.prompt_len)
            .map(|j| self.rollout_at(layer, self.prompt_len - 1, j))
            .collect()
    }
}

/// What one [`ModelEngine::step_generation`] call accomplished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepEvent {
    /// One chunked-prefill unit (a single back layer) ran; no token yet.
    Prefilled { layer: usize },
    /// A token was decided — the first token when prefill completes, or
    /// one decode step afterwards.
    Token(u32),
    /// The generation had already finished; nothing ran.
    Done,
}

/// Resumable in-flight generation state.
///
/// Produced by [`ModelEngine::begin_generation`], advanced one quantum
/// at a time by [`ModelEngine::step_generation`], and consumed by
/// [`ModelEngine::finish_generation`]. Holding the state outside the
/// engine is what lets the serving layer interleave decode steps of
/// many requests on one engine (continuous-batching-style scheduling):
/// everything a request owns — live rows, per-layer caches, FLOPs tally,
/// emitted tokens — travels in this struct.
pub struct Generation {
    opts: GenerateOptions,
    prompt_len: usize,
    /// Original full-prompt modality tags (decode-time fine pruning
    /// re-derives segment classes from cache positions).
    segments_src: Vec<Segment>,
    /// Global-pruning split depth for this request.
    g: usize,
    h_live: Vec<f32>,
    positions: Vec<i32>,
    segments: Vec<Segment>,
    /// Next back layer to run; `== n_layers` once prefill is complete.
    next_layer: usize,
    caches: CacheSet,
    flops: FlopsTally,
    live_counts: Vec<usize>,
    tokens: Vec<u32>,
    decode_steps: usize,
    prefill_seconds: f64,
    decode_seconds: f64,
    done: bool,
    /// Pin on the prefix-cache entry this generation resumed from (kept
    /// for the generation's lifetime so eviction can't race the blocks).
    prefix_lease: Option<PrefixLease>,
    /// Prefix tokens reused on a hit (0 on miss).
    prefix_tokens_reused: usize,
}

impl Generation {
    /// Current KV-cache footprint (serving admission accounting).
    pub fn kv_bytes(&self) -> usize {
        self.caches.bytes()
    }

    /// Tokens emitted so far.
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// True while back layers are still being prefilled.
    pub fn is_prefilling(&self) -> bool {
        self.tokens.is_empty() && !self.done
    }

    /// Decode-ready: prefill complete (first token emitted) and the
    /// generation still running — exactly the set a fused
    /// [`ModelEngine::step_decode_batch`] dispatch can advance together.
    pub fn is_decoding(&self) -> bool {
        !self.done && !self.tokens.is_empty()
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    pub fn decode_steps(&self) -> usize {
        self.decode_steps
    }

    /// Whether this generation resumed from a cached AV prefix.
    pub fn prefix_hit(&self) -> bool {
        self.prefix_lease.is_some()
    }

    pub fn prefix_tokens_reused(&self) -> usize {
        self.prefix_tokens_reused
    }

    /// Eagerly release this generation's KV blocks (terminal cleanup:
    /// the request finished, was canceled, or expired). The peak-bytes
    /// watermark, pruning trace, and prefix lease survive so
    /// [`ModelEngine::finish_generation`]'s result accounting is
    /// unchanged; only the block references drop — the pool reclaims
    /// non-prefix-shared blocks in the same quantum rather than when
    /// the request (or its still-draining stream) is torn down.
    pub fn release_kv(&mut self) {
        self.caches.release();
    }

    fn update_done(&mut self) {
        let last = *self.tokens.last().expect("update_done before first token");
        self.done = self.tokens.len() >= self.opts.max_gen || last == EOS;
    }
}

/// Per-front-layer K/V slabs produced by the prefill front stage, one
/// `[Hs, src_n, dh]` slab per shard per layer. The fused tp_degree = 1
/// front emits a single stacked `[g, H, src_n, dh]` pair (borrowed
/// zero-copy as the one-shard case); the mesh front collects per-layer,
/// per-shard slabs as it runs.
enum FrontSlabs {
    /// Fused front output: `[g, H, src_n, dh]` stacked K and V.
    Stacked { ks: Vec<f32>, vs: Vec<f32>, stride: usize },
    /// `layers[l][s]` = shard `s`'s `[Hs, src_n, dh]` K/V of layer `l`.
    Sharded { layers: Vec<Vec<(Vec<f32>, Vec<f32>)>> },
}

struct FrontKv {
    slabs: FrontSlabs,
    /// Row count of every slab (the prefill bucket).
    src_n: usize,
}

impl FrontKv {
    fn shards(&self) -> usize {
        match &self.slabs {
            FrontSlabs::Stacked { .. } => 1,
            FrontSlabs::Sharded { layers } => layers[0].len(),
        }
    }

    /// Layer `l`, shard `s` K/V slab (`[Hs, src_n, dh]` row-major).
    fn slab(&self, l: usize, s: usize) -> (&[f32], &[f32]) {
        match &self.slabs {
            FrontSlabs::Stacked { ks, vs, stride } => {
                debug_assert_eq!(s, 0);
                (&ks[l * stride..(l + 1) * stride], &vs[l * stride..(l + 1) * stride])
            }
            FrontSlabs::Sharded { layers } => {
                let (k, v) = &layers[l][s];
                (k, v)
            }
        }
    }
}

/// One fully staged batched-decode layer: everything the dispatch needs
/// except the hidden-state literal (which depends on the previous
/// layer's output). Built by `ModelEngine::stage_batch_layer`, one layer
/// ahead of the in-flight dispatch on the pipelined path.
struct StagedBatchLayer {
    cap: usize,
    /// Pre-append live length per generation (FLOPs + append bookkeeping).
    ctxs: Vec<usize>,
    m_lit: xla::Literal,
    ci_lit: xla::Literal,
    kc: xla::Literal,
    vc: xla::Literal,
}

/// The engine: one model on a device mesh (one PJRT runtime per logical
/// device), prebuilt weight literals. The single-device engine is the
/// `tp_degree = 1` case of the mesh executor — same struct, same code
/// path, a mesh of one.
pub struct ModelEngine {
    pub cfg: ModelConfig,
    mesh: DeviceMesh,
    /// Devices the model is sharded over (`mesh.tp()`; 1 = unsharded).
    tp: usize,
    art: ArtifactDir,
    weights: Weights,
    wlit: WeightLiterals,
    /// Per-shard QKV/emb column slices (`None` at tp_degree = 1).
    shard_wlit: Option<ShardWeightLiterals>,
    /// Lazily-built front slabs for non-default split depths (Fig. 4).
    front_slabs: HashMap<usize, Vec<xla::Literal>>,
    /// Shared AV-prefix KV cache (attached by the serving pool; `None`
    /// on the one-shot eval/bench paths, where every request is a miss).
    prefix_cache: Option<Arc<PrefixCache>>,
    /// Reused upload buffers for the per-step paged-cache gather
    /// (`LayerCache::padded_kv_fill`) — the decode hot path allocates
    /// nothing per quantum. Sized once to the high-water bucket
    /// (largest decode bucket) and sliced per call, so alternating
    /// small/large contexts never reallocate. On the mesh path the
    /// same buffers are reused shard-after-shard (literal builds copy).
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    /// Batched-decode upload buffers: `[B, H, cap, dh]` at the joint
    /// (batch-bucket, seq-bucket) high-water mark, grow-only.
    scratch_bk: Vec<f32>,
    scratch_bv: Vec<f32>,
    /// Pipelined batched decode (tp_degree = 1): overlap layer `l+1`'s
    /// paged-cache gather + literal build with layer `l`'s in-flight
    /// dispatch, and reuse per-layer staging buffers for delta-append
    /// uploads. Token-for-token identical to the sequential ordering
    /// (`--pipeline off`); pinned by `rust/tests/test_pipeline.rs` and
    /// the `GatherBuf` property tests.
    pipeline: bool,
    /// One persistent [`GatherBuf`] per layer: cross-quantum delta
    /// validity needs the same layer's caches to land in the same
    /// buffer every quantum. Sized lazily; freed by `set_pipeline(false)`.
    batch_gather: Vec<GatherBuf>,
}

impl ModelEngine {
    /// Load a model from `artifact_root/<model>` (artifacts + config) and
    /// `artifact_root/<weights_dir>` (checkpoint), unsharded.
    pub fn load(artifact_root: &std::path::Path, model: &str) -> Result<ModelEngine> {
        Self::load_with_tp(artifact_root, model, 1)
    }

    /// [`Self::load`] at an explicit tensor-parallel degree: `tp > 1`
    /// builds a [`DeviceMesh`] of `tp` devices, per-shard weight slices,
    /// and requires the artifact set to carry the matching
    /// `*_shard<s>of<tp>` entries (lowered when the python config's
    /// `tp_degree` equals `tp`).
    pub fn load_with_tp(
        artifact_root: &std::path::Path,
        model: &str,
        tp: usize,
    ) -> Result<ModelEngine> {
        let tp = tp.max(1);
        let dir = artifact_root.join(model);
        let cfg = ModelConfig::load(&dir.join("model.json"))?;
        let art = ArtifactDir::open(&dir)?;
        if tp > 1 {
            if cfg.n_heads % tp != 0 || cfg.d_model % tp != 0 {
                bail!(
                    "tp {} must divide n_heads {} and d_model {}",
                    tp,
                    cfg.n_heads,
                    cfg.d_model
                );
            }
            let probe = format!("layer_shard0of{}", tp);
            if !art.has_entry(&probe) {
                bail!(
                    "model '{}' has no '{}' artifacts — re-lower with tp_degree={} \
                     (model.json was lowered with tp_degree={})",
                    cfg.name,
                    probe,
                    tp,
                    cfg.tp_degree
                );
            }
        }
        let weights = Weights::load(&artifact_root.join(&cfg.weights_dir))?;
        weights.check(&cfg)?;
        // Mesh builds skip the fused-only literals (front slab, full-head
        // QKV, tied unembedding) — the sharded artifacts never take them.
        let wlit = WeightLiterals::build_with(&weights, &cfg, tp == 1)?;
        let shard_wlit = if tp > 1 {
            Some(ShardWeightLiterals::build(&weights, &cfg, tp)?)
        } else {
            None
        };
        let mesh = DeviceMesh::cpu(tp)?;
        // High-water scratch: one slab at the largest decode bucket per
        // K/V; shrinking bucket picks slice it instead of reallocating.
        let hw = cfg.seq_buckets.iter().copied().max().unwrap_or(0)
            * cfg.n_heads
            * cfg.d_head;
        Ok(ModelEngine {
            cfg,
            mesh,
            tp,
            art,
            weights,
            wlit,
            shard_wlit,
            front_slabs: HashMap::new(),
            prefix_cache: None,
            scratch_k: vec![0.0; hw],
            scratch_v: vec![0.0; hw],
            scratch_bk: Vec::new(),
            scratch_bv: Vec::new(),
            pipeline: true,
            batch_gather: Vec::new(),
        })
    }

    /// Tensor-parallel degree this engine executes at (mesh devices).
    pub fn tp_degree(&self) -> usize {
        self.tp
    }

    /// Enable/disable the pipelined batched-decode path (`--pipeline`).
    /// Off forces the original strict upload → dispatch ordering for
    /// A/B comparison and drops the per-layer staging buffers (their
    /// validity state must not survive a disable/enable cycle — the
    /// fresh buffers re-gather everything).
    pub fn set_pipeline(&mut self, on: bool) {
        self.pipeline = on;
        if !on {
            self.batch_gather = Vec::new();
        }
    }

    /// Whether the pipelined batched-decode path is active.
    pub fn pipeline(&self) -> bool {
        self.pipeline
    }

    /// Attach a shared prefix cache. Subsequent `begin_generation` calls
    /// with a query-independent (positional) global-pruning plan consult
    /// it, resume mid-sequence on a hit, and insert the AV prefix on a
    /// miss.
    pub fn set_prefix_cache(&mut self, cache: Arc<PrefixCache>) {
        self.prefix_cache = Some(cache);
    }

    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.prefix_cache.as_ref()
    }

    /// Cache config key for this engine + plan: what must match for a
    /// stored AV-prefix entry to be reusable. The tokenized prefix itself
    /// is the trie key *under* this config key.
    fn prefix_config_key(&self, plan: &PruningPlan, g: usize) -> Option<u64> {
        let fp = plan_prefix_fingerprint(plan)?;
        let name: Vec<u32> = self.cfg.name.bytes().map(|b| b as u32).collect();
        Some(hash_mix(&[
            fp,
            g as u64,
            hash_tokens(1, &[self.cfg.n_heads as u32, self.cfg.d_head as u32]),
            hash_tokens(2, &name),
        ]))
    }

    /// Admission probe: the shareable AV-prefix bytes already resident
    /// for a request (counted once across concurrent users by
    /// `serving::Admission`), keyed by the cache entry. `None` when no
    /// cache is attached or the request is not coverable.
    pub fn prefix_shared_estimate(
        &self,
        prompt: &[u32],
        segments: &[Segment],
        frame_of: &[i32],
        plan: &PruningPlan,
    ) -> Option<(u64, usize)> {
        if self.tp != 1 {
            return None; // sharded engines neither insert nor resume
        }
        let cache = self.prefix_cache.as_ref()?;
        let g = plan.global_layer.unwrap_or(self.cfg.mid_layer);
        let base = self.prefix_config_key(plan, g)?;
        let p = av_prefix_len(segments)?;
        if p >= prompt.len() {
            return None;
        }
        let cfg_key = hash_mix(&[base, Self::layout_fingerprint(segments, frame_of, p)]);
        cache.peek(cfg_key, &prompt[..p])
    }

    pub fn artifacts(&self) -> &ArtifactDir {
        &self.art
    }

    /// (compiled executables, total executions) summed over mesh devices
    /// — cache-health telemetry.
    pub fn runtime_stats(&self) -> (usize, u64) {
        self.mesh.stats()
    }

    /// Pre-compile exactly the artifact set this engine dispatches
    /// (fused entries at tp_degree = 1; the per-shard entries on their
    /// own devices plus the combine stages on the mesh — the fused set
    /// is unreachable there and is *not* compiled) so first-request
    /// latency excludes XLA compilation.
    pub fn warmup(&mut self) -> Result<()> {
        if self.tp == 1 {
            let mut entries: Vec<String> = ["prefill_front", "back_layer", "decode_layer"]
                .iter()
                .map(|s| s.to_string())
                .collect();
            for &bb in &self.cfg.batch_buckets {
                let entry = format!("decode_batch{}", bb);
                if self.art.has_entry(&entry) {
                    entries.push(entry);
                }
            }
            let mut paths = Vec::new();
            for entry in &entries {
                for &b in self.art.buckets(entry) {
                    paths.push(self.art.path(entry, Some(b)));
                }
            }
            paths.push(self.art.path("logits", None));
            for &bb in self.art.buckets("logits_batch") {
                paths.push(self.art.path("logits_batch", Some(bb)));
            }
            for p in paths {
                self.mesh.load(&p)?;
            }
            return Ok(());
        }
        // Mesh path. Combine stages run on device 0.
        let mut paths = Vec::new();
        for &b in self.art.buckets("layer_tail") {
            paths.push(self.art.path("layer_tail", Some(b)));
        }
        paths.push(self.art.path("decode_tail", None));
        for &bb in self.art.buckets("decode_batch_tail") {
            paths.push(self.art.path("decode_batch_tail", Some(bb)));
        }
        for p in paths {
            self.mesh.load(&p)?;
        }
        // Per-shard entries compile on their own devices.
        for s in 0..self.tp {
            let mut shard_paths = Vec::new();
            for base in ["layer_shard", "decode_shard"] {
                let entry = format!("{}{}of{}", base, s, self.tp);
                for &b in self.art.buckets(&entry) {
                    shard_paths.push(self.art.path(&entry, Some(b)));
                }
            }
            for &bb in &self.cfg.batch_buckets {
                let entry = format!("decode_batch{}_shard{}of{}", bb, s, self.tp);
                for &b in self.art.buckets(&entry) {
                    shard_paths.push(self.art.path(&entry, Some(b)));
                }
            }
            let logits_entry = format!("logits_shard{}of{}", s, self.tp);
            if self.art.has_entry(&logits_entry) {
                shard_paths.push(self.art.path(&logits_entry, None));
            }
            let batch_logits_entry = format!("logits_batch_shard{}of{}", s, self.tp);
            for &bb in self.art.buckets(&batch_logits_entry) {
                shard_paths.push(self.art.path(&batch_logits_entry, Some(bb)));
            }
            for p in shard_paths {
                self.mesh.load_on(s, &p)?;
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------ helpers

    fn fm(&self) -> crate::flops::FlopsModel {
        self.cfg.flops_model()
    }

    /// Front artifact entry name for a split depth.
    fn front_entry(&self, g: usize) -> String {
        if g == self.cfg.mid_layer {
            "prefill_front".to_string()
        } else {
            format!("frontsplit{}", g)
        }
    }

    /// Ensure the stacked front-weight literals for split depth `g` exist
    /// (prefix slab of the stacked per-layer tensors; cached).
    fn ensure_front_slab(&mut self, g: usize) -> Result<()> {
        if g == self.cfg.mid_layer || self.front_slabs.contains_key(&g) {
            return Ok(());
        }
        let mut slab = Vec::with_capacity(9);
        for t in &self.weights.layers {
            let row = t.elems() / t.shape[0];
            let mut shape = vec![g];
            shape.extend(&t.shape[1..]);
            slab.push(lit_f32(&shape, &t.data[..g * row])?);
        }
        self.front_slabs.insert(g, slab);
        Ok(())
    }

    /// Build the (mask, positions) literal pair padded to `bucket`.
    fn mask_positions(
        &self,
        live_positions: &[i32],
        bucket: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let mut mask = vec![0.0f32; bucket];
        let mut pos = vec![0i32; bucket];
        for (i, &p) in live_positions.iter().enumerate() {
            mask[i] = 1.0;
            pos[i] = p;
        }
        Ok((lit_f32(&[bucket], &mask)?, lit_i32(&[bucket], &pos)?))
    }

    /// `<base><s>of<D>` — the per-shard artifact entry name.
    fn shard_entry(&self, base: &str, s: usize) -> String {
        format!("{}{}of{}", base, s, self.tp)
    }

    /// Run the logits head on a hidden vector. At tp > 1 each device
    /// computes a vocab partial over its `d/D` column slice of the tied
    /// unembedding; the partials are summed host-side (all-reduce).
    ///
    /// §Perf note: a device-resident-weights variant via `execute_b` was
    /// measured but the xla 0.1.6 PJRT wrapper appears to donate input
    /// buffers on execution (reuse segfaults); see EXPERIMENTS.md §Perf.
    fn logits(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let x_lit = lit_f32(&[self.cfg.d_model], x)?;
        if self.tp == 1 {
            let path = self.art.path("logits", None);
            let emb = self.wlit.emb.as_ref().expect("fused build carries emb");
            let outs = self.mesh.execute(&path, &[&x_lit, &self.wlit.ln_f, emb])?;
            return to_vec_f32(&outs[0]);
        }
        let sw = self.shard_wlit.as_ref().expect("tp > 1 implies shard weights");
        let dispatches: Vec<ShardDispatch> = (0..self.tp)
            .map(|s| ShardDispatch {
                path: self.art.path(&self.shard_entry("logits_shard", s), None),
                inputs: vec![&x_lit, &self.wlit.ln_f, &sw.emb[s]],
            })
            .collect();
        let outs = self.mesh.execute_sharded(&dispatches)?;
        let mut sum = vec![0.0f32; self.cfg.vocab];
        for shard in &outs {
            add_partial(&mut sum, &shard[0])?;
        }
        Ok(sum)
    }

    /// Batched logits head: one `logits_batch` dispatch (or one
    /// `logits_batch_shard` dispatch per device, partials summed) for all
    /// `b` rows of `xs` (`[b, d]`, row-major). Falls back to `b`
    /// single-vector [`Self::logits`] calls when the artifact set
    /// predates the batched head. Padding rows beyond `b` are zero and
    /// their (zero) logits rows are dropped.
    fn logits_rows(&mut self, xs: &[f32], b: usize) -> Result<Vec<Vec<f32>>> {
        let d = self.cfg.d_model;
        debug_assert_eq!(xs.len() % d, 0);
        let entry = if self.tp == 1 {
            "logits_batch".to_string()
        } else {
            self.shard_entry("logits_batch_shard", 0)
        };
        let bucket = match self.art.pick_bucket(&entry, b) {
            Ok(bb) if b >= 2 => bb,
            _ => {
                // No batched head (or a single row): per-row dispatches.
                let mut rows = Vec::with_capacity(b);
                for i in 0..b {
                    rows.push(self.logits(&xs[i * d..(i + 1) * d])?);
                }
                return Ok(rows);
            }
        };
        let mut x_pad = vec![0.0f32; bucket * d];
        x_pad[..b * d].copy_from_slice(&xs[..b * d]);
        let x_lit = lit_f32(&[bucket, d], &x_pad)?;
        let flat = if self.tp == 1 {
            let path = self.art.path("logits_batch", Some(bucket));
            let emb = self.wlit.emb.as_ref().expect("fused build carries emb");
            let outs = self.mesh.execute(&path, &[&x_lit, &self.wlit.ln_f, emb])?;
            to_vec_f32(&outs[0])?
        } else {
            let sw = self.shard_wlit.as_ref().expect("tp > 1 implies shard weights");
            let dispatches: Vec<ShardDispatch> = (0..self.tp)
                .map(|s| ShardDispatch {
                    path: self
                        .art
                        .path(&self.shard_entry("logits_batch_shard", s), Some(bucket)),
                    inputs: vec![&x_lit, &self.wlit.ln_f, &sw.emb[s]],
                })
                .collect();
            let outs = self.mesh.execute_sharded(&dispatches)?;
            let mut sum = vec![0.0f32; bucket * self.cfg.vocab];
            for shard in &outs {
                add_partial(&mut sum, &shard[0])?;
            }
            sum
        };
        let vocab = self.cfg.vocab;
        Ok((0..b).map(|i| flat[i * vocab..(i + 1) * vocab].to_vec()).collect())
    }

    /// Execute one back layer over the live rows. Returns (h', k, v, s)
    /// as host vectors sized to the bucket (tp_degree = 1 fused path).
    fn run_back_layer(
        &mut self,
        layer: usize,
        h_live: &[f32],
        live_positions: &[i32],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.cfg.d_model;
        let n_live = live_positions.len();
        let mut h_pad = vec![0.0f32; bucket * d];
        h_pad[..n_live * d].copy_from_slice(&h_live[..n_live * d]);
        let h_lit = lit_f32(&[bucket, d], &h_pad)?;
        let (mask, pos) = self.mask_positions(live_positions, bucket)?;
        let last_idx = lit_i32_scalar(n_live as i32 - 1)?;
        let path = self.art.path("back_layer", Some(bucket));
        let mut inputs: Vec<&xla::Literal> = vec![&h_lit, &mask, &pos, &last_idx];
        for p in &self.wlit.per_layer[layer] {
            inputs.push(p);
        }
        let outs = self.mesh.execute(&path, &inputs)?;
        let [h_out, k, v, s]: [xla::Literal; 4] = outs
            .try_into()
            .map_err(|_| anyhow!("back_layer returned wrong arity"))?;
        Ok((to_vec_f32(&h_out)?, to_vec_f32(&k)?, to_vec_f32(&v)?, to_vec_f32(&s)?))
    }

    /// Execute one prefill-shaped layer on the mesh: D `layer_shard`
    /// dispatches (one per device, each over its H/D heads), a host
    /// combine (concat attention outputs in head order, sum importance
    /// partials), and the `layer_tail` combine stage on device 0.
    /// Returns `(h', per-shard [Hs, bucket, dh] K/V, s)`.
    #[allow(clippy::type_complexity)]
    fn run_layer_sharded(
        &mut self,
        layer: usize,
        h_live: &[f32],
        live_positions: &[i32],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<(Vec<f32>, Vec<f32>)>, Vec<f32>)> {
        let d = self.cfg.d_model;
        let tp = self.tp;
        let hs_width = d / tp; // Hs * dh
        let n_live = live_positions.len();
        let up_t0 = crate::trace::seg_begin();
        let mut h_pad = vec![0.0f32; bucket * d];
        h_pad[..n_live * d].copy_from_slice(&h_live[..n_live * d]);
        let h_lit = lit_f32(&[bucket, d], &h_pad)?;
        let (mask, pos) = self.mask_positions(live_positions, bucket)?;
        let last_idx = lit_i32_scalar(n_live as i32 - 1)?;
        let sw = self.shard_wlit.as_ref().expect("tp > 1 implies shard weights");
        let ln1 = &self.wlit.per_layer[layer][0];
        let dispatches: Vec<ShardDispatch> = (0..tp)
            .map(|s| {
                let mut inputs: Vec<&xla::Literal> = vec![&h_lit, &mask, &pos, &last_idx, ln1];
                for w in &sw.qkv[layer][s] {
                    inputs.push(w);
                }
                ShardDispatch {
                    path: self
                        .art
                        .path(&self.shard_entry("layer_shard", s), Some(bucket)),
                    inputs,
                }
            })
            .collect();
        crate::trace::seg_end("upload", None, up_t0);
        let outs = self.mesh.execute_sharded(&dispatches)?;
        let dl_t0 = crate::trace::seg_begin();
        // Combine: attention concat (head order), importance all-reduce.
        let mut attn = vec![0.0f32; bucket * d];
        let mut s_sum = vec![0.0f32; bucket];
        let mut kv = Vec::with_capacity(tp);
        for (s, shard) in outs.iter().enumerate() {
            let [a, k, v, sp]: &[xla::Literal; 4] = shard
                .as_slice()
                .try_into()
                .map_err(|_| anyhow!("layer_shard returned wrong arity"))?;
            let a = to_vec_f32(a)?; // [bucket, Hs*dh]
            for row in 0..bucket {
                attn[row * d + s * hs_width..row * d + (s + 1) * hs_width]
                    .copy_from_slice(&a[row * hs_width..(row + 1) * hs_width]);
            }
            add_partial(&mut s_sum, sp)?;
            kv.push((to_vec_f32(k)?, to_vec_f32(v)?));
        }
        crate::trace::seg_end("download", None, dl_t0);
        let cb_t0 = crate::trace::seg_begin();
        let attn_lit = lit_f32(&[bucket, d], &attn)?;
        let tail_path = self.art.path("layer_tail", Some(bucket));
        let pl = &self.wlit.per_layer[layer];
        let mut tail_inputs: Vec<&xla::Literal> = vec![&h_lit, &attn_lit, &mask];
        for p in &pl[pl.len() - 5..] {
            tail_inputs.push(p);
        }
        let outs = self.mesh.execute(&tail_path, &tail_inputs)?;
        let h_out = to_vec_f32(&outs[0])?;
        crate::trace::seg_end("combine", None, cb_t0);
        Ok((h_out, kv, s_sum))
    }

    /// Unified prefill-shaped layer step: the fused single-device
    /// artifact at tp_degree = 1 (one shard covering all heads), the
    /// sharded mesh path otherwise. Returns `(h', per-shard K/V, s)`.
    #[allow(clippy::type_complexity)]
    fn run_layer(
        &mut self,
        layer: usize,
        h_live: &[f32],
        live_positions: &[i32],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<(Vec<f32>, Vec<f32>)>, Vec<f32>)> {
        if self.tp == 1 {
            let (h, k, v, s) = self.run_back_layer(layer, h_live, live_positions, bucket)?;
            Ok((h, vec![(k, v)], s))
        } else {
            self.run_layer_sharded(layer, h_live, live_positions, bucket)
        }
    }

    /// Compact live-state vectors to a keep set (indices into live rows).
    fn compact_live(
        h_live: &mut Vec<f32>,
        positions: &mut Vec<i32>,
        segments: &mut Vec<Segment>,
        keep: &[usize],
        d: usize,
    ) {
        let mut new_h = Vec::with_capacity(keep.len() * d);
        let mut new_p = Vec::with_capacity(keep.len());
        let mut new_s = Vec::with_capacity(keep.len());
        for &i in keep {
            new_h.extend_from_slice(&h_live[i * d..(i + 1) * d]);
            new_p.push(positions[i]);
            new_s.push(segments[i]);
        }
        *h_live = new_h;
        *positions = new_p;
        *segments = new_s;
    }

    /// Decode-path artifact entry whose bucket grid sizes caches: the
    /// fused single-token entry at tp_degree = 1, shard 0's entry on the
    /// mesh (all shards share one grid).
    fn decode_entry(&self) -> String {
        if self.tp == 1 {
            "decode_layer".to_string()
        } else {
            self.shard_entry("decode_shard", 0)
        }
    }

    /// Prefill-shaped layer entry whose bucket grid sizes back-layer
    /// dispatches (fused at tp_degree = 1, shard 0's grid on the mesh).
    fn layer_entry(&self) -> String {
        if self.tp == 1 {
            "back_layer".to_string()
        } else {
            self.shard_entry("layer_shard", 0)
        }
    }

    /// Cache capacity for a live set: the smallest decode bucket that fits
    /// `live + max_gen` appended tokens.
    fn cache_cap(&self, live: usize, max_gen: usize) -> Result<usize> {
        self.art.pick_bucket(&self.decode_entry(), live + max_gen)
    }

    /// Build one layer's (possibly sharded) cache by gathering `keep`
    /// rows from that layer's per-shard prefill K/V slabs.
    fn front_cache(
        &self,
        front: &FrontKv,
        layer: usize,
        keep: &[usize],
        cap: usize,
    ) -> ShardedLayerCache {
        let dh = self.cfg.d_head;
        let shards = (0..front.shards())
            .map(|s| {
                let (k, v) = front.slab(layer, s);
                let heads = k.len() / (front.src_n * dh);
                LayerCache::from_strided_rows(
                    BlockPool::global(),
                    heads,
                    dh,
                    cap,
                    k,
                    v,
                    front.src_n,
                    keep,
                )
            })
            .collect();
        ShardedLayerCache::from_shards(shards)
    }

    // ----------------------------------------------------------- generate

    /// Run one full generation (prefill + decode) under a pruning plan.
    pub fn generate(
        &mut self,
        input: &RequestInput,
        opts: &GenerateOptions,
    ) -> Result<GenerateResult> {
        self.generate_with(input, opts, |_| {})
    }

    /// [`Self::generate`] with a per-token streaming callback (invoked as
    /// each output token is decided, before the next decode step runs).
    ///
    /// Implemented on top of the resumable
    /// [`begin_generation`](Self::begin_generation) /
    /// [`step_generation`](Self::step_generation) /
    /// [`finish_generation`](Self::finish_generation) stages so the
    /// one-shot path and the serving step scheduler share one engine
    /// code path.
    pub fn generate_with(
        &mut self,
        input: &RequestInput,
        opts: &GenerateOptions,
        mut on_token: impl FnMut(u32),
    ) -> Result<GenerateResult> {
        let mut gen = self.begin_generation(input, opts)?;
        loop {
            match self.step_generation(&mut gen)? {
                StepEvent::Token(t) => on_token(t),
                StepEvent::Prefilled { .. } => {}
                StepEvent::Done => break,
            }
        }
        Ok(self.finish_generation(gen))
    }

    /// Start a resumable generation: embed the prompt, run the fused
    /// front half, apply global pruning, and seed the per-layer caches.
    /// The remaining back layers and every decode step are advanced one
    /// at a time by [`step_generation`](Self::step_generation), so a
    /// serving scheduler can interleave many in-flight generations on
    /// one engine (chunked prefill + iteration-level decode).
    pub fn begin_generation(
        &mut self,
        input: &RequestInput,
        opts: &GenerateOptions,
    ) -> Result<Generation> {
        let cfg = self.cfg.clone();
        let fm = self.fm();
        let d = cfg.d_model;
        let k = input.prompt.len();
        if k == 0 {
            bail!("empty prompt");
        }
        if k != input.segments.len() || k != input.frame_of.len() {
            bail!("prompt/segments/frame_of length mismatch");
        }
        let g = opts.plan.global_layer.unwrap_or(cfg.mid_layer);
        if g == 0 || g >= cfg.n_layers {
            bail!("global_layer {} outside [1, {})", g, cfg.n_layers);
        }
        let front_entry = self.front_entry(g);
        // The mesh path runs the front per layer through `layer_shard`
        // artifacts, which exist for every split depth; only the fused
        // tp_degree = 1 path needs a per-split front artifact.
        if self.tp == 1 && !self.art.has_entry(&front_entry) {
            bail!(
                "model '{}' has no '{}' artifact (emit_splits off?)",
                cfg.name,
                front_entry
            );
        }

        // --- Prefix-cache fast path: when a warm AV-prefix entry covers
        // this prompt under the same (positional) pruning config, resume
        // mid-sequence instead of re-prefilling the front half.
        if let Some(gen) = self.try_begin_from_prefix(input, opts, g)? {
            return Ok(gen);
        }

        let mut flops = FlopsTally::default();
        let mut live_counts = vec![k; g];
        let t_prefill = Instant::now();

        // --- Stage 1: front half (layers 0..g) over the full prompt —
        // one fused dispatch at tp_degree = 1, g per-layer mesh rounds
        // (D `layer_shard` dispatches + one `layer_tail`) otherwise.
        let all_pos: Vec<i32> = (0..k as i32).collect();
        let (h_rows, front) = if self.tp == 1 {
            let bucket_p = self.art.pick_bucket(&front_entry, k)?;
            let mut x_emb = vec![0.0f32; bucket_p * d];
            self.weights.embed_into(input.prompt, &mut x_emb);
            let x_lit = lit_f32(&[bucket_p, d], &x_emb)?;
            let (mask, pos) = self.mask_positions(&all_pos, bucket_p)?;
            let path = self.art.path(&front_entry, Some(bucket_p));
            self.ensure_front_slab(g)?;
            let outs = {
                // Disjoint field borrows: `slab` reads wlit/front_slabs
                // while `self.mesh.execute` mutates only `mesh`.
                let slab: &[xla::Literal] = if g == self.cfg.mid_layer {
                    &self.wlit.front
                } else {
                    self.front_slabs.get(&g).unwrap()
                };
                let mut inputs: Vec<&xla::Literal> = vec![&x_lit, &mask, &pos];
                for p in slab {
                    inputs.push(p);
                }
                self.mesh.execute(&path, &inputs)?
            };
            let [h_lit, k_stack, v_stack]: [xla::Literal; 3] = outs
                .try_into()
                .map_err(|_| anyhow!("front returned wrong arity"))?;
            let h_full = to_vec_f32(&h_lit)?; // [bucket_p, d]
            let ks = to_vec_f32(&k_stack)?; // [g, H, bucket_p, dh]
            let vs = to_vec_f32(&v_stack)?;
            for _ in 0..g {
                flops.add_prefill_layer(&fm, k, k);
            }
            let stride = self.cfg.n_heads * bucket_p * self.cfg.d_head;
            (
                h_full[..k * d].to_vec(),
                FrontKv { slabs: FrontSlabs::Stacked { ks, vs, stride }, src_n: bucket_p },
            )
        } else {
            let bucket_p = self
                .art
                .pick_bucket(&self.shard_entry("layer_shard", 0), k)?;
            let mut h = vec![0.0f32; k * d];
            self.weights.embed_into(input.prompt, &mut h);
            let mut layers = Vec::with_capacity(g);
            for l in 0..g {
                let (h2, kv, _s) = self.run_layer_sharded(l, &h, &all_pos, bucket_p)?;
                h = h2[..k * d].to_vec();
                layers.push(kv);
                flops.add_prefill_layer(&fm, k, k);
            }
            (h, FrontKv { slabs: FrontSlabs::Sharded { layers }, src_n: bucket_p })
        };

        // Live state (rows of h, original positions, modality).
        let mut h_live: Vec<f32> = h_rows;
        let mut positions: Vec<i32> = (0..k as i32).collect();
        let mut segments: Vec<Segment> = input.segments.to_vec();

        // --- Stage 2: global pruning at the split boundary. ---------------
        // Attention-score strategies need layer g's own attention: the
        // layer runs unpruned first and the keep applies after it.
        // Positional / random / rollout strategies prune before layer g
        // (paper semantics: tokens removed at the middle layer).
        let needs_scores = opts.plan.needs_scores();
        let needs_rollout = opts.plan.needs_rollout();

        let rollout_row: Option<Vec<f32>> = if needs_rollout {
            // Offline analysis pass; its FLOPs are calibration, not serving
            // cost (the deployed policy is positional — see DESIGN.md).
            let probe = self.calib_probe(input.prompt)?;
            Some(probe.last_row(g))
        } else {
            None
        };

        let mut next_layer = g;
        let mut mid_scores: Option<Vec<f32>> = None;
        let mut mid_kv: Option<(Vec<(Vec<f32>, Vec<f32>)>, usize)> = None;

        if needs_scores {
            let bucket = self
                .art
                .pick_bucket(&self.layer_entry(), positions.len())?;
            let (h2, kv, s) = self.run_layer(g, &h_live, &positions, bucket)?;
            live_counts.push(positions.len());
            flops.add_prefill_layer(&fm, positions.len(), positions.len());
            h_live = h2[..positions.len() * d].to_vec();
            mid_scores = Some(s[..positions.len()].to_vec());
            mid_kv = Some((kv, bucket));
            next_layer = g + 1;
        }

        let ginp = opts.plan.global_inputs(
            &segments,
            input.frame_of,
            mid_scores.as_deref(),
            rollout_row.as_deref(),
        );
        let keep = global_keep(&opts.plan.global, &ginp);
        validate_keep(&keep, &segments).map_err(|e| anyhow!("global keep invalid: {}", e))?;

        // Cache for layer g when it ran unpruned (tokens alive entering g
        // = the full prompt; kept unpruned, LazyLLM-style).
        let mut caches = CacheSet::default();
        let cap_front = self.cache_cap(keep.len(), opts.max_gen)?;
        for l in 0..g {
            caches.push(self.front_cache(&front, l, &keep, cap_front));
        }
        if let Some((kv, bucket)) = mid_kv {
            let pos_then: Vec<i32> = (0..k as i32).collect();
            let cap = self.cache_cap(k, opts.max_gen)?;
            caches.push(ShardedLayerCache::from_prefill_shards(
                cfg.d_head,
                cap,
                &kv,
                bucket,
                k,
                &pos_then,
            ));
        }
        // Publish the AV prefix for future same-sample requests (no-op
        // when no cache is attached or the engine is sharded — prefix
        // entries store full-head caches). Gated on the plan itself:
        // `prefix_shareable()` is the typed query-independence test (a
        // spec with query-dependent global pruning must never insert a
        // positional-keep entry), and `!needs_scores` additionally
        // guards the row provenance — stage 2 advances `h_live` through
        // layer g for score-based strategies, so the rows are post-front
        // only when it did not run. Today `needs_scores` implies
        // `!prefix_shareable()`, but stating both keeps a future
        // fingerprintable scores strategy from caching post-g rows.
        if opts.plan.prefix_shareable() && !needs_scores {
            self.maybe_insert_prefix(input, opts, g, &keep, &front, &h_live);
        }
        Self::compact_live(&mut h_live, &mut positions, &mut segments, &keep, d);

        Ok(Generation {
            opts: opts.clone(),
            prompt_len: k,
            segments_src: input.segments.to_vec(),
            g,
            h_live,
            positions,
            segments,
            next_layer,
            caches,
            flops,
            live_counts,
            tokens: Vec::new(),
            decode_steps: 0,
            prefill_seconds: t_prefill.elapsed().as_secs_f64(),
            decode_seconds: 0.0,
            done: false,
            prefix_lease: None,
            prefix_tokens_reused: 0,
        })
    }

    /// Layout disambiguator folded into the cache config key: identical
    /// token streams under different segment/frame layouts must not
    /// collide.
    fn layout_fingerprint(segments: &[Segment], frame_of: &[i32], p: usize) -> u64 {
        let segs: Vec<u32> = segments[..p].iter().map(|&s| s as u32).collect();
        let frames: Vec<u32> = frame_of[..p].iter().map(|&f| f as u32).collect();
        hash_mix(&[hash_tokens(3, &segs), hash_tokens(4, &frames)])
    }

    /// Attempt the warm-prefix resume. Returns `Ok(None)` — falling back
    /// to full prefill — whenever the request is not coverable: no cache
    /// attached, a sharded engine (entries store full-head caches),
    /// query-dependent plan, no AV prefix / no text suffix, no (or only
    /// partial) cached entry, or missing decode buckets.
    fn try_begin_from_prefix(
        &mut self,
        input: &RequestInput,
        opts: &GenerateOptions,
        g: usize,
    ) -> Result<Option<Generation>> {
        if self.tp != 1 {
            return Ok(None);
        }
        let Some(cache) = self.prefix_cache.clone() else { return Ok(None) };
        let Some(base_cfg) = self.prefix_config_key(&opts.plan, g) else { return Ok(None) };
        let k = input.prompt.len();
        let Some(p_max) = av_prefix_len(input.segments) else { return Ok(None) };
        if p_max >= k {
            return Ok(None); // no text suffix to resume into
        }
        let cfg_key = hash_mix(&[
            base_cfg,
            Self::layout_fingerprint(input.segments, input.frame_of, p_max),
        ]);
        // Feasibility before the lookup, so a bail here never skews the
        // hit counter: the decode-path buckets must cover prefix +
        // suffix (resume) and the final live set (decode).
        let Ok(temp_cap) = self.art.pick_bucket("decode_layer", k) else {
            return Ok(None);
        };
        let d = self.cfg.d_model;
        let fm = self.fm();
        let p = p_max;
        // Positional plans never consult scores/rollout, so the keep set
        // is computable host-side without running any layer — *before*
        // the lookup, so a keep-set mismatch below is counted as a miss
        // (nothing reused), never as a hit.
        let ginp = opts.plan.global_inputs(input.segments, input.frame_of, None, None);
        let keep = global_keep(&opts.plan.global, &ginp);
        validate_keep(&keep, input.segments)
            .map_err(|e| anyhow!("global keep invalid: {}", e))?;
        let cap_front = self.cache_cap(keep.len(), opts.max_gen)?;
        let keep_pre = keep.iter().take_while(|&&i| i < p).count();
        // Exact match only: budget-matched strategies (e.g. Random)
        // select over the whole AV set, so a shorter covered prefix
        // would yield a different keep set. The predicate checks the
        // entry's keep∩prefix rows are exactly this request's (the key
        // guarantees it; cheap check) — a mismatch counts as a miss.
        let Some(lease) = cache.lookup_exact_where(cfg_key, &input.prompt[..p_max], |entry| {
            entry.keep_positions.len() == keep_pre
                && entry
                    .keep_positions
                    .iter()
                    .zip(keep.iter())
                    .all(|(&a, &b)| a == b as i32)
        }) else {
            return Ok(None);
        };

        let t0 = Instant::now();
        let mut flops = FlopsTally::default();
        // Temp full-prefix caches: what the resumed suffix attends to at
        // layers below the split (global pruning removes tokens *at* the
        // split layer, so suffix rows must see every prefix token there).
        // Clones share the frozen entry blocks; suffix appends fork only
        // the partial tail block (copy-on-write).
        let mut full: Vec<LayerCache> = lease.entry().full_layers.to_vec();
        for c in &mut full {
            c.grow(temp_cap.max(c.cap()));
        }
        // The generation's own front caches start from keep∩prefix.
        let mut front: Vec<LayerCache> = lease.entry().keep_layers.to_vec();
        for c in &mut front {
            c.grow(cap_front.max(c.cap()));
        }
        // Resume mid-sequence: push each text-suffix token through the
        // front half via the single-token decode artifact, extending both
        // cache views causally (token j attends to prefix + earlier
        // suffix — the same set it saw inside the fused front pass).
        let mut h_suffix: Vec<f32> = Vec::with_capacity((k - p) * d);
        for j in p..k {
            let mut x: Vec<f32> = self.weights.embed(input.prompt[j]).to_vec();
            for (l, fc) in full.iter_mut().enumerate() {
                let ctx = fc.len() + 1;
                let (x2, k_new, v_new, _s) = self.decode_one_single(l, &x, j as i32, fc)?;
                fc.append(&k_new, &v_new, j as i32);
                front[l].append(&k_new, &v_new, j as i32);
                x = x2;
                flops.add_decode_layer(&fm, ctx);
            }
            h_suffix.extend_from_slice(&x);
        }
        drop(full); // temp view done; forked tail blocks recycle here

        // Live state entering the back layers = cached keep∩prefix rows
        // + freshly computed suffix rows (ascending positions).
        let mut h_live: Vec<f32> = Vec::with_capacity((keep_pre + k - p) * d);
        h_live.extend_from_slice(&lease.entry().h_keep);
        h_live.extend_from_slice(&h_suffix);
        let mut positions: Vec<i32> = lease.entry().keep_positions.clone();
        positions.extend(p as i32..k as i32);
        let segments: Vec<Segment> = positions
            .iter()
            .map(|&i| input.segments[i as usize])
            .collect();
        let mut caches = CacheSet::default();
        for c in front {
            caches.push_single(c); // resume path is tp_degree = 1 only
        }
        caches.update_peak();

        Ok(Some(Generation {
            opts: opts.clone(),
            prompt_len: k,
            segments_src: input.segments.to_vec(),
            g,
            h_live,
            positions,
            segments,
            next_layer: g,
            caches,
            flops,
            // Same tokens were live entering each front layer as on the
            // miss path; they just came from the cache.
            live_counts: vec![k; g],
            tokens: Vec::new(),
            decode_steps: 0,
            prefill_seconds: t0.elapsed().as_secs_f64(),
            decode_seconds: 0.0,
            done: false,
            prefix_lease: Some(lease),
            prefix_tokens_reused: p,
        }))
    }

    /// On a full-prefill miss under a cacheable plan, freeze the AV
    /// prefix into the shared cache: per-front-layer K/V for all prefix
    /// rows (resume attention), keep∩prefix K/V (future generations'
    /// front caches), and the post-front hidden rows for keep∩prefix.
    /// `h_rows` are the post-front hidden states for the full prompt
    /// (`[k, d]`, pre-compaction). No-op on a sharded engine — entries
    /// store full-head caches and the resume path is tp_degree = 1 only.
    fn maybe_insert_prefix(
        &self,
        input: &RequestInput,
        opts: &GenerateOptions,
        g: usize,
        keep: &[usize],
        front: &FrontKv,
        h_rows: &[f32],
    ) {
        if self.tp != 1 {
            return;
        }
        let Some(cache) = self.prefix_cache.as_ref() else { return };
        let Some(base_cfg) = self.prefix_config_key(&opts.plan, g) else { return };
        let k = input.prompt.len();
        let Some(p) = av_prefix_len(input.segments) else { return };
        if p >= k {
            return;
        }
        let cfg_key = hash_mix(&[
            base_cfg,
            Self::layout_fingerprint(input.segments, input.frame_of, p),
        ]);
        let tokens = &input.prompt[..p];
        if cache.peek(cfg_key, tokens).is_some() {
            return; // already published
        }
        let (h_n, dh, d) = (self.cfg.n_heads, self.cfg.d_head, self.cfg.d_model);
        let pool = cache.pool().clone();
        let all_rows: Vec<usize> = (0..p).collect();
        let keep_pre: Vec<usize> = keep.iter().copied().take_while(|&i| i < p).collect();
        let mut full_layers = Vec::with_capacity(g);
        let mut keep_layers = Vec::with_capacity(g);
        for l in 0..g {
            let (src_k, src_v) = front.slab(l, 0);
            full_layers.push(LayerCache::from_strided_rows(
                pool.clone(),
                h_n,
                dh,
                p,
                src_k,
                src_v,
                front.src_n,
                &all_rows,
            ));
            keep_layers.push(LayerCache::from_strided_rows(
                pool.clone(),
                h_n,
                dh,
                keep_pre.len().max(1),
                src_k,
                src_v,
                front.src_n,
                &keep_pre,
            ));
        }
        let mut h_keep = Vec::with_capacity(keep_pre.len() * d);
        for &i in &keep_pre {
            h_keep.extend_from_slice(&h_rows[i * d..(i + 1) * d]);
        }
        let entry = PrefixEntry {
            prefix_len: p,
            full_layers,
            keep_layers,
            h_keep,
            keep_positions: keep_pre.iter().map(|&i| i as i32).collect(),
            bytes: 0,
        }
        .finalize();
        cache.insert(cfg_key, tokens, entry);
    }

    /// Advance a generation by one scheduling quantum: one back layer
    /// while prefill is in flight (chunked prefill), or one full decode
    /// step afterwards. Engine time is accumulated on the generation, so
    /// per-request latency accounting survives interleaving.
    pub fn step_generation(&mut self, gen: &mut Generation) -> Result<StepEvent> {
        if gen.done {
            return Ok(StepEvent::Done);
        }
        if gen.next_layer < self.cfg.n_layers {
            self.prefill_layer_step(gen)
        } else {
            self.decode_step(gen)
        }
    }

    /// One chunked-prefill unit: back layer `gen.next_layer` over the
    /// live rows (with fine pruning entering the next layer); after the
    /// final layer, the logits head decides the first token.
    fn prefill_layer_step(&mut self, gen: &mut Generation) -> Result<StepEvent> {
        let t0 = Instant::now();
        // Hot path (one call per scheduling quantum): copy the scalar
        // dims instead of cloning the whole config.
        let fm = self.fm();
        let (d, d_head, n_layers) =
            (self.cfg.d_model, self.cfg.d_head, self.cfg.n_layers);
        let l = gen.next_layer;
        let n_live = gen.positions.len();
        gen.live_counts.push(n_live);
        let bucket = self.art.pick_bucket(&self.layer_entry(), n_live)?;
        let (h2, kv, s) = self.run_layer(l, &gen.h_live, &gen.positions, bucket)?;
        gen.flops.add_prefill_layer(&fm, n_live, n_live);
        gen.h_live = h2[..n_live * d].to_vec();
        let cap = self.cache_cap(n_live, gen.opts.max_gen)?;
        gen.caches.push(ShardedLayerCache::from_prefill_shards(
            d_head,
            cap,
            &kv,
            bucket,
            n_live,
            &gen.positions,
        ));
        // Fine pruning applies entering the next layer.
        if l + 1 < n_layers && gen.opts.plan.fine != FineStrategy::None {
            let keep = fine_keep(
                gen.opts.plan.fine,
                &s[..n_live],
                &gen.segments,
                gen.opts.plan.fine_percent,
                gen.opts.plan.seed ^ ((l as u64) << 8),
                gen.opts.plan.min_keep_vis,
                gen.opts.plan.min_keep_aud,
            );
            validate_keep(&keep, &gen.segments)
                .map_err(|e| anyhow!("fine keep invalid at layer {}: {}", l, e))?;
            Self::compact_live(&mut gen.h_live, &mut gen.positions, &mut gen.segments, &keep, d);
        }
        gen.next_layer = l + 1;
        if gen.next_layer < n_layers {
            gen.prefill_seconds += t0.elapsed().as_secs_f64();
            return Ok(StepEvent::Prefilled { layer: l });
        }
        // Prefill complete: first token from the last live hidden row.
        gen.caches.update_peak();
        let last = gen.h_live[(gen.positions.len() - 1) * d..gen.positions.len() * d].to_vec();
        let lg = self.logits(&last)?;
        let first_tok = select_token(&lg, &gen.opts.sampling, 0);
        gen.flops.add_logits(&fm);
        gen.tokens.push(first_tok);
        gen.update_done();
        gen.prefill_seconds += t0.elapsed().as_secs_f64();
        Ok(StepEvent::Token(first_tok))
    }

    /// Run one layer of the fused single-token decode artifact over a
    /// full-head `cache` (growing it to the next bucket first if full).
    /// Returns `(x', k_new, v_new, s)`; the caller appends
    /// `k_new`/`v_new`. This is the tp_degree = 1 decode loop's inner
    /// step *and* the prefix-resume path's way of pushing a text-suffix
    /// token through the front half.
    fn decode_one_single(
        &mut self,
        layer: usize,
        x: &[f32],
        pos: i32,
        cache: &mut LayerCache,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (d, n_heads, d_head) =
            (self.cfg.d_model, self.cfg.n_heads, self.cfg.d_head);
        if cache.len() + 1 > cache.cap() {
            let new_cap = self.art.pick_bucket("decode_layer", cache.len() + 1)?;
            cache.grow(new_cap);
        }
        let cap = cache.cap();
        let cur_idx = cache.len();
        let mut mask = cache.mask();
        mask[cur_idx] = 1.0;
        let x_lit = lit_f32(&[d], x)?;
        let pos_lit = lit_i32_scalar(pos)?;
        let idx_lit = lit_i32_scalar(cur_idx as i32)?;
        // Gather the paged blocks into a slice of the reused high-water
        // upload buffers (same O(cap) copy the literal build always paid;
        // no allocs, no shrink/regrow churn across bucket sizes).
        let elems = n_heads * cap * d_head;
        if self.scratch_k.len() < elems {
            self.scratch_k.resize(elems, 0.0);
            self.scratch_v.resize(elems, 0.0);
        }
        cache.padded_kv_fill(cap, &mut self.scratch_k[..elems], &mut self.scratch_v[..elems]);
        let kc = lit_f32(&[n_heads, cap, d_head], &self.scratch_k[..elems])?;
        let vc = lit_f32(&[n_heads, cap, d_head], &self.scratch_v[..elems])?;
        let m_lit = lit_f32(&[cap], &mask)?;
        let path = self.art.path("decode_layer", Some(cap));
        let mut inputs: Vec<&xla::Literal> =
            vec![&x_lit, &pos_lit, &idx_lit, &kc, &vc, &m_lit];
        for p in &self.wlit.per_layer[layer] {
            inputs.push(p);
        }
        let outs = self.mesh.execute(&path, &inputs)?;
        let [x2, k_new, v_new, s_lit]: [xla::Literal; 4] = outs
            .try_into()
            .map_err(|_| anyhow!("decode_layer returned wrong arity"))?;
        Ok((
            to_vec_f32(&x2)?,
            to_vec_f32(&k_new)?,
            to_vec_f32(&v_new)?,
            to_vec_f32(&s_lit)?,
        ))
    }

    /// One layer of a single-token decode step on the mesh: D
    /// `decode_shard` dispatches (each over its shard's paged block
    /// list), host combine (concat attention, sum importance partials),
    /// and the `decode_tail` stage on device 0. Returns the same
    /// `(x', k_new, v_new, s)` shape as the fused path, with
    /// `k_new`/`v_new` as full-head head-major rows (shard concat).
    fn decode_one_sharded(
        &mut self,
        layer: usize,
        x: &[f32],
        pos: i32,
        cache: &mut ShardedLayerCache,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (d, d_head, tp) = (self.cfg.d_model, self.cfg.d_head, self.tp);
        let hs = self.cfg.n_heads / tp;
        let hs_width = hs * d_head;
        if cache.len() + 1 > cache.cap() {
            let new_cap = self
                .art
                .pick_bucket(&self.decode_entry(), cache.len() + 1)?;
            cache.grow(new_cap);
        }
        let cap = cache.cap();
        let cur_idx = cache.len();
        let up_t0 = crate::trace::seg_begin();
        let mut mask = cache.mask();
        mask[cur_idx] = 1.0;
        let x_lit = lit_f32(&[d], x)?;
        let pos_lit = lit_i32_scalar(pos)?;
        let idx_lit = lit_i32_scalar(cur_idx as i32)?;
        let m_lit = lit_f32(&[cap], &mask)?;
        // Per-shard uploads straight from each shard's block list; the
        // scratch pair is reused shard-after-shard (literal builds copy).
        let elems = hs_width * cap;
        if self.scratch_k.len() < elems {
            self.scratch_k.resize(elems, 0.0);
            self.scratch_v.resize(elems, 0.0);
        }
        let mut kcs = Vec::with_capacity(tp);
        let mut vcs = Vec::with_capacity(tp);
        for s in 0..tp {
            cache.shard(s).padded_kv_fill(
                cap,
                &mut self.scratch_k[..elems],
                &mut self.scratch_v[..elems],
            );
            kcs.push(lit_f32(&[hs, cap, d_head], &self.scratch_k[..elems])?);
            vcs.push(lit_f32(&[hs, cap, d_head], &self.scratch_v[..elems])?);
        }
        let sw = self.shard_wlit.as_ref().expect("tp > 1 implies shard weights");
        let ln1 = &self.wlit.per_layer[layer][0];
        let dispatches: Vec<ShardDispatch> = (0..tp)
            .map(|s| {
                let mut inputs: Vec<&xla::Literal> =
                    vec![&x_lit, &pos_lit, &idx_lit, &kcs[s], &vcs[s], &m_lit, ln1];
                for w in &sw.qkv[layer][s] {
                    inputs.push(w);
                }
                ShardDispatch {
                    path: self.art.path(&self.shard_entry("decode_shard", s), Some(cap)),
                    inputs,
                }
            })
            .collect();
        crate::trace::seg_end("upload", None, up_t0);
        let outs = self.mesh.execute_sharded(&dispatches)?;
        let dl_t0 = crate::trace::seg_begin();
        let mut attn = vec![0.0f32; d];
        let mut k_new = vec![0.0f32; d];
        let mut v_new = vec![0.0f32; d];
        let mut s_sum = vec![0.0f32; cap];
        for (s, shard) in outs.iter().enumerate() {
            let [a, kn, vn, sp]: &[xla::Literal; 4] = shard
                .as_slice()
                .try_into()
                .map_err(|_| anyhow!("decode_shard returned wrong arity"))?;
            attn[s * hs_width..(s + 1) * hs_width].copy_from_slice(&to_vec_f32(a)?);
            k_new[s * hs_width..(s + 1) * hs_width].copy_from_slice(&to_vec_f32(kn)?);
            v_new[s * hs_width..(s + 1) * hs_width].copy_from_slice(&to_vec_f32(vn)?);
            add_partial(&mut s_sum, sp)?;
        }
        crate::trace::seg_end("download", None, dl_t0);
        let cb_t0 = crate::trace::seg_begin();
        let attn_lit = lit_f32(&[d], &attn)?;
        let tail_path = self.art.path("decode_tail", None);
        let pl = &self.wlit.per_layer[layer];
        let mut tail_inputs: Vec<&xla::Literal> = vec![&x_lit, &attn_lit];
        for p in &pl[pl.len() - 5..] {
            tail_inputs.push(p);
        }
        let outs = self.mesh.execute(&tail_path, &tail_inputs)?;
        let res = (to_vec_f32(&outs[0])?, k_new, v_new, s_sum);
        crate::trace::seg_end("combine", None, cb_t0);
        Ok(res)
    }

    /// One decode step over the per-layer caches: every layer advances
    /// one token (fused dispatch at tp_degree = 1, shard fan-out +
    /// combine on the mesh), then the logits head selects the next token.
    fn decode_step(&mut self, gen: &mut Generation) -> Result<StepEvent> {
        let t0 = Instant::now();
        // Hot path (one call per decode token): no config clone.
        let fm = self.fm();
        let n_layers = self.cfg.n_layers;
        let k = gen.prompt_len;
        let cur = *gen.tokens.last().expect("decode_step before prefill finished");
        let pos = (k + gen.tokens.len() - 1) as i32;
        let mut x: Vec<f32> = self.weights.embed(cur).to_vec();
        for l in 0..n_layers {
            let ctx = gen.caches.layers[l].len() + 1;
            let (x2, k_new, v_new, s) = if self.tp == 1 {
                self.decode_one_single(l, &x, pos, gen.caches.layers[l].primary_mut())?
            } else {
                self.decode_one_sharded(l, &x, pos, &mut gen.caches.layers[l])?
            };
            x = x2;
            gen.caches.layers[l].append(&k_new, &v_new, pos);
            gen.flops.add_decode_layer(&fm, ctx);
            Self::maybe_decode_prune(gen, l, &s);
        }
        gen.caches.update_peak();
        let lg = self.logits(&x)?;
        let tok = select_token(&lg, &gen.opts.sampling, gen.tokens.len());
        gen.flops.add_logits(&fm);
        gen.tokens.push(tok);
        gen.decode_steps += 1;
        gen.update_done();
        gen.decode_seconds += t0.elapsed().as_secs_f64();
        Ok(StepEvent::Token(tok))
    }

    /// Progressive decode-time pruning (extension): drop the
    /// least-important AV rows of layer `l`'s cache using this step's own
    /// importance row `s` (`s[..cache.len()]` are the live scores incl.
    /// the just-appended token). Shared by the single-token and batched
    /// decode paths so they stay token-for-token equivalent.
    fn maybe_decode_prune(gen: &mut Generation, l: usize, s: &[f32]) {
        if !gen.opts.plan.fine_during_decode
            || l < gen.g
            || gen.opts.plan.fine == FineStrategy::None
        {
            return;
        }
        let k = gen.prompt_len;
        let segments_src = &gen.segments_src;
        let cache = &mut gen.caches.layers[l];
        let len = cache.len();
        let segs: Vec<Segment> = cache
            .positions()
            .iter()
            .map(|&p| {
                if (p as usize) < k {
                    segments_src[p as usize]
                } else {
                    Segment::Text // generated tokens are text
                }
            })
            .collect();
        let keep = fine_keep(
            gen.opts.plan.fine,
            &s[..len],
            &segs,
            gen.opts.plan.fine_percent,
            gen.opts.plan.seed ^ ((l as u64) << 16) ^ gen.tokens.len() as u64,
            gen.opts.plan.min_keep_vis,
            gen.opts.plan.min_keep_aud,
        );
        if keep.len() < len {
            cache.compact(&keep);
        }
    }

    /// Batched-decode artifact entry base for batch bucket `bb` (the
    /// fused all-head artifact at tp_degree = 1, shard 0's entry on the
    /// mesh — all shards are lowered together).
    fn batch_entry_name(&self, bb: usize) -> String {
        if self.tp == 1 {
            format!("decode_batch{}", bb)
        } else {
            format!("decode_batch{}_shard0of{}", bb, self.tp)
        }
    }

    /// Smallest configured batch bucket that fits `b` requests *and* has
    /// a lowered batched-decode artifact; `None` = no batched path.
    fn batch_entry(&self, b: usize) -> Option<(usize, String)> {
        self.cfg
            .batch_buckets
            .iter()
            .copied()
            .filter(|&bb| bb >= b)
            .map(|bb| (bb, self.batch_entry_name(bb)))
            .find(|(_, e)| self.art.has_entry(e))
    }

    /// Largest decode batch one fused dispatch can advance (1 when the
    /// artifact set predates batched decode).
    pub fn max_decode_batch(&self) -> usize {
        self.cfg
            .batch_buckets
            .iter()
            .copied()
            .filter(|&bb| self.art.has_entry(&self.batch_entry_name(bb)))
            .max()
            .unwrap_or(1)
    }

    /// Advance every generation in `gens` by one decode step with **one
    /// `decode_batch` dispatch per layer** instead of one per generation
    /// per layer — the continuous-batching hot path. Per-request K/V
    /// stays in its own paged block list; the per-layer gather
    /// materializes all B lists into one `[B, cap, H·dh]`-shaped upload
    /// at the joint (batch, seq) bucket, with per-request valid-length
    /// masks. Row `b` of the artifact computes exactly what the
    /// single-token path computes for that request (requests never attend
    /// across the batch — equivalence is asserted in
    /// `python/tests/test_model.py` and `rust/tests/test_batching.rs`).
    ///
    /// Falls back to sequential [`step_generation`](Self::step_generation)
    /// calls when the batch is degenerate (fewer than 2 requests, a
    /// request that is not decode-ready, or no covering artifact).
    ///
    /// Engine wall time is split evenly across the batch, so per-request
    /// latency accounting stays comparable with the sequential path.
    pub fn step_decode_batch(&mut self, gens: &mut [&mut Generation]) -> Result<Vec<StepEvent>> {
        let degenerate = gens.len() < 2
            || gens.iter().any(|g| !g.is_decoding())
            || self.batch_entry(gens.len()).is_none();
        if degenerate {
            let mut out = Vec::with_capacity(gens.len());
            for g in gens.iter_mut() {
                out.push(self.step_generation(g)?);
            }
            return Ok(out);
        }
        // Pipelined variant (tp_degree = 1): overlap layer l+1's gather
        // + literal build with layer l's in-flight dispatch, with
        // delta-append staging buffers. Token-for-token identical;
        // `set_pipeline(false)` keeps the strict ordering below.
        if self.tp == 1 && self.pipeline {
            return self.step_decode_batch_pipelined(gens);
        }
        let t0 = Instant::now();
        let fm = self.fm();
        let (d, n_heads, d_head, n_layers) = (
            self.cfg.d_model,
            self.cfg.n_heads,
            self.cfg.d_head,
            self.cfg.n_layers,
        );
        let b = gens.len();
        let (bb, entry) = self.batch_entry(b).expect("checked above");

        // Current-token embeddings and positions, padded to the batch
        // bucket (padding rows: zero x, all-zero mask — they stay exactly
        // zero through every layer; see decode_layer_batched).
        let mut x_all = vec![0.0f32; bb * d];
        let mut pos = vec![0i32; bb];
        for (i, g) in gens.iter().enumerate() {
            let cur = *g.tokens.last().expect("decode-ready implies a token");
            x_all[i * d..(i + 1) * d].copy_from_slice(self.weights.embed(cur));
            pos[i] = (g.prompt_len + g.tokens.len() - 1) as i32;
        }
        let pos_lit = lit_i32(&[bb], &pos)?;

        for l in 0..n_layers {
            // Joint capacity: smallest compiled bucket fitting every
            // request's post-append context at this layer.
            let need = gens
                .iter()
                .map(|g| g.caches.layers[l].len() + 1)
                .max()
                .unwrap_or(1);
            let cap = self.art.pick_bucket(&entry, need)?;
            for g in gens.iter_mut() {
                let c = &mut g.caches.layers[l];
                if c.len() + 1 > c.cap() {
                    c.grow(cap); // logical re-target; paged — no copy
                }
            }
            let ctxs: Vec<usize> = gens.iter().map(|g| g.caches.layers[l].len()).collect();
            let mut mask = vec![0.0f32; bb * cap];
            let mut cur_idx = vec![0i32; bb];
            for (i, &ctx) in ctxs.iter().enumerate() {
                // Live rows + the slot this step's K/V is written into.
                mask[i * cap..i * cap + ctx + 1].fill(1.0);
                cur_idx[i] = ctx as i32;
            }
            let x_lit = lit_f32(&[bb, d], &x_all)?;
            let m_lit = lit_f32(&[bb, cap], &mask)?;
            let ci_lit = lit_i32(&[bb], &cur_idx)?;
            let x2: Vec<f32>;
            let kn: Vec<f32>;
            let vn: Vec<f32>;
            let sv: Vec<f32>;
            if self.tp == 1 {
                let per = n_heads * cap * d_head;
                {
                    let caches: Vec<&LayerCache> =
                        gens.iter().map(|g| g.caches.layers[l].primary()).collect();
                    LayerCache::padded_kv_batch_into(
                        &caches,
                        bb,
                        cap,
                        &mut self.scratch_bk,
                        &mut self.scratch_bv,
                    );
                }
                let elems = bb * per;
                let kc = lit_f32(&[bb, n_heads, cap, d_head], &self.scratch_bk[..elems])?;
                let vc = lit_f32(&[bb, n_heads, cap, d_head], &self.scratch_bv[..elems])?;
                let path = self.art.path(&entry, Some(cap));
                let mut inputs: Vec<&xla::Literal> =
                    vec![&x_lit, &pos_lit, &ci_lit, &kc, &vc, &m_lit];
                for p in &self.wlit.per_layer[l] {
                    inputs.push(p);
                }
                let outs = self.mesh.execute(&path, &inputs)?;
                let [x2_lit, k_lit, v_lit, s_lit]: [xla::Literal; 4] = outs
                    .try_into()
                    .map_err(|_| anyhow!("decode_batch returned wrong arity"))?;
                x2 = to_vec_f32(&x2_lit)?; // [bb, d]
                kn = to_vec_f32(&k_lit)?; // [bb, H, dh]
                vn = to_vec_f32(&v_lit)?;
                sv = to_vec_f32(&s_lit)?; // [bb, cap]
            } else {
                // Mesh path: one decode_batch shard dispatch per device
                // over that shard's block lists, then the batch tail.
                let tp = self.tp;
                let hs = n_heads / tp;
                let hs_width = hs * d_head;
                let per = hs_width * cap;
                let mut kcs = Vec::with_capacity(tp);
                let mut vcs = Vec::with_capacity(tp);
                for s in 0..tp {
                    {
                        let caches: Vec<&LayerCache> =
                            gens.iter().map(|g| g.caches.layers[l].shard(s)).collect();
                        LayerCache::padded_kv_batch_into(
                            &caches,
                            bb,
                            cap,
                            &mut self.scratch_bk,
                            &mut self.scratch_bv,
                        );
                    }
                    let elems = bb * per;
                    kcs.push(lit_f32(&[bb, hs, cap, d_head], &self.scratch_bk[..elems])?);
                    vcs.push(lit_f32(&[bb, hs, cap, d_head], &self.scratch_bv[..elems])?);
                }
                let sw = self.shard_wlit.as_ref().expect("tp > 1 implies shard weights");
                let ln1 = &self.wlit.per_layer[l][0];
                let dispatches: Vec<ShardDispatch> = (0..tp)
                    .map(|s| {
                        let mut inputs: Vec<&xla::Literal> =
                            vec![&x_lit, &pos_lit, &ci_lit, &kcs[s], &vcs[s], &m_lit, ln1];
                        for w in &sw.qkv[l][s] {
                            inputs.push(w);
                        }
                        ShardDispatch {
                            path: self.art.path(
                                &format!("decode_batch{}_shard{}of{}", bb, s, tp),
                                Some(cap),
                            ),
                            inputs,
                        }
                    })
                    .collect();
                let outs = self.mesh.execute_sharded(&dispatches)?;
                let mut attn = vec![0.0f32; bb * d];
                let mut k_all = vec![0.0f32; bb * d];
                let mut v_all = vec![0.0f32; bb * d];
                let mut s_all = vec![0.0f32; bb * cap];
                for (s, shard) in outs.iter().enumerate() {
                    let [a, k_lit, v_lit, s_lit]: &[xla::Literal; 4] = shard
                        .as_slice()
                        .try_into()
                        .map_err(|_| anyhow!("decode_batch shard returned wrong arity"))?;
                    let a = to_vec_f32(a)?; // [bb, hs*dh]
                    let k_part = to_vec_f32(k_lit)?; // [bb, hs, dh]
                    let v_part = to_vec_f32(v_lit)?;
                    for i in 0..bb {
                        let dst = i * d + s * hs_width;
                        attn[dst..dst + hs_width]
                            .copy_from_slice(&a[i * hs_width..(i + 1) * hs_width]);
                        k_all[dst..dst + hs_width]
                            .copy_from_slice(&k_part[i * hs_width..(i + 1) * hs_width]);
                        v_all[dst..dst + hs_width]
                            .copy_from_slice(&v_part[i * hs_width..(i + 1) * hs_width]);
                    }
                    add_partial(&mut s_all, s_lit)?;
                }
                let attn_lit = lit_f32(&[bb, d], &attn)?;
                let tail_path = self.art.path("decode_batch_tail", Some(bb));
                let pl = &self.wlit.per_layer[l];
                let mut tail_inputs: Vec<&xla::Literal> = vec![&x_lit, &attn_lit];
                for p in &pl[pl.len() - 5..] {
                    tail_inputs.push(p);
                }
                let tail_outs = self.mesh.execute(&tail_path, &tail_inputs)?;
                x2 = to_vec_f32(&tail_outs[0])?;
                kn = k_all;
                vn = v_all;
                sv = s_all;
            }
            x_all = x2;
            let row = n_heads * d_head;
            for (i, g) in gens.iter_mut().enumerate() {
                g.caches.layers[l].append(
                    &kn[i * row..(i + 1) * row],
                    &vn[i * row..(i + 1) * row],
                    pos[i],
                );
                g.flops.add_decode_layer(&fm, ctxs[i] + 1);
                Self::maybe_decode_prune(g, l, &sv[i * cap..(i + 1) * cap]);
            }
        }

        // Logits head + sampling: one batched-head dispatch for the whole
        // quantum when the artifact set carries `logits_batch` buckets
        // (per-request single-vector dispatches otherwise).
        let rows = self.logits_rows(&x_all[..b * d], b)?;
        let mut out = Vec::with_capacity(b);
        for (i, g) in gens.iter_mut().enumerate() {
            g.caches.update_peak();
            let tok = select_token(&rows[i], &g.opts.sampling, g.tokens.len());
            g.flops.add_logits(&fm);
            g.tokens.push(tok);
            g.decode_steps += 1;
            g.update_done();
            out.push(StepEvent::Token(tok));
        }
        let dt = t0.elapsed().as_secs_f64() / b as f64;
        for g in gens.iter_mut() {
            g.decode_seconds += dt;
        }
        Ok(out)
    }

    /// Stage one batched-decode layer: pick the joint bucket, grow
    /// caches, build the mask/current-index literals, and gather every
    /// cache into the layer's persistent [`GatherBuf`] (a delta-append
    /// copy when a row is provably unchanged except appended tokens —
    /// see `kvcache::gather`). Associated rather than `&mut self` so
    /// the pipelined loop can stage layer `l+1` through disjoint field
    /// borrows while literals borrowed from `self.wlit` sit in an
    /// in-flight dispatch.
    ///
    /// Staging layer `l+1` during layer `l`'s dispatch is safe for
    /// token equivalence because it touches only layer `l+1` state
    /// (bucket pick, logical `grow`, gather), which the sequential
    /// ordering leaves untouched until its own iteration — layer `l`'s
    /// append/prune mutate layer `l` only.
    #[allow(clippy::too_many_arguments)]
    fn stage_batch_layer(
        art: &ArtifactDir,
        entry: &str,
        gens: &mut [&mut Generation],
        l: usize,
        bb: usize,
        gather: &mut GatherBuf,
        n_heads: usize,
        d_head: usize,
    ) -> Result<StagedBatchLayer> {
        let need = gens
            .iter()
            .map(|g| g.caches.layers[l].len() + 1)
            .max()
            .unwrap_or(1);
        let cap = art.pick_bucket(entry, need)?;
        for g in gens.iter_mut() {
            let c = &mut g.caches.layers[l];
            if c.len() + 1 > c.cap() {
                c.grow(cap); // logical re-target; paged — no copy
            }
        }
        let ctxs: Vec<usize> = gens.iter().map(|g| g.caches.layers[l].len()).collect();
        let mut mask = vec![0.0f32; bb * cap];
        let mut cur_idx = vec![0i32; bb];
        for (i, &ctx) in ctxs.iter().enumerate() {
            // Live rows + the slot this step's K/V is written into.
            mask[i * cap..i * cap + ctx + 1].fill(1.0);
            cur_idx[i] = ctx as i32;
        }
        let m_lit = lit_f32(&[bb, cap], &mask)?;
        let ci_lit = lit_i32(&[bb], &cur_idx)?;
        {
            let caches: Vec<&LayerCache> =
                gens.iter().map(|g| g.caches.layers[l].primary()).collect();
            gather.fill(&caches, bb, cap);
        }
        let elems = bb * n_heads * cap * d_head;
        let kc = lit_f32(&[bb, n_heads, cap, d_head], &gather.k()[..elems])?;
        let vc = lit_f32(&[bb, n_heads, cap, d_head], &gather.v()[..elems])?;
        Ok(StagedBatchLayer { cap, ctxs, m_lit, ci_lit, kc, vc })
    }

    /// [`Self::step_decode_batch`], pipelined: layer `l` is dispatched
    /// through the device-0 worker's queue without blocking
    /// ([`DeviceMesh::execute_queued`]) and layer `l+1`'s upload —
    /// paged-cache gather + literal build — is staged while it runs;
    /// only then does the loop wait on the completion channel. Traced
    /// quanta record the staged uploads with `overlap = true`, visible
    /// as the `overlap` attribute in `GET /v1/trace/{id}` and folded
    /// into `fastav_upload_overlap_ratio`. Per-layer persistent
    /// [`GatherBuf`]s additionally downgrade steady-state re-gathers to
    /// delta-append copies across quanta.
    fn step_decode_batch_pipelined(
        &mut self,
        gens: &mut [&mut Generation],
    ) -> Result<Vec<StepEvent>> {
        let t0 = Instant::now();
        let fm = self.fm();
        let (d, n_heads, d_head, n_layers) = (
            self.cfg.d_model,
            self.cfg.n_heads,
            self.cfg.d_head,
            self.cfg.n_layers,
        );
        let b = gens.len();
        let (bb, entry) = self.batch_entry(b).expect("checked by step_decode_batch");
        if self.batch_gather.len() < n_layers {
            self.batch_gather.resize_with(n_layers, GatherBuf::new);
        }
        let mut x_all = vec![0.0f32; bb * d];
        let mut pos = vec![0i32; bb];
        for (i, g) in gens.iter().enumerate() {
            let cur = *g.tokens.last().expect("decode-ready implies a token");
            x_all[i * d..(i + 1) * d].copy_from_slice(self.weights.embed(cur));
            pos[i] = (g.prompt_len + g.tokens.len() - 1) as i32;
        }
        let pos_lit = lit_i32(&[bb], &pos)?;
        // Layer 0 has no dispatch to hide behind: staged synchronously.
        let up0 = crate::trace::seg_begin();
        let mut staged = Some(Self::stage_batch_layer(
            &self.art,
            &entry,
            gens,
            0,
            bb,
            &mut self.batch_gather[0],
            n_heads,
            d_head,
        )?);
        crate::trace::seg_end("upload", None, up0);
        let row = n_heads * d_head;
        for l in 0..n_layers {
            let cur = staged.take().expect("layer staged by the previous iteration");
            let x_lit = lit_f32(&[bb, d], &x_all)?;
            let path = self.art.path(&entry, Some(cur.cap));
            let mut inputs: Vec<&xla::Literal> =
                vec![&x_lit, &pos_lit, &cur.ci_lit, &cur.kc, &cur.vc, &cur.m_lit];
            for p in &self.wlit.per_layer[l] {
                inputs.push(p);
            }
            // Non-blocking dispatch: the device-0 worker runs layer l
            // while this thread stages layer l+1's upload.
            let pending = self.mesh.execute_queued(&path, &inputs)?;
            if l + 1 < n_layers {
                let up = crate::trace::seg_begin();
                let next = Self::stage_batch_layer(
                    &self.art,
                    &entry,
                    gens,
                    l + 1,
                    bb,
                    &mut self.batch_gather[l + 1],
                    n_heads,
                    d_head,
                );
                crate::trace::seg_end_overlap("upload", None, up, true);
                // `?` only after the segment closes; an error drops
                // `pending`, whose drop drains the in-flight dispatch
                // before the borrowed literals go away.
                staged = Some(next?);
            }
            let outs = pending.wait()?;
            let [x2_lit, k_lit, v_lit, s_lit]: [xla::Literal; 4] = outs
                .try_into()
                .map_err(|_| anyhow!("decode_batch returned wrong arity"))?;
            x_all = to_vec_f32(&x2_lit)?; // [bb, d]
            let kn = to_vec_f32(&k_lit)?; // [bb, H, dh]
            let vn = to_vec_f32(&v_lit)?;
            let sv = to_vec_f32(&s_lit)?; // [bb, cap]
            for (i, g) in gens.iter_mut().enumerate() {
                g.caches.layers[l].append(
                    &kn[i * row..(i + 1) * row],
                    &vn[i * row..(i + 1) * row],
                    pos[i],
                );
                g.flops.add_decode_layer(&fm, cur.ctxs[i] + 1);
                Self::maybe_decode_prune(g, l, &sv[i * cur.cap..(i + 1) * cur.cap]);
            }
        }

        let rows = self.logits_rows(&x_all[..b * d], b)?;
        let mut out = Vec::with_capacity(b);
        for (i, g) in gens.iter_mut().enumerate() {
            g.caches.update_peak();
            let tok = select_token(&rows[i], &g.opts.sampling, g.tokens.len());
            g.flops.add_logits(&fm);
            g.tokens.push(tok);
            g.decode_steps += 1;
            g.update_done();
            out.push(StepEvent::Token(tok));
        }
        let dt = t0.elapsed().as_secs_f64() / b as f64;
        for g in gens.iter_mut() {
            g.decode_seconds += dt;
        }
        Ok(out)
    }

    /// Consume a generation into its result. Callable at any point — a
    /// canceled or deadline-expired generation yields its partial tokens
    /// and the FLOPs/memory actually spent.
    pub fn finish_generation(&self, gen: Generation) -> GenerateResult {
        let fm = self.fm();
        let relative = gen.flops.relative_to_vanilla(&fm, gen.prompt_len, gen.tokens.len());
        GenerateResult {
            prompt_len: gen.prompt_len,
            relative_flops: relative,
            flops: gen.flops,
            peak_kv_bytes: gen.caches.peak_bytes(),
            prefill_seconds: gen.prefill_seconds,
            decode_seconds: gen.decode_seconds,
            decode_steps: gen.decode_steps,
            live_counts: gen.live_counts,
            prefix_hit: gen.prefix_lease.is_some(),
            prefix_tokens_reused: gen.prefix_tokens_reused,
            tokens: gen.tokens,
            // `gen.prefix_lease` drops here, unpinning the cache entry.
        }
    }

    /// [`Self::estimate_kv_bytes`] charged at the plan's *effective keep
    /// budget*: for a query-independent global stage the keep set is
    /// computable host-side, and every per-layer cache the request pins
    /// is sized to at most `keep + max_gen` rows (front caches gather
    /// keep rows; back-layer live sets only shrink from there). Falls
    /// back to the dense prompt bound when the plan needs scores/rollout
    /// (those plans also cache layer `g` over the full prompt). Serving
    /// admission uses this, so mixed-profile pools charge each request
    /// what its own pruning policy can actually pin.
    pub fn estimate_kv_bytes_planned(
        &self,
        plan: &PruningPlan,
        segments: &[Segment],
        frame_of: &[i32],
        max_gen: usize,
    ) -> usize {
        let live =
            plan_effective_keep_len(plan, segments, frame_of).unwrap_or(segments.len());
        self.estimate_kv_bytes(live, max_gen)
    }

    /// Conservative upper bound on the KV bytes a request can pin:
    /// unpruned prompt + full generation budget, at bucket granularity,
    /// across every layer. Serving admission gates on this estimate.
    pub fn estimate_kv_bytes(&self, prompt_len: usize, max_gen: usize) -> usize {
        let needed = prompt_len + max_gen;
        let cap = self
            .art
            .pick_bucket(&self.decode_entry(), needed)
            .unwrap_or(needed);
        // Sharding splits the same rows by head range; the total is
        // unchanged (each shard holds n_heads/D of this).
        LayerCache::slab_bytes(self.cfg.n_heads, self.cfg.d_head, cap) * self.cfg.n_layers
    }

    // -------------------------------------------------------- calibration

    /// Run the all-layer rollout/attention probe (offline path).
    pub fn calib_probe(&mut self, prompt: &[u32]) -> Result<CalibProbe> {
        let cfg = self.cfg.clone();
        let d = cfg.d_model;
        let k = prompt.len();
        let bucket = self.art.pick_bucket("calib_probe", k)?;
        let mut x_emb = vec![0.0f32; bucket * d];
        self.weights.embed_into(prompt, &mut x_emb);
        let x_lit = lit_f32(&[bucket, d], &x_emb)?;
        let all_pos: Vec<i32> = (0..k as i32).collect();
        let (mask, pos) = self.mask_positions(&all_pos, bucket)?;
        let path = self.art.path("calib_probe", Some(bucket));
        let mut inputs: Vec<&xla::Literal> = vec![&x_lit, &mask, &pos];
        for p in &self.wlit.full_stack {
            inputs.push(p);
        }
        let outs = self.mesh.execute(&path, &inputs)?;
        let [rollout, attn]: [xla::Literal; 2] = outs
            .try_into()
            .map_err(|_| anyhow!("calib_probe returned wrong arity"))?;
        Ok(CalibProbe {
            n_layers: cfg.n_layers,
            bucket,
            prompt_len: k,
            rollout: to_vec_f32(&rollout)?,
            attn: to_vec_f32(&attn)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vanilla_plan_has_no_pruning() {
        let p = PruningPlan::vanilla();
        assert_eq!(p.global, GlobalStrategy::None);
        assert_eq!(p.fine, FineStrategy::None);
    }

    #[test]
    fn fastav_plan_shape() {
        let p = PruningPlan::fastav(40, 4, 2, 20.0);
        assert!(matches!(p.global, GlobalStrategy::FastAvPosition { .. }));
        assert_eq!(p.fine, FineStrategy::LowAttentive);
        assert!((p.fine_percent - 20.0).abs() < 1e-9);
    }

    #[test]
    fn plan_stage_predicates() {
        assert!(!PruningPlan::vanilla().needs_scores());
        assert!(!PruningPlan::vanilla().needs_rollout());
        assert!(PruningPlan::vanilla().prefix_shareable());
        assert!(PruningPlan::fastav(8, 2, 0, 20.0).prefix_shareable());
        let mut p = PruningPlan::vanilla();
        p.global = GlobalStrategy::LowAttentive;
        assert!(p.needs_scores());
        assert!(!p.prefix_shareable(), "score-guided plans are per-request");
        p.global = GlobalStrategy::TopInformative;
        assert!(p.needs_rollout());
        assert!(!p.prefix_shareable());
        p.global = GlobalStrategy::Random;
        assert!(!p.needs_scores() && !p.needs_rollout());
        assert!(p.prefix_shareable(), "random is query-independent");
    }

    #[test]
    fn fingerprint_distinguishes_floors() {
        let a = PruningPlan::fastav(8, 2, 0, 20.0);
        let mut b = a.clone();
        b.min_keep_aud = 4;
        assert_ne!(
            plan_prefix_fingerprint(&a),
            plan_prefix_fingerprint(&b),
            "floors change keep sets, so they must split prefix configs"
        );
        // Fine-stage-only differences share entries (pruning after the
        // split never touches the prefix KV).
        let mut c = a.clone();
        c.fine_percent = 55.0;
        assert_eq!(plan_prefix_fingerprint(&a), plan_prefix_fingerprint(&c));
    }

    #[test]
    fn effective_keep_len_matches_global_keep() {
        // 1 ctrl + 4 vis + 2 aud + 1 text.
        let mut segments = vec![Segment::Ctrl];
        segments.extend([Segment::Vis; 4]);
        segments.extend([Segment::Aud; 2]);
        segments.push(Segment::Text);
        let frame_of = vec![-1i32; segments.len()];
        // vis positions are 1..=4; cutoff 3 keeps vis 1,2. keep_audio 1.
        let plan = PruningPlan::fastav(3, 1, 0, 20.0);
        // ctrl + vis{1,2} + first aud + text = 5 live rows.
        assert_eq!(plan_effective_keep_len(&plan, &segments, &frame_of), Some(5));
        assert_eq!(
            plan_effective_keep_len(&PruningPlan::vanilla(), &segments, &frame_of),
            Some(segments.len())
        );
        let mut scored = PruningPlan::vanilla();
        scored.global = GlobalStrategy::LowAttentive;
        assert_eq!(
            plan_effective_keep_len(&scored, &segments, &frame_of),
            None,
            "score-guided keep sets are unknowable host-side"
        );
        // Floors grow the host-side estimate the same way they grow the
        // engine's keep set.
        let mut floored = plan.clone();
        floored.min_keep_aud = 2;
        assert_eq!(plan_effective_keep_len(&floored, &segments, &frame_of), Some(6));
    }

    #[test]
    fn default_options() {
        let o = GenerateOptions::default();
        assert_eq!(o.max_gen, 4);
        assert_eq!(o.sampling.temperature, 0.0);
    }

    #[test]
    fn select_token_greedy() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let s = Sampling::default();
        assert_eq!(select_token(&logits, &s, 0), 1);
        assert_eq!(select_token(&logits, &s, 7), 1); // step-independent
    }

    #[test]
    fn select_token_top_k_1_is_greedy() {
        let logits = vec![0.0, 3.0, 1.0];
        let s = Sampling { temperature: 1.0, top_k: 1, seed: 42 };
        for step in 0..10 {
            assert_eq!(select_token(&logits, &s, step), 1);
        }
    }

    #[test]
    fn select_token_sampling_deterministic_and_varied() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let s = Sampling { temperature: 1.0, top_k: 0, seed: 5 };
        let a: Vec<u32> = (0..20).map(|st| select_token(&logits, &s, st)).collect();
        let b: Vec<u32> = (0..20).map(|st| select_token(&logits, &s, st)).collect();
        assert_eq!(a, b); // deterministic under fixed seed
        let distinct: std::collections::HashSet<u32> = a.into_iter().collect();
        assert!(distinct.len() > 1, "uniform logits must mix across steps");
    }

    #[test]
    fn select_token_low_temperature_concentrates() {
        let logits = vec![0.0, 5.0, 0.0];
        let s = Sampling { temperature: 0.1, top_k: 0, seed: 9 };
        for step in 0..20 {
            assert_eq!(select_token(&logits, &s, step), 1);
        }
    }
}
