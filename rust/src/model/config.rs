//! Model configuration parsed from `artifacts/<model>/model.json`
//! (written by `python/compile/aot.py` — the single source of truth).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::flops::FlopsModel;
use crate::tokens::Layout;
use crate::util::json::Json;

/// AV-LLM decoder hyperparameters + bucket grid (mirrors python ModelCfg).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub mid_layer: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub rollout_alpha: f64,
    pub layout: Layout,
    pub prefill_buckets: Vec<usize>,
    pub seq_buckets: Vec<usize>,
    pub calib_buckets: Vec<usize>,
    /// Decode batch sizes with `decode_batch<b>_<n>` artifacts (empty for
    /// artifact sets lowered before batched decode existed).
    pub batch_buckets: Vec<usize>,
    /// Tensor-parallel degree the artifact set was lowered for: when
    /// > 1, head-sharded `*_shard<s>of<D>` artifacts exist and the
    /// device-mesh backend may run this model at that degree. `1` for
    /// artifact sets lowered before the mesh existed.
    pub tp_degree: usize,
    /// Directory (under the artifact root) holding this model's weights —
    /// alias configs (vl2sim_long) share another model's checkpoint.
    pub weights_dir: String,
    /// Kernel implementation the artifacts were lowered with.
    pub kernel_impl: String,
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .as_usize()
        .ok_or_else(|| anyhow!("model.json: missing/invalid '{}'", key))
}

fn usize_list(j: &Json, key: &str) -> Result<Vec<usize>> {
    j.get(key)
        .as_arr()
        .ok_or_else(|| anyhow!("model.json: missing list '{}'", key))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("model.json: bad int in '{}'", key)))
        .collect()
}

impl ModelConfig {
    /// Parse `artifacts/<model>/model.json`.
    pub fn load(path: &Path) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {:?} (run `make artifacts`)", path))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{:?}: {}", path, e))?;
        Self::from_json(&root)
    }

    pub fn from_json(root: &Json) -> Result<ModelConfig> {
        let c = root.get("config");
        let l = c.get("layout");
        let layout = Layout {
            frames: usize_field(l, "frames")?,
            vis_per_frame: usize_field(l, "vis_per_frame")?,
            aud_len: usize_field(l, "aud_len")?,
            aud_per_frame: usize_field(l, "aud_per_frame")?,
            interleaved: l
                .get("interleaved")
                .as_bool()
                .ok_or_else(|| anyhow!("layout.interleaved"))?,
        };
        Ok(ModelConfig {
            name: c
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("config.name"))?
                .to_string(),
            vocab: usize_field(c, "vocab")?,
            d_model: usize_field(c, "d_model")?,
            n_heads: usize_field(c, "n_heads")?,
            d_head: usize_field(c, "d_head")?,
            n_layers: usize_field(c, "n_layers")?,
            mid_layer: usize_field(c, "mid_layer")?,
            d_ff: usize_field(c, "d_ff")?,
            rope_theta: c.get("rope_theta").as_f64().unwrap_or(10000.0),
            rollout_alpha: c.get("rollout_alpha").as_f64().unwrap_or(0.6),
            layout,
            prefill_buckets: usize_list(c, "prefill_buckets")?,
            seq_buckets: usize_list(c, "seq_buckets")?,
            calib_buckets: usize_list(c, "calib_buckets")?,
            batch_buckets: usize_list(c, "batch_buckets").unwrap_or_default(),
            tp_degree: c.get("tp_degree").as_usize().unwrap_or(1).max(1),
            weights_dir: root
                .get("weights_dir")
                .as_str()
                .unwrap_or_else(|| c.get("name").as_str().unwrap_or("model"))
                .to_string(),
            kernel_impl: root.get("impl").as_str().unwrap_or("pallas").to_string(),
        })
    }

    pub fn flops_model(&self) -> FlopsModel {
        FlopsModel {
            d_model: self.d_model,
            d_ff: self.d_ff,
            n_layers: self.n_layers,
            vocab: self.vocab,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {
        "name": "tiny", "vocab": 256, "d_model": 32, "n_heads": 2,
        "d_head": 16, "n_layers": 4, "mid_layer": 2, "d_ff": 64,
        "rope_theta": 10000.0, "rollout_alpha": 0.6,
        "layout": {"frames": 2, "vis_per_frame": 4, "aud_len": 6,
                    "aud_per_frame": 3, "interleaved": false},
        "prefill_buckets": [32], "seq_buckets": [16, 32],
        "calib_buckets": [32],
        "train_steps": 150, "train_batch": 8, "train_lr": 0.002,
        "train_seed": 1234
      },
      "impl": "pallas",
      "weights_dir": "tiny",
      "abi": {}
    }"#;

    #[test]
    fn parses_model_json() {
        let cfg = ModelConfig::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.name, "tiny");
        assert_eq!(cfg.d_model, 32);
        assert_eq!(cfg.n_heads * cfg.d_head, cfg.d_model);
        assert_eq!(cfg.seq_buckets, vec![16, 32]);
        // Older model.json without batch_buckets parses as "no batched
        // decode artifacts" rather than erroring; likewise a missing
        // tp_degree parses as the unsharded degree 1.
        assert!(cfg.batch_buckets.is_empty());
        assert_eq!(cfg.tp_degree, 1);
        assert!(!cfg.layout.interleaved);
        assert_eq!(cfg.weights_dir, "tiny");
        assert_eq!(cfg.kernel_impl, "pallas");
    }

    #[test]
    fn flops_model_dims() {
        let cfg = ModelConfig::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        let fm = cfg.flops_model();
        assert_eq!(fm.d_model, 32);
        assert_eq!(fm.n_layers, 4);
    }

    #[test]
    fn missing_field_errors() {
        let bad = r#"{"config": {"name": "x"}}"#;
        assert!(ModelConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn parses_batch_buckets_when_present() {
        let with = SAMPLE.replace(
            "\"seq_buckets\": [16, 32],",
            "\"seq_buckets\": [16, 32], \"batch_buckets\": [2, 4],",
        );
        let cfg = ModelConfig::from_json(&Json::parse(&with).unwrap()).unwrap();
        assert_eq!(cfg.batch_buckets, vec![2, 4]);
    }

    #[test]
    fn parses_tp_degree_when_present() {
        let with = SAMPLE.replace(
            "\"seq_buckets\": [16, 32],",
            "\"seq_buckets\": [16, 32], \"tp_degree\": 2,",
        );
        let cfg = ModelConfig::from_json(&Json::parse(&with).unwrap()).unwrap();
        assert_eq!(cfg.tp_degree, 2);
    }
}
