//! Theoretical FLOPs accounting — the paper's efficiency metric.
//!
//! The paper reports FLOPs *relative to the vanilla model = 100* (the
//! FastV protocol, [11]). This module implements exact closed-form
//! per-layer counts given the number of live tokens at each layer, and a
//! [`FlopsTally`] that the engine updates as it executes so every request
//! carries its own measured-theoretical cost.
//!
//! Conventions: one multiply-accumulate = 2 FLOPs; biases/norms/softmax
//! are omitted (matmul-dominated, matching the paper's protocol).

/// Model dimensions needed for FLOPs accounting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlopsModel {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub vocab: usize,
}

impl FlopsModel {
    /// FLOPs of one transformer layer processing `n_q` query rows against
    /// `n_k` key rows.
    ///
    /// qkv+output projections: `8 * n_q * d^2`; attention scores + values:
    /// `4 * n_q * n_k * d`; SwiGLU MLP (3 matmuls): `6 * n_q * d * d_ff`.
    pub fn layer(&self, n_q: usize, n_k: usize) -> u64 {
        let d = self.d_model as u64;
        let ff = self.d_ff as u64;
        let nq = n_q as u64;
        let nk = n_k as u64;
        8 * nq * d * d + 4 * nq * nk * d + 6 * nq * d * ff
    }

    /// FLOPs of the logits head for one token (tied unembedding).
    pub fn logits(&self) -> u64 {
        2 * self.d_model as u64 * self.vocab as u64
    }

    /// Full-prompt prefill with no pruning: all layers see `k` tokens.
    pub fn vanilla_prefill(&self, k: usize) -> u64 {
        self.layer(k, k) * self.n_layers as u64 + self.logits()
    }

    /// One vanilla decode step with a cache of `k` tokens (query row
    /// attends over `k + 1` keys including itself).
    pub fn vanilla_decode_step(&self, k: usize) -> u64 {
        self.layer(1, k + 1) * self.n_layers as u64 + self.logits()
    }

    /// Vanilla end-to-end generation cost: prefill of `k` prompt tokens +
    /// `gen` decode steps with a growing cache.
    pub fn vanilla_generate(&self, k: usize, gen: usize) -> u64 {
        let mut total = self.vanilla_prefill(k);
        for t in 0..gen.saturating_sub(1) {
            total += self.vanilla_decode_step(k + t);
        }
        total
    }
}

/// Running tally of theoretical FLOPs for one request. The engine calls
/// `add_layer` with the *actual* live token counts at each executed layer,
/// so pruning shows up directly.
#[derive(Debug, Clone, Default)]
pub struct FlopsTally {
    pub total: u64,
    pub prefill: u64,
    pub decode: u64,
}

impl FlopsTally {
    pub fn add_prefill_layer(&mut self, m: &FlopsModel, n_q: usize, n_k: usize) {
        let f = m.layer(n_q, n_k);
        self.total += f;
        self.prefill += f;
    }

    pub fn add_decode_layer(&mut self, m: &FlopsModel, n_k: usize) {
        let f = m.layer(1, n_k);
        self.total += f;
        self.decode += f;
    }

    pub fn add_logits(&mut self, m: &FlopsModel) {
        self.total += m.logits();
    }

    /// Relative cost vs a vanilla run over the same prompt/generation
    /// lengths, scaled so vanilla = 100 (paper protocol).
    pub fn relative_to_vanilla(&self, m: &FlopsModel, prompt_len: usize, gen_len: usize) -> f64 {
        let vanilla = m.vanilla_generate(prompt_len, gen_len.max(1)) as f64;
        100.0 * self.total as f64 / vanilla
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> FlopsModel {
        FlopsModel { d_model: 128, d_ff: 256, n_layers: 8, vocab: 256 }
    }

    #[test]
    fn layer_closed_form() {
        // Hand-computed: d=128, ff=256, n_q=n_k=100:
        // 8*100*128^2 = 13_107_200; 4*100*100*128 = 5_120_000;
        // 6*100*128*256 = 19_660_800. Total 37_888_000.
        assert_eq!(m().layer(100, 100), 37_888_000);
    }

    #[test]
    fn logits_closed_form() {
        assert_eq!(m().logits(), 2 * 128 * 256);
    }

    #[test]
    fn vanilla_prefill_is_layers_plus_logits() {
        let mm = m();
        assert_eq!(mm.vanilla_prefill(64), mm.layer(64, 64) * 8 + mm.logits());
    }

    #[test]
    fn tally_matches_vanilla_when_unpruned() {
        let mm = m();
        let k = 93;
        let gen = 3;
        let mut tally = FlopsTally::default();
        for _ in 0..mm.n_layers {
            tally.add_prefill_layer(&mm, k, k);
        }
        tally.add_logits(&mm);
        for t in 0..gen - 1 {
            for _ in 0..mm.n_layers {
                tally.add_decode_layer(&mm, k + t + 1);
            }
            tally.add_logits(&mm);
        }
        let rel = tally.relative_to_vanilla(&mm, k, gen);
        assert!((rel - 100.0).abs() < 1e-9, "rel = {}", rel);
    }

    #[test]
    fn pruning_reduces_relative() {
        let mm = m();
        let k = 93;
        let kept = 40;
        let mut tally = FlopsTally::default();
        for l in 0..mm.n_layers {
            let n = if l < 4 { k } else { kept };
            tally.add_prefill_layer(&mm, n, n);
        }
        tally.add_logits(&mm);
        let rel = tally.relative_to_vanilla(&mm, k, 1);
        assert!(rel < 80.0 && rel > 30.0, "rel = {}", rel);
    }

    #[test]
    fn monotone_in_tokens() {
        let mm = m();
        assert!(mm.layer(50, 50) < mm.layer(51, 50));
        assert!(mm.layer(50, 50) < mm.layer(50, 51));
        assert!(mm.vanilla_decode_step(10) < mm.vanilla_decode_step(11));
    }
}
