//! HTTP API surface: the handler shared by `fastav serve`, the serving
//! example, and the integration tests.
//!
//! Endpoints:
//! * `POST /v1/generate` — body `{"dataset": "...", "index": N,
//!   "no_pruning": bool, "priority": "high"?, "max_gen": N?,
//!   "deadline_ms": N?, "question": "what_scene"|"what_sound"|
//!   "scene_sound"?}`; generates the avsynth sample's answer and returns
//!   tokens + efficiency metrics (including `prefix_hit` /
//!   `prefix_tokens_reused` from the AV-prefix cache) + the pool request
//!   id. The optional `question` override re-asks a *different* question
//!   about the same sample — the workload shape the prefix cache
//!   accelerates, since the AV prefix K/V is shared across questions.
//! * `POST /v1/cancel` — body `{"request_id": N}`; cooperative
//!   cancellation of a queued or running request.
//! * `POST /v1/cache/flush` — evict every lease-free AV-prefix cache
//!   entry; returns `{"flushed_entries": N, "freed_bytes": N}`.
//! * `GET /v1/pool` — per-replica status, the pool conservation ledger,
//!   prefix-cache stats (`hits`/`misses`/`evictions`/`entries`/`bytes`),
//!   shared KV block-pool gauges (`used`/`shared`/`free`), and the
//!   `decode_batch` block (`quanta`/`tokens`/`mean_occupancy` of the
//!   fused continuous-batching decode path).
//! * `GET /metrics` — Prometheus text exposition.
//! * `GET /healthz` — liveness.
//!
//! Backpressure mapping: a full queue is `429` with `Retry-After`; a
//! shutting-down pool is `503`. Every response echoes the client's
//! `x-request-id` header (or the pool-assigned id on generate) for
//! request tracing.

use std::sync::Arc;
use std::time::Duration;

use super::{Handler, Request, Response};
use crate::avsynth::{gen_sample, Dataset, QuestionKind};
use crate::coordinator::{Coordinator, Event, GenRequest, Priority};
use crate::eval::exact_match;
use crate::model::{GenerateOptions, PruningPlan};
use crate::serving::SubmitError;
use crate::tokens::{render_answer, Layout};
use crate::util::json::Json;

/// Build the request handler for a running coordinator. `max_gen` is
/// the operator-configured generation cap: the default for requests
/// that don't ask, and the ceiling for requests that do.
pub fn make_handler(
    coord: Arc<Coordinator>,
    layout: Layout,
    plan: PruningPlan,
    max_gen: usize,
    base_seed: u64,
) -> Handler {
    Arc::new(move |req: &Request| {
        let resp = route(req, &coord, &layout, &plan, max_gen, base_seed);
        echo_request_id(req, resp)
    })
}

/// Echo the client's `x-request-id` unless the handler already set one
/// (generate sets the pool-assigned id when the client sent none).
fn echo_request_id(req: &Request, resp: Response) -> Response {
    match req.header("x-request-id") {
        Some(v) if !resp.headers.iter().any(|(k, _)| k == "x-request-id") => {
            resp.with_header("x-request-id", v)
        }
        _ => resp,
    }
}

fn route(
    req: &Request,
    coord: &Coordinator,
    layout: &Layout,
    plan: &PruningPlan,
    max_gen: usize,
    base_seed: u64,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, "ok"),
        ("GET", "/metrics") => Response::text(200, &coord.metrics.export()),
        ("GET", "/v1/pool") => pool_status(coord),
        ("POST", "/v1/generate") => generate(req, coord, layout, plan, max_gen, base_seed),
        ("POST", "/v1/cancel") => cancel(req, coord),
        ("POST", "/v1/cache/flush") => cache_flush(coord),
        ("GET", _) | ("POST", _) => Response::text(404, "not found"),
        _ => Response::text(405, "method not allowed"),
    }
}

fn parse_body(req: &Request) -> Result<Json, Response> {
    std::str::from_utf8(&req.body)
        .map_err(|_| ())
        .and_then(|s| Json::parse(s).map_err(|_| ()))
        .map_err(|_| Response::text(400, "invalid JSON body"))
}

fn pool_status(coord: &Coordinator) -> Response {
    let replicas = coord.pool_status().into_iter().map(|r| {
        Json::obj(vec![
            ("id", Json::num(r.id as f64)),
            ("queued", Json::num(r.queued as f64)),
            ("active", Json::num(r.active as f64)),
            ("tp_degree", Json::num(r.tp_degree as f64)),
            ("kv_bytes", Json::num(r.kv_bytes as f64)),
            ("kv_budget_bytes", Json::num(r.kv_budget_bytes as f64)),
            ("steps_total", Json::num(r.steps_total as f64)),
            ("steps_per_sec", Json::num(r.steps_per_sec as f64)),
            ("completed", Json::num(r.completed as f64)),
            ("decode_batch_quanta", Json::num(r.decode_batch_quanta as f64)),
            ("decode_batch_tokens", Json::num(r.decode_batch_tokens as f64)),
        ])
    });
    let s = coord.pool_stats();
    let p = coord.prefix_stats();
    let b = coord.block_stats();
    let (bq, bt) = coord.decode_batch_stats();
    let out = Json::obj(vec![
        ("replicas", Json::arr(replicas)),
        (
            "stats",
            Json::obj(vec![
                ("submitted", Json::num(s.submitted as f64)),
                ("rejected", Json::num(s.rejected as f64)),
                ("completed", Json::num(s.completed as f64)),
                ("failed", Json::num(s.failed as f64)),
                ("canceled", Json::num(s.canceled as f64)),
                ("expired", Json::num(s.expired as f64)),
                ("in_queue", Json::num(s.in_queue as f64)),
                ("in_flight", Json::num(s.in_flight as f64)),
            ]),
        ),
        (
            "prefix_cache",
            Json::obj(vec![
                ("entries", Json::num(p.entries as f64)),
                ("bytes", Json::num(p.bytes as f64)),
                ("active_leases", Json::num(p.active_leases as f64)),
                ("trie_nodes", Json::num(p.trie_nodes as f64)),
                ("hits", Json::num(p.hits as f64)),
                ("misses", Json::num(p.misses as f64)),
                ("evictions", Json::num(p.evictions as f64)),
                ("insertions", Json::num(p.insertions as f64)),
            ]),
        ),
        (
            "kv_blocks",
            Json::obj(vec![
                ("used", Json::num(b.used as f64)),
                ("shared", Json::num(b.shared as f64)),
                ("free", Json::num(b.free as f64)),
                ("bytes_used", Json::num(b.bytes_used as f64)),
            ]),
        ),
        (
            "decode_batch",
            Json::obj(vec![
                ("quanta", Json::num(bq as f64)),
                ("tokens", Json::num(bt as f64)),
                (
                    "mean_occupancy",
                    Json::num(if bq == 0 { 0.0 } else { bt as f64 / bq as f64 }),
                ),
            ]),
        ),
    ]);
    Response::json(200, out.to_string())
}

fn cache_flush(coord: &Coordinator) -> Response {
    let (flushed, freed) = coord.flush_prefix_cache();
    let out = Json::obj(vec![
        ("flushed_entries", Json::num(flushed as f64)),
        ("freed_bytes", Json::num(freed as f64)),
    ]);
    Response::json(200, out.to_string())
}

fn cancel(req: &Request, coord: &Coordinator) -> Response {
    let body = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(id) = body.get("request_id").as_usize() else {
        return Response::text(400, "request_id (integer) required");
    };
    let found = coord.cancel(id as u64);
    let out = Json::obj(vec![
        ("request_id", Json::num(id as f64)),
        ("canceled", Json::Bool(found)),
    ]);
    Response::json(if found { 200 } else { 404 }, out.to_string())
}

fn generate(
    req: &Request,
    coord: &Coordinator,
    layout: &Layout,
    plan: &PruningPlan,
    max_gen: usize,
    base_seed: u64,
) -> Response {
    let body = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let dataset = body
        .get("dataset")
        .as_str()
        .and_then(Dataset::parse)
        .unwrap_or(Dataset::Avqa);
    let index = body.get("index").as_usize().unwrap_or(0) as u64;
    let vanilla = body.get("no_pruning").as_bool().unwrap_or(false);
    let high_priority = body.get("priority").as_str() == Some("high");
    let req_max_gen = body
        .get("max_gen")
        .as_usize()
        .map(|n| n.clamp(1, max_gen))
        .unwrap_or(max_gen);
    let deadline = body
        .get("deadline_ms")
        .as_usize()
        .map(|ms| Duration::from_millis(ms as u64));
    let mut sample = gen_sample(layout, dataset, index, base_seed);
    // Optional question override: re-ask about the same sample (same AV
    // prefix, different text suffix) — the prefix-cache workload shape.
    if let Some(qname) = body.get("question").as_str() {
        match QuestionKind::parse(qname) {
            Some(q) => sample = sample.with_question(q),
            None => {
                return Response::text(
                    400,
                    "question must be one of what_scene|what_sound|scene_sound",
                )
            }
        }
    }
    let request = GenRequest {
        prompt: sample.prompt.clone(),
        segments: sample.segments.clone(),
        frame_of: sample.frame_of.clone(),
        opts: GenerateOptions {
            plan: if vanilla { PruningPlan::vanilla() } else { plan.clone() },
            max_gen: req_max_gen,
            ..Default::default()
        },
        priority: if high_priority { Priority::High } else { Priority::Normal },
        deadline,
    };
    let (id, rx) = match coord.submit_with_id(request) {
        Ok(ok) => ok,
        Err(SubmitError::Full(_)) => {
            return Response::text(429, "queue full").with_header("retry-after", "1")
        }
        Err(SubmitError::Closed(_)) => {
            return Response::text(503, "shutting down")
        }
    };
    // Echo the client's trace id verbatim when it sent one; otherwise
    // surface the pool-assigned id (also in the JSON, for /v1/cancel).
    let id_str = req
        .header("x-request-id")
        .map(|s| s.to_string())
        .unwrap_or_else(|| id.to_string());
    for ev in rx {
        match ev {
            Event::Token(_) => {}
            Event::Done(res) => {
                let correct = exact_match(&res.tokens, &sample.answer);
                let out = Json::obj(vec![
                    ("request_id", Json::num(id as f64)),
                    ("answer", Json::str(&render_answer(&res.tokens))),
                    ("expected", Json::str(&render_answer(&sample.answer))),
                    ("correct", Json::Bool(correct)),
                    ("subtask", Json::str(sample.subtask.name())),
                    (
                        "tokens",
                        Json::arr(res.tokens.iter().map(|&t| Json::num(t as f64))),
                    ),
                    ("relative_flops", Json::num(res.relative_flops)),
                    ("prefill_seconds", Json::num(res.prefill_seconds)),
                    ("decode_seconds", Json::num(res.decode_seconds)),
                    ("peak_kv_bytes", Json::num(res.peak_kv_bytes as f64)),
                    ("prefix_hit", Json::Bool(res.prefix_hit)),
                    (
                        "prefix_tokens_reused",
                        Json::num(res.prefix_tokens_reused as f64),
                    ),
                ]);
                return Response::json(200, out.to_string())
                    .with_header("x-request-id", &id_str);
            }
            Event::Error(e) => {
                return Response::text(500, &e).with_header("x-request-id", &id_str)
            }
        }
    }
    Response::text(500, "worker dropped the request").with_header("x-request-id", &id_str)
}
