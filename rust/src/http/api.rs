//! HTTP API surface: the handler shared by `fastav serve`, the serving
//! example, and the integration tests.
//!
//! ## Endpoints
//!
//! | Method | Path               | Purpose |
//! |--------|--------------------|---------|
//! | POST   | `/v2/generate`     | Generate under a **named pruning profile** with optional per-request spec overrides; returns the v1 payload plus the resolved `policy` block. With `"stream": true` the response is `text/event-stream`: a `policy` event up front (request id + resolved spec), one `token` event per decoded token, then exactly one `done` or `error` event (see `docs/STREAMING.md`). |
//! | POST   | `/v1/generate`     | Legacy surface: a thin adapter onto the registry's default profile (`no_pruning: true` → the `off` profile). **Responses** are byte-compatible with the pre-profile API (same key set, same values for the same config — golden-tested); requests are now strictly validated, so bodies with unknown fields that were silently tolerated before get a 400. |
//! | GET    | `/v1/policies`     | The profile registry: default profile name + every profile's canonical spec, `spec_hash`, and prefix-shareability. |
//! | POST   | `/v1/cancel`       | Cooperative cancellation by request id. |
//! | POST   | `/v1/cache/flush`  | Evict every lease-free AV-prefix cache entry. |
//! | GET    | `/v1/pool`         | Per-replica status (incl. health/restarts/panics), conservation ledger, supervision summary, prefix-cache stats (aggregate **and** per-pruning-config rows), KV block gauges, decode-batch occupancy, latency summaries (TTFT + per-profile generate). |
//! | GET    | `/v1/health`       | Readiness: `200 {"status":"ok"}` when every replica is healthy, `200 {"status":"degraded"}` while some are restarting or dead but at least one can serve, `503 {"status":"unavailable"}` only when **all** replicas are dead (circuit breaker tripped everywhere). Per-replica health/restart/panic detail inline. |
//! | GET    | `/v1/traces`       | Recent sampled request traces, newest first: per-request phase breakdown (queue/admit/prefill/decode seconds), TTFT, FLOP totals. Empty with `enabled: false` when tracing is off. |
//! | GET    | `/v1/trace/{id}`   | One request's full span tree (`?format=chrome` → Chrome trace-event JSON loadable in Perfetto, replica/shard tracks as threads). 404 when the id was never sampled or has aged out of the ring. |
//! | GET    | `/metrics`         | Prometheus text exposition (includes `fastav_requests_total{profile="..."}`). |
//! | GET    | `/healthz`         | Liveness. |
//!
//! ## Request bodies
//!
//! Both generate endpoints take a JSON object and **reject unknown
//! fields with a 400 listing them** (a typo like `"max_token"` fails
//! loudly instead of silently using defaults).
//!
//! * `POST /v1/generate` — `{"dataset": "...", "index": N,
//!   "no_pruning": bool, "priority": "high"?, "max_gen": N?,
//!   "deadline_ms": N?, "question": "what_scene"|"what_sound"|
//!   "scene_sound"?}`. The optional `question` override re-asks a
//!   *different* question about the same sample — the workload shape the
//!   AV-prefix cache accelerates.
//! * `POST /v2/generate` — the same request fields minus `no_pruning`,
//!   plus `"profile": "name"?` (default: the registry default),
//!   `"pruning": {spec overrides}?` (deep-merged onto the profile, then
//!   re-validated; see `crate::policy`), and `"stream": bool?`
//!   (default false: the buffered JSON response, byte-unchanged). The
//!   response adds `"policy": {"profile", "spec", "spec_hash"}` with
//!   the fully resolved spec the request actually ran under; the
//!   streamed form carries the same resolved-policy block in its
//!   leading `policy` event and the full buffered payload in `done`.
//!
//! Backpressure mapping: a full queue is `429` with `Retry-After`; a
//! shutting-down pool is `503`. Every response echoes the client's
//! `x-request-id` header (or the pool-assigned id on generate) for
//! request tracing.

use std::io::Write;
use std::sync::Arc;
use std::time::Duration;

use super::{Action, Handler, Request, Response, StreamingResponse};
use crate::avsynth::{gen_sample, Dataset, QuestionKind, Sample};
use crate::coordinator::{Coordinator, Event, GenRequest, Priority};
use crate::eval::exact_match;
use crate::metrics::labeled;
use crate::model::{GenerateResult, Sampling};
use crate::policy::{PolicyRegistry, PruningSpec};
use crate::serving::{ReplicaHealth, SubmitError};
use crate::streaming::{StreamReceiver, StreamRecv};
use crate::tokens::{render_answer, Layout};
use crate::util::json::Json;

/// Fields `POST /v1/generate` accepts; anything else is a 400.
const V1_GENERATE_KEYS: &[&str] = &[
    "dataset", "index", "no_pruning", "priority", "max_gen", "deadline_ms", "question",
];

/// Fields `POST /v2/generate` accepts (`no_pruning` is subsumed by the
/// `off` profile).
const V2_GENERATE_KEYS: &[&str] = &[
    "dataset", "index", "priority", "max_gen", "deadline_ms", "question", "profile",
    "pruning", "stream",
];

/// Build the request handler for a running coordinator. `registry` maps
/// profile names to pruning specs (its default profile is what
/// `/v1/generate` serves); `max_gen` is the operator-configured
/// generation cap: the default for requests that don't ask, and the
/// ceiling for requests that do.
pub fn make_handler(
    coord: Arc<Coordinator>,
    layout: Layout,
    registry: Arc<PolicyRegistry>,
    max_gen: usize,
    base_seed: u64,
) -> Handler {
    Arc::new(move |req: &Request| {
        // Streaming pre-check: `POST /v2/generate` with `"stream": true`
        // takes the SSE path; everything else (including stream bodies
        // that fail to parse — they 400 identically) stays buffered.
        if req.method == "POST" && req.path == "/v2/generate" && wants_stream(req) {
            return generate_stream(req, &coord, &layout, &registry, max_gen, base_seed);
        }
        let resp = route(req, &coord, &layout, &registry, max_gen, base_seed);
        echo_request_id(req, resp).into()
    })
}

/// Whether a `/v2/generate` body opts into SSE streaming. Unparseable
/// bodies return false — the buffered path rejects them with the same
/// 400 it always did.
fn wants_stream(req: &Request) -> bool {
    std::str::from_utf8(&req.body)
        .ok()
        .and_then(|s| Json::parse(s).ok())
        .map(|j| j.get("stream").as_bool() == Some(true))
        .unwrap_or(false)
}

/// Echo the client's `x-request-id` unless the handler already set one
/// (generate sets the pool-assigned id when the client sent none).
fn echo_request_id(req: &Request, resp: Response) -> Response {
    match req.header("x-request-id") {
        Some(v) if !resp.headers.iter().any(|(k, _)| k == "x-request-id") => {
            resp.with_header("x-request-id", v)
        }
        _ => resp,
    }
}

fn route(
    req: &Request,
    coord: &Coordinator,
    layout: &Layout,
    registry: &PolicyRegistry,
    max_gen: usize,
    base_seed: u64,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, "ok"),
        ("GET", "/metrics") => Response::text(200, &coord.metrics.export()),
        ("GET", "/v1/pool") => pool_status(coord),
        ("GET", "/v1/health") => health(coord),
        ("GET", "/v1/policies") => Response::json(200, registry.to_json().to_string()),
        ("POST", "/v1/generate") => {
            generate(req, coord, layout, registry, max_gen, base_seed, ApiVersion::V1)
        }
        ("POST", "/v2/generate") => {
            generate(req, coord, layout, registry, max_gen, base_seed, ApiVersion::V2)
        }
        ("POST", "/v1/cancel") => cancel(req, coord),
        ("POST", "/v1/cache/flush") => cache_flush(coord),
        ("GET", "/v1/traces") => traces_list(coord),
        ("GET", p) if p.starts_with("/v1/trace/") => trace_get(p, coord),
        ("GET", _) | ("POST", _) => Response::text(404, "not found"),
        _ => Response::text(405, "method not allowed"),
    }
}

fn parse_body(req: &Request) -> Result<Json, Response> {
    std::str::from_utf8(&req.body)
        .map_err(|_| ())
        .and_then(|s| Json::parse(s).map_err(|_| ()))
        .map_err(|_| Response::text(400, "invalid JSON body"))
}

/// Strict body validation: the generate endpoints take an object and
/// reject unknown fields by name (shared logic with the spec/profile
/// parsers — `policy::check_keys`), so client typos fail loudly.
fn check_body_keys(body: &Json, allowed: &[&str]) -> Result<(), Response> {
    let Some(obj) = body.as_obj() else {
        return Err(Response::text(400, "request body must be a JSON object"));
    };
    crate::policy::check_keys(obj, allowed, "request body")
        .map_err(|e| Response::text(400, &e))
}

fn pool_status(coord: &Coordinator) -> Response {
    let replicas = coord.pool_status().into_iter().map(|r| {
        Json::obj(vec![
            ("id", Json::num(r.id as f64)),
            ("queued", Json::num(r.queued as f64)),
            ("active", Json::num(r.active as f64)),
            ("tp_degree", Json::num(r.tp_degree as f64)),
            ("kv_bytes", Json::num(r.kv_bytes as f64)),
            ("kv_budget_bytes", Json::num(r.kv_budget_bytes as f64)),
            ("steps_total", Json::num(r.steps_total as f64)),
            ("steps_per_sec", Json::num(r.steps_per_sec as f64)),
            ("completed", Json::num(r.completed as f64)),
            ("decode_batch_quanta", Json::num(r.decode_batch_quanta as f64)),
            ("decode_batch_tokens", Json::num(r.decode_batch_tokens as f64)),
            ("health", Json::str(r.health.name())),
            ("restarts", Json::num(r.restarts as f64)),
            ("panics", Json::num(r.panics as f64)),
        ])
    });
    let s = coord.pool_stats();
    let p = coord.prefix_stats();
    let b = coord.block_stats();
    let (bq, bt) = coord.decode_batch_stats();
    // Per-pruning-config rows: mixed-profile pools report per-spec
    // reuse instead of one profile-blind aggregate. Config keys are
    // hashes; hex keeps them exact (f64 JSON numbers cannot hold u64).
    let per_config = coord.prefix_per_config().into_iter().map(|r| {
        Json::obj(vec![
            ("config", Json::str(&format!("{:016x}", r.config))),
            ("entries", Json::num(r.entries as f64)),
            ("bytes", Json::num(r.bytes as f64)),
            ("trie_nodes", Json::num(r.trie_nodes as f64)),
            ("hits", Json::num(r.hits as f64)),
            ("misses", Json::num(r.misses as f64)),
        ])
    });
    let out = Json::obj(vec![
        ("replicas", Json::arr(replicas)),
        (
            "stats",
            Json::obj(vec![
                ("submitted", Json::num(s.submitted as f64)),
                ("rejected", Json::num(s.rejected as f64)),
                ("completed", Json::num(s.completed as f64)),
                ("failed", Json::num(s.failed as f64)),
                ("canceled", Json::num(s.canceled as f64)),
                ("expired", Json::num(s.expired as f64)),
                ("retried", Json::num(s.retried as f64)),
                ("in_queue", Json::num(s.in_queue as f64)),
                ("in_flight", Json::num(s.in_flight as f64)),
            ]),
        ),
        ("supervision", supervision_summary(coord)),
        (
            "prefix_cache",
            Json::obj(vec![
                ("entries", Json::num(p.entries as f64)),
                ("bytes", Json::num(p.bytes as f64)),
                ("active_leases", Json::num(p.active_leases as f64)),
                ("trie_nodes", Json::num(p.trie_nodes as f64)),
                ("hits", Json::num(p.hits as f64)),
                ("misses", Json::num(p.misses as f64)),
                ("evictions", Json::num(p.evictions as f64)),
                ("insertions", Json::num(p.insertions as f64)),
                ("per_config", Json::arr(per_config)),
            ]),
        ),
        (
            "kv_blocks",
            Json::obj(vec![
                ("used", Json::num(b.used as f64)),
                ("shared", Json::num(b.shared as f64)),
                ("free", Json::num(b.free as f64)),
                ("bytes_used", Json::num(b.bytes_used as f64)),
            ]),
        ),
        (
            "decode_batch",
            Json::obj(vec![
                ("quanta", Json::num(bq as f64)),
                ("tokens", Json::num(bt as f64)),
                (
                    "mean_occupancy",
                    Json::num(if bq == 0 { 0.0 } else { bt as f64 / bq as f64 }),
                ),
            ]),
        ),
        ("tier", tier_summary(coord)),
        ("streams", streams_summary(coord)),
        ("latency", latency_summary(coord)),
    ]);
    Response::json(200, out.to_string())
}

/// Streaming-session block for `/v1/pool`: live session counts
/// (active includes parked; parked are the slow consumers currently
/// gated out of decode quanta) plus the stream-duration summary, also
/// broken out per pruning profile (the labeled
/// `fastav_stream_duration_seconds{profile=...}` series).
fn streams_summary(coord: &Coordinator) -> Json {
    let st = coord.stream_stats();
    let dur = coord.metrics.histogram("fastav_stream_duration_seconds");
    let mut per_profile = Vec::new();
    for (name, h) in coord.metrics.histogram_entries() {
        if let Some(p) = name
            .strip_prefix("fastav_stream_duration_seconds{profile=\"")
            .and_then(|r| r.strip_suffix("\"}"))
        {
            per_profile.push(Json::obj(vec![
                ("profile", Json::str(p)),
                ("duration", hist_summary(&h)),
            ]));
        }
    }
    Json::obj(vec![
        ("active", Json::num(st.active as f64)),
        ("parked", Json::num(st.parked as f64)),
        ("completed", Json::num(st.completed as f64)),
        ("duration", hist_summary(&dur)),
        ("per_profile", Json::arr(per_profile)),
    ])
}

/// Spill-tier block for `/v1/pool`: per-tier occupancy, movement
/// counters, pruner progress, and the promotion-latency summary.
/// `{"enabled": false}` when the pool runs device-only.
fn tier_summary(coord: &Coordinator) -> Json {
    let Some(t) = coord.tier_stats() else {
        return Json::obj(vec![("enabled", Json::Bool(false))]);
    };
    let promote = coord.metrics.histogram("fastav_tier_promote_seconds");
    Json::obj(vec![
        ("enabled", Json::Bool(true)),
        (
            "pending",
            Json::obj(vec![
                ("entries", Json::num(t.pending_entries as f64)),
                ("bytes", Json::num(t.pending_bytes as f64)),
            ]),
        ),
        (
            "ram",
            Json::obj(vec![
                ("entries", Json::num(t.ram_entries as f64)),
                ("bytes", Json::num(t.ram_bytes as f64)),
                ("demotions", Json::num(t.demotions_ram as f64)),
                ("promotions", Json::num(t.promotions_ram as f64)),
                ("drops", Json::num(t.drops_ram as f64)),
            ]),
        ),
        (
            "disk",
            Json::obj(vec![
                ("entries", Json::num(t.disk_entries as f64)),
                ("bytes", Json::num(t.disk_bytes as f64)),
                ("file_bytes", Json::num(t.disk_file_bytes as f64)),
                ("demotions", Json::num(t.demotions_disk as f64)),
                ("promotions", Json::num(t.promotions_disk as f64)),
                ("drops", Json::num(t.drops_disk as f64)),
            ]),
        ),
        (
            "pruner",
            Json::obj(vec![
                ("runs", Json::num(t.prune_runs as f64)),
                ("entries", Json::num(t.prune_entries as f64)),
                ("bytes", Json::num(t.prune_bytes as f64)),
                ("cursor_stage", Json::num(t.cursor.stage as f64)),
                ("cursor_ram_seq", Json::num(t.cursor.ram_seq as f64)),
            ]),
        ),
        ("promote_latency", hist_summary(&promote)),
    ])
}

/// Supervision block for `/v1/pool`: replica health census plus the
/// pool-wide restart/panic totals the supervisor maintains.
fn supervision_summary(coord: &Coordinator) -> Json {
    let status = coord.pool_status();
    let count = |h: ReplicaHealth| status.iter().filter(|r| r.health == h).count();
    let restarts: u64 = status.iter().map(|r| r.restarts).sum();
    let panics: u64 = status.iter().map(|r| r.panics).sum();
    Json::obj(vec![
        ("healthy", Json::num(count(ReplicaHealth::Healthy) as f64)),
        ("restarting", Json::num(count(ReplicaHealth::Restarting) as f64)),
        ("dead", Json::num(count(ReplicaHealth::Dead) as f64)),
        ("restarts_total", Json::num(restarts as f64)),
        ("panics_total", Json::num(panics as f64)),
    ])
}

/// `GET /v1/health`: readiness for load balancers. `503` **only** when
/// every replica is dead — a pool with any serving capacity left
/// answers `200`, with `"degraded"` flagging partial outages so
/// dashboards can alert before total loss.
fn health(coord: &Coordinator) -> Response {
    let status = coord.pool_status();
    let replicas = status.iter().map(|r| {
        Json::obj(vec![
            ("id", Json::num(r.id as f64)),
            ("health", Json::str(r.health.name())),
            ("restarts", Json::num(r.restarts as f64)),
            ("panics", Json::num(r.panics as f64)),
        ])
    });
    let all_dead = coord.all_dead();
    let healthy = coord.healthy_count();
    let state = if all_dead {
        "unavailable"
    } else if healthy == status.len() {
        "ok"
    } else {
        "degraded"
    };
    let out = Json::obj(vec![
        ("status", Json::str(state)),
        ("replicas", Json::arr(replicas)),
        ("healthy", Json::num(healthy as f64)),
        (
            "restarting",
            Json::num(
                status.iter().filter(|r| r.health == ReplicaHealth::Restarting).count()
                    as f64,
            ),
        ),
        (
            "dead",
            Json::num(
                status.iter().filter(|r| r.health == ReplicaHealth::Dead).count() as f64,
            ),
        ),
    ]);
    Response::json(if all_dead { 503 } else { 200 }, out.to_string())
}

/// Summarize a histogram as count/mean/p50/p95/p99 (all seconds).
fn hist_summary(h: &crate::metrics::Histogram) -> Json {
    let count = h.count();
    let sum = h.sum_seconds();
    Json::obj(vec![
        ("count", Json::num(count as f64)),
        ("mean_seconds", Json::num(if count == 0 { 0.0 } else { sum / count as f64 })),
        ("p50_seconds", Json::num(h.quantile(0.5))),
        ("p95_seconds", Json::num(h.quantile(0.95))),
        ("p99_seconds", Json::num(h.quantile(0.99))),
    ])
}

/// SLO latency block for `/v1/pool`: TTFT and end-to-end generate
/// latency, the latter also broken out per pruning profile (the labeled
/// `fastav_generate_seconds{profile=...}` series).
fn latency_summary(coord: &Coordinator) -> Json {
    let ttft = coord.metrics.histogram("fastav_ttft_seconds");
    let gen = coord.metrics.histogram("fastav_generate_seconds");
    let mut per_profile = Vec::new();
    for (name, h) in coord.metrics.histogram_entries() {
        if let Some(p) = name
            .strip_prefix("fastav_generate_seconds{profile=\"")
            .and_then(|r| r.strip_suffix("\"}"))
        {
            per_profile.push(Json::obj(vec![
                ("profile", Json::str(p)),
                ("generate", hist_summary(&h)),
            ]));
        }
    }
    // Mesh pipeline health (traced quanta only): per-shard dispatch
    // latency and the fraction of KV-upload time hidden under an
    // in-flight dispatch (gauge stored in permille).
    let dispatch = coord.metrics.histogram("fastav_mesh_dispatch_seconds");
    let overlap = coord.metrics.gauge("fastav_upload_overlap_ratio").get();
    Json::obj(vec![
        ("ttft", hist_summary(&ttft)),
        ("generate", hist_summary(&gen)),
        ("mesh_dispatch", hist_summary(&dispatch)),
        ("upload_overlap_ratio", Json::num(overlap as f64 / 1000.0)),
        ("per_profile", Json::arr(per_profile)),
    ])
}

/// `GET /v1/traces`: summaries of the most recent sampled traces across
/// every replica ring, newest first.
fn traces_list(coord: &Coordinator) -> Response {
    let tracer = coord.tracer();
    let traces = tracer
        .recent(64)
        .iter()
        .map(|t| crate::trace::export::summary_json(t))
        .collect::<Vec<_>>();
    let out = Json::obj(vec![
        ("enabled", Json::Bool(tracer.enabled())),
        ("traces", Json::arr(traces)),
    ]);
    Response::json(200, out.to_string())
}

/// `GET /v1/trace/{id}`: one request's span tree, or the Chrome
/// trace-event form with `?format=chrome`.
fn trace_get(path: &str, coord: &Coordinator) -> Response {
    let rest = &path["/v1/trace/".len()..];
    let (id_str, query) = match rest.split_once('?') {
        Some((i, q)) => (i, q),
        None => (rest, ""),
    };
    let Ok(id) = id_str.parse::<u64>() else {
        return Response::text(400, "trace id must be an integer request id");
    };
    let Some(trace) = coord.tracer().get(id) else {
        return Response::text(404, "no sampled trace for that request id");
    };
    let chrome = query.split('&').any(|kv| kv == "format=chrome");
    let out = if chrome {
        crate::trace::export::chrome_json(&trace)
    } else {
        crate::trace::export::trace_json(&trace)
    };
    Response::json(200, out.to_string())
}

/// `POST /v1/cache/flush`: drain every tier — device prefix cache plus
/// the host-RAM and disk spill tiers — and reset the pruner checkpoint.
/// Top-level `flushed_entries`/`freed_bytes` keep the pre-tier response
/// shape (summed across tiers); `tiers` breaks the totals out.
fn cache_flush(coord: &Coordinator) -> Response {
    let report = coord.flush_all_tiers();
    let tier = report.tier.unwrap_or_default();
    let total_entries = report.device_entries
        + tier.pending_entries
        + tier.ram_entries
        + tier.disk_entries;
    let total_bytes =
        report.device_bytes + tier.pending_bytes + tier.ram_bytes + tier.disk_bytes;
    let per_tier = |entries: usize, bytes: usize| {
        Json::obj(vec![
            ("flushed_entries", Json::num(entries as f64)),
            ("freed_bytes", Json::num(bytes as f64)),
        ])
    };
    let out = Json::obj(vec![
        ("flushed_entries", Json::num(total_entries as f64)),
        ("freed_bytes", Json::num(total_bytes as f64)),
        (
            "tiers",
            Json::obj(vec![
                ("device", per_tier(report.device_entries, report.device_bytes)),
                (
                    "pending",
                    per_tier(tier.pending_entries, tier.pending_bytes),
                ),
                ("ram", per_tier(tier.ram_entries, tier.ram_bytes)),
                ("disk", per_tier(tier.disk_entries, tier.disk_bytes)),
            ]),
        ),
        ("pruner_checkpoint_reset", Json::Bool(report.tier.is_some())),
    ]);
    Response::json(200, out.to_string())
}

fn cancel(req: &Request, coord: &Coordinator) -> Response {
    let body = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let Some(id) = body.get("request_id").as_usize() else {
        return Response::text(400, "request_id (integer) required");
    };
    let found = coord.cancel(id as u64);
    let out = Json::obj(vec![
        ("request_id", Json::num(id as f64)),
        ("canceled", Json::Bool(found)),
    ]);
    Response::json(if found { 200 } else { 404 }, out.to_string())
}

#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum ApiVersion {
    V1,
    V2,
}

/// A generate request resolved through body validation, policy
/// resolution, and sample synthesis — everything both front doors
/// (HTTP and gRPC) and both delivery modes (buffered and streamed)
/// share before submission.
pub(crate) struct Assembled {
    pub request: GenRequest,
    pub sample: Sample,
    pub profile: String,
    pub spec: PruningSpec,
}

/// Validate a generate body and assemble the pool request: strict key
/// check, policy resolution (profile + overrides), sample synthesis
/// with the optional question override, clamps, and the per-profile
/// traffic counter. Returns the HTTP error response on invalid input —
/// the gRPC front door maps it onto `INVALID_ARGUMENT`.
pub(crate) fn assemble_request(
    coord: &Coordinator,
    body: &Json,
    layout: &Layout,
    registry: &PolicyRegistry,
    max_gen: usize,
    base_seed: u64,
    version: ApiVersion,
) -> Result<Assembled, Response> {
    let allowed = match version {
        ApiVersion::V1 => V1_GENERATE_KEYS,
        ApiVersion::V2 => V2_GENERATE_KEYS,
    };
    check_body_keys(body, allowed)?;
    let (profile, spec) = resolve_policy(body, registry, version)?;
    let dataset = body
        .get("dataset")
        .as_str()
        .and_then(Dataset::parse)
        .unwrap_or(Dataset::Avqa);
    let index = body.get("index").as_usize().unwrap_or(0) as u64;
    let high_priority = body.get("priority").as_str() == Some("high");
    let req_max_gen = body
        .get("max_gen")
        .as_usize()
        .map(|n| n.clamp(1, max_gen))
        .unwrap_or(max_gen);
    let deadline = body
        .get("deadline_ms")
        .as_usize()
        .map(|ms| Duration::from_millis(ms as u64));
    let mut sample = gen_sample(layout, dataset, index, base_seed);
    // Optional question override: re-ask about the same sample (same AV
    // prefix, different text suffix) — the prefix-cache workload shape.
    if let Some(qname) = body.get("question").as_str() {
        match QuestionKind::parse(qname) {
            Some(q) => sample = sample.with_question(q),
            None => {
                return Err(Response::text(
                    400,
                    "question must be one of what_scene|what_sound|scene_sound",
                ))
            }
        }
    }
    let request = GenRequest {
        prompt: sample.prompt.clone(),
        segments: sample.segments.clone(),
        frame_of: sample.frame_of.clone(),
        spec: spec.clone(),
        max_gen: req_max_gen,
        sampling: Sampling::default(),
        priority: if high_priority { Priority::High } else { Priority::Normal },
        deadline,
        profile: Some(profile.clone()),
    };
    // Per-profile traffic accounting; label values are registry-bounded
    // (only known profile names reach this point). Series semantics:
    // the *labeled* `fastav_requests_total{profile=...}` series count
    // front-door generate requests after policy resolution (including
    // ones later rejected with 429/503), while the unlabeled series
    // counts every pool submission (HTTP, gRPC, or direct); sum the
    // labeled series — never the whole family — for per-profile
    // dashboards.
    coord
        .metrics
        .counter(&labeled("fastav_requests_total", "profile", &profile))
        .inc();
    Ok(Assembled { request, sample, profile, spec })
}

/// The completed-generation payload — identical JSON for the buffered
/// `200` body and the SSE `done` event, so streamed and buffered runs
/// of one request are byte-identical in everything but framing.
pub(crate) fn done_payload(
    coord: &Coordinator,
    id: u64,
    asm: &Assembled,
    res: &GenerateResult,
    version: ApiVersion,
) -> Json {
    let correct = exact_match(&res.tokens, &asm.sample.answer);
    let mut fields = vec![
        ("request_id", Json::num(id as f64)),
        ("answer", Json::str(&render_answer(&res.tokens))),
        ("expected", Json::str(&render_answer(&asm.sample.answer))),
        ("correct", Json::Bool(correct)),
        ("subtask", Json::str(asm.sample.subtask.name())),
        (
            "tokens",
            Json::arr(res.tokens.iter().map(|&t| Json::num(t as f64))),
        ),
        ("relative_flops", Json::num(res.relative_flops)),
        ("prefill_seconds", Json::num(res.prefill_seconds)),
        ("decode_seconds", Json::num(res.decode_seconds)),
        ("peak_kv_bytes", Json::num(res.peak_kv_bytes as f64)),
        ("prefix_hit", Json::Bool(res.prefix_hit)),
        (
            "prefix_tokens_reused",
            Json::num(res.prefix_tokens_reused as f64),
        ),
    ];
    // v2 returns the resolved policy; v1 stays byte-compatible with the
    // pre-profile response shape.
    if version == ApiVersion::V2 {
        fields.push((
            "policy",
            Json::obj(vec![
                ("profile", Json::str(&asm.profile)),
                ("spec", asm.spec.to_json()),
                ("spec_hash", Json::str(&asm.spec.spec_hash_hex())),
            ]),
        ));
        // Sampled requests carry their lifecycle timing inline (the
        // same summary `/v1/traces` serves); unsampled requests omit
        // the block entirely.
        if let Some(t) = coord.tracer().get(id) {
            fields.push(("timing", crate::trace::export::summary_json(&t)));
        }
    }
    Json::obj(fields)
}

/// Resolve the pruning policy a generate request runs under.
///
/// * v1: the registry's default profile, or `off` when
///   `no_pruning: true` — byte-compatible with the pre-profile API.
/// * v2: the named `profile` (default: registry default) with the
///   optional `pruning` override object merged on and re-validated.
fn resolve_policy(
    body: &Json,
    registry: &PolicyRegistry,
    version: ApiVersion,
) -> Result<(String, PruningSpec), Response> {
    match version {
        ApiVersion::V1 => {
            if body.get("no_pruning").as_bool().unwrap_or(false) {
                let spec = registry.get("off").cloned().unwrap_or_else(PruningSpec::off);
                Ok(("off".to_string(), spec))
            } else {
                Ok((
                    registry.default_name().to_string(),
                    registry.default_spec().clone(),
                ))
            }
        }
        ApiVersion::V2 => {
            let obj = body.as_obj().expect("checked by check_body_keys");
            let name = match obj.get("profile") {
                None => registry.default_name(),
                Some(v) => v.as_str().ok_or_else(|| {
                    Response::text(400, "profile must be a string")
                })?,
            };
            let Some(base) = registry.get(name) else {
                return Err(Response::text(
                    400,
                    &format!(
                        "unknown profile '{}' (known: {})",
                        name,
                        registry.names().join(", ")
                    ),
                ));
            };
            let spec = match obj.get("pruning") {
                None => base.clone(),
                Some(overrides) => base.with_overrides(overrides).map_err(|e| {
                    Response::text(400, &format!("invalid pruning override: {}", e))
                })?,
            };
            Ok((name.to_string(), spec))
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn generate(
    req: &Request,
    coord: &Coordinator,
    layout: &Layout,
    registry: &PolicyRegistry,
    max_gen: usize,
    base_seed: u64,
    version: ApiVersion,
) -> Response {
    let body = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let asm = match assemble_request(coord, &body, layout, registry, max_gen, base_seed, version)
    {
        Ok(a) => a,
        Err(resp) => return resp,
    };
    let (id, rx) = match coord.submit_with_id(asm.request.clone()) {
        Ok(ok) => ok,
        Err(SubmitError::Full(_)) => {
            return Response::text(429, "queue full").with_header("retry-after", "1")
        }
        Err(SubmitError::Closed(_)) => {
            return Response::text(503, "shutting down")
        }
    };
    // Echo the client's trace id verbatim when it sent one; otherwise
    // surface the pool-assigned id (also in the JSON, for /v1/cancel).
    let id_str = req
        .header("x-request-id")
        .map(|s| s.to_string())
        .unwrap_or_else(|| id.to_string());
    for ev in rx {
        match ev {
            Event::Token(_) => {}
            Event::Done(res) => {
                let payload = done_payload(coord, id, &asm, &res, version);
                return Response::json(200, payload.to_string())
                    .with_header("x-request-id", &id_str);
            }
            Event::Error(e) => {
                return Response::text(500, &e).with_header("x-request-id", &id_str)
            }
        }
    }
    Response::text(500, "worker dropped the request").with_header("x-request-id", &id_str)
}

/// One SSE frame: `event: <name>` + a single `data:` line. Payloads are
/// single-line JSON, so no data-splitting is needed; the flush after
/// each frame is what makes tokens visible as they decode.
fn sse_event(w: &mut dyn Write, event: &str, data: &str) -> std::io::Result<()> {
    write!(w, "event: {}\ndata: {}\n\n", event, data)?;
    w.flush()
}

/// `POST /v2/generate` with `"stream": true`: submit through the same
/// assembly path as the buffered form, then return a streaming action
/// whose body relays the per-request token channel as SSE. A write
/// failure (client went away mid-stream) cancels the request; dropping
/// the receiver disconnects the channel, so the replica stops within
/// one scheduling quantum either way.
fn generate_stream(
    req: &Request,
    coord: &Arc<Coordinator>,
    layout: &Layout,
    registry: &Arc<PolicyRegistry>,
    max_gen: usize,
    base_seed: u64,
) -> Action {
    let body = match parse_body(req) {
        Ok(j) => j,
        Err(resp) => return echo_request_id(req, resp).into(),
    };
    let asm = match assemble_request(
        coord, &body, layout, registry, max_gen, base_seed, ApiVersion::V2,
    ) {
        Ok(a) => a,
        Err(resp) => return echo_request_id(req, resp).into(),
    };
    let (id, rx) = match coord.submit_streaming(asm.request.clone()) {
        Ok(ok) => ok,
        Err(SubmitError::Full(_)) => {
            return echo_request_id(
                req,
                Response::text(429, "queue full").with_header("retry-after", "1"),
            )
            .into()
        }
        Err(SubmitError::Closed(_)) => {
            return echo_request_id(req, Response::text(503, "shutting down")).into()
        }
    };
    let id_str = req
        .header("x-request-id")
        .map(|s| s.to_string())
        .unwrap_or_else(|| id.to_string());
    let coord = Arc::clone(coord);
    Action::Stream(StreamingResponse {
        status: 200,
        content_type: "text/event-stream".into(),
        headers: vec![
            ("cache-control".into(), "no-cache".into()),
            ("x-request-id".into(), id_str),
        ],
        body: Box::new(move |w| {
            let out = relay_stream(w, &coord, id, &rx, &asm);
            if out.is_err() {
                // The client hung up mid-stream: flip the cancel flag
                // now; dropping `rx` (below) also disconnects the
                // channel, so the replica stops within one quantum.
                coord.cancel(id);
            }
            out
        }),
    })
}

/// Relay the stream channel onto an SSE body: the resolved-policy block
/// first, one `token` event per decoded token, then exactly one
/// `done`/`error` event.
fn relay_stream(
    w: &mut dyn Write,
    coord: &Coordinator,
    id: u64,
    rx: &StreamReceiver,
    asm: &Assembled,
) -> std::io::Result<()> {
    let policy = Json::obj(vec![
        ("request_id", Json::num(id as f64)),
        ("profile", Json::str(&asm.profile)),
        ("spec", asm.spec.to_json()),
        ("spec_hash", Json::str(&asm.spec.spec_hash_hex())),
    ]);
    sse_event(w, "policy", &policy.to_string())?;
    let mut index = 0u64;
    loop {
        match rx.recv(Duration::from_millis(100)) {
            StreamRecv::Token(t) => {
                let data = Json::obj(vec![
                    ("index", Json::num(index as f64)),
                    ("token", Json::num(t as f64)),
                ]);
                index += 1;
                sse_event(w, "token", &data.to_string())?;
            }
            StreamRecv::Done(res) => {
                let payload = done_payload(coord, id, asm, &res, ApiVersion::V2);
                return sse_event(w, "done", &payload.to_string());
            }
            StreamRecv::Error(e) => {
                let data = Json::obj(vec![("error", Json::str(&e))]);
                return sse_event(w, "error", &data.to_string());
            }
            StreamRecv::TimedOut => continue, // decode still running
            StreamRecv::SenderGone => {
                let data =
                    Json::obj(vec![("error", Json::str("worker dropped the request"))]);
                return sse_event(w, "error", &data.to_string());
            }
        }
    }
}
