//! HTTP API surface: the handler shared by `fastav serve`, the serving
//! example, and the integration tests.
//!
//! Endpoints:
//! * `POST /v1/generate` — body `{"dataset": "...", "index": N,
//!   "no_pruning": bool}`; generates the avsynth sample's answer and
//!   returns tokens + efficiency metrics.
//! * `GET /metrics` — Prometheus text exposition.
//! * `GET /healthz` — liveness.

use std::sync::Arc;

use super::{Handler, Request, Response};
use crate::avsynth::{gen_sample, Dataset};
use crate::coordinator::{Coordinator, GenRequest, Priority};
use crate::eval::exact_match;
use crate::model::{GenerateOptions, PruningPlan};
use crate::tokens::{render_answer, Layout};
use crate::util::json::Json;

/// Build the request handler for a running coordinator.
pub fn make_handler(
    coord: Arc<Coordinator>,
    layout: Layout,
    plan: PruningPlan,
    max_gen: usize,
    base_seed: u64,
) -> Handler {
    Arc::new(move |req: &Request| route(req, &coord, &layout, &plan, max_gen, base_seed))
}

fn route(
    req: &Request,
    coord: &Coordinator,
    layout: &Layout,
    plan: &PruningPlan,
    max_gen: usize,
    base_seed: u64,
) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, "ok"),
        ("GET", "/metrics") => Response::text(200, &coord.metrics.export()),
        ("POST", "/v1/generate") => generate(req, coord, layout, plan, max_gen, base_seed),
        ("GET", _) | ("POST", _) => Response::text(404, "not found"),
        _ => Response::text(405, "method not allowed"),
    }
}

fn generate(
    req: &Request,
    coord: &Coordinator,
    layout: &Layout,
    plan: &PruningPlan,
    max_gen: usize,
    base_seed: u64,
) -> Response {
    let body = match std::str::from_utf8(&req.body)
        .map_err(|_| ())
        .and_then(|s| Json::parse(s).map_err(|_| ()))
    {
        Ok(j) => j,
        Err(_) => return Response::text(400, "invalid JSON body"),
    };
    let dataset = body
        .get("dataset")
        .as_str()
        .and_then(Dataset::parse)
        .unwrap_or(Dataset::Avqa);
    let index = body.get("index").as_usize().unwrap_or(0) as u64;
    let vanilla = body.get("no_pruning").as_bool().unwrap_or(false);
    let high_priority = body.get("priority").as_str() == Some("high");
    let sample = gen_sample(layout, dataset, index, base_seed);
    let request = GenRequest {
        prompt: sample.prompt.clone(),
        segments: sample.segments.clone(),
        frame_of: sample.frame_of.clone(),
        opts: GenerateOptions {
            plan: if vanilla { PruningPlan::vanilla() } else { plan.clone() },
            max_gen,
            ..Default::default()
        },
        priority: if high_priority { Priority::High } else { Priority::Normal },
    };
    match coord.submit_blocking(request) {
        Ok(res) => {
            let correct = exact_match(&res.tokens, &sample.answer);
            let out = Json::obj(vec![
                ("answer", Json::str(&render_answer(&res.tokens))),
                ("expected", Json::str(&render_answer(&sample.answer))),
                ("correct", Json::Bool(correct)),
                ("subtask", Json::str(sample.subtask.name())),
                (
                    "tokens",
                    Json::arr(res.tokens.iter().map(|&t| Json::num(t as f64))),
                ),
                ("relative_flops", Json::num(res.relative_flops)),
                ("prefill_seconds", Json::num(res.prefill_seconds)),
                ("decode_seconds", Json::num(res.decode_seconds)),
                ("peak_kv_bytes", Json::num(res.peak_kv_bytes as f64)),
            ]);
            Response::json(200, out.to_string())
        }
        Err(e) if format!("{}", e).contains("backpressure") => Response::text(429, "queue full"),
        Err(e) => Response::text(500, &format!("{:#}", e)),
    }
}
