//! Minimal HTTP/1.1 server + client (substrate — no web framework on this
//! image; built directly on `std::net` + the [`crate::util::threadpool`]).
//!
//! Scope: exactly what the serving example needs — `POST /v1/generate`
//! (JSON body), `GET /metrics`, `GET /healthz`. Parsing is incremental and
//! robust to fragmented reads; malformed requests get a 400 instead of a
//! panic (property-tested with garbage inputs).

pub mod api;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::threadpool::ThreadPool;

/// A parsed HTTP request (headers lowercased).
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == lower)
            .map(|(_, v)| v.as_str())
    }
}

/// Response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
    /// Extra headers (lowercase names), e.g. `retry-after`, `x-request-id`.
    pub headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json".into(),
            body: body.into_bytes(),
            headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain".into(),
            body: body.as_bytes().to_vec(),
            headers: Vec::new(),
        }
    }

    /// Attach an extra response header (builder style).
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }

    fn status_line(&self) -> &'static str {
        status_line(self.status)
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: close\r\n",
            self.status_line(),
            self.content_type,
            self.body.len()
        )?;
        for (name, value) in &self.headers {
            write!(w, "{}: {}\r\n", name, value)?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)
    }
}

fn status_line(status: u16) -> &'static str {
    match status {
        200 => "200 OK",
        400 => "400 Bad Request",
        404 => "404 Not Found",
        405 => "405 Method Not Allowed",
        429 => "429 Too Many Requests",
        500 => "500 Internal Server Error",
        503 => "503 Service Unavailable",
        _ => "500 Internal Server Error",
    }
}

/// A response whose body is produced incrementally *after* the head is
/// on the wire (SSE streaming on `/v2/generate`). No `content-length`:
/// the body is delimited by connection close, which HTTP/1.1 permits
/// with `connection: close` — every client that can read SSE handles it.
pub struct StreamingResponse {
    pub status: u16,
    pub content_type: String,
    /// Extra headers (lowercase names), e.g. `x-request-id`.
    pub headers: Vec<(String, String)>,
    /// Runs on the connection's worker thread with the socket as its
    /// writer; returning (or erroring) closes the connection.
    pub body: Box<dyn FnOnce(&mut dyn Write) -> std::io::Result<()> + Send>,
}

impl StreamingResponse {
    fn write_head(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {}\r\ncontent-type: {}\r\nconnection: close\r\n",
            status_line(self.status),
            self.content_type,
        )?;
        for (name, value) in &self.headers {
            write!(w, "{}: {}\r\n", name, value)?;
        }
        write!(w, "\r\n")?;
        w.flush()
    }
}

/// What a handler produces: a buffered response (the default — written
/// in one shot with `content-length`) or a streaming one.
pub enum Action {
    Respond(Response),
    Stream(StreamingResponse),
}

impl From<Response> for Action {
    fn from(r: Response) -> Action {
        Action::Respond(r)
    }
}

/// Incremental request parser outcome.
pub enum ParseOutcome {
    /// Need more bytes.
    Incomplete,
    /// Parsed a full request, consuming `used` bytes.
    Done(Request, usize),
    /// Irrecoverably malformed.
    Bad(&'static str),
}

/// Maximum accepted body (1 MiB) — backpressure against abusive clients.
pub const MAX_BODY: usize = 1 << 20;
const MAX_HEAD: usize = 64 * 1024;

/// Parse an HTTP/1.1 request head + content-length body from `buf`.
pub fn parse_request(buf: &[u8]) -> ParseOutcome {
    let head_end = match find_head_end(buf) {
        Some(e) => e,
        None => {
            return if buf.len() > MAX_HEAD {
                ParseOutcome::Bad("headers too large")
            } else {
                ParseOutcome::Incomplete
            }
        }
    };
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return ParseOutcome::Bad("non-utf8 head"),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if !m.is_empty() && p.starts_with('/') => (m, p, v),
        _ => return ParseOutcome::Bad("bad request line"),
    };
    if !version.starts_with("HTTP/1.") {
        return ParseOutcome::Bad("unsupported version");
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        match line.split_once(':') {
            Some((k, v)) => headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string())),
            None => return ParseOutcome::Bad("bad header"),
        }
    }
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse::<usize>())
        .transpose();
    let content_length = match content_length {
        Ok(cl) => cl.unwrap_or(0),
        Err(_) => return ParseOutcome::Bad("bad content-length"),
    };
    if content_length > MAX_BODY {
        return ParseOutcome::Bad("body too large");
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return ParseOutcome::Incomplete;
    }
    let req = Request {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body: buf[body_start..body_start + content_length].to_vec(),
    };
    ParseOutcome::Done(req, body_start + content_length)
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Request handler: borrows the request, returns a buffered or
/// streaming [`Action`] (plain [`Response`] values convert via `into()`).
pub type Handler = Arc<dyn Fn(&Request) -> Action + Send + Sync>;

/// Minimal HTTP server bound to `addr`, serving until `shutdown` is set.
pub struct Server {
    listener: TcpListener,
    pool: ThreadPool,
    handler: Handler,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(Server {
            listener,
            pool: ThreadPool::new(workers),
            handler,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().unwrap()
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Accept loop; returns when the shutdown flag is set.
    pub fn serve(&self) {
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let handler = Arc::clone(&self.handler);
                    self.pool.execute(move || handle_conn(stream, handler));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
    }
}

fn handle_conn(mut stream: TcpStream, handler: Handler) {
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(10)));
    let mut buf = Vec::with_capacity(4096);
    let mut chunk = [0u8; 4096];
    loop {
        match parse_request(&buf) {
            ParseOutcome::Done(req, _) => {
                match handler(&req) {
                    Action::Respond(resp) => {
                        let _ = resp.write_to(&mut stream);
                    }
                    Action::Stream(s) => {
                        // Keep the read timeout off the write path: SSE
                        // bodies outlive 10s; writes block on the socket
                        // send buffer (backpressure) instead.
                        if s.write_head(&mut stream).is_ok() {
                            let _ = (s.body)(&mut stream);
                        }
                    }
                }
                return;
            }
            ParseOutcome::Bad(msg) => {
                let _ = Response::text(400, msg).write_to(&mut stream);
                return;
            }
            ParseOutcome::Incomplete => match stream.read(&mut chunk) {
                Ok(0) => return, // peer closed before a full request
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(_) => return,
            },
        }
    }
}

/// A parsed client-side reply (status + headers + body).
#[derive(Debug, Clone)]
pub struct Reply {
    pub status: u16,
    /// Response headers, names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Reply {
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == lower)
            .map(|(_, v)| v.as_str())
    }
}

/// One-shot HTTP client (for examples/benches/tests).
pub fn request(addr: &str, method: &str, path: &str, body: &[u8]) -> std::io::Result<(u16, Vec<u8>)> {
    let r = request_with_headers(addr, method, path, &[], body)?;
    Ok((r.status, r.body))
}

/// One-shot HTTP client with request headers and a full [`Reply`]
/// (needed to observe `retry-after` / `x-request-id`).
pub fn request_with_headers(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<Reply> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "{} {} HTTP/1.1\r\nhost: localhost\r\ncontent-length: {}\r\nconnection: close\r\n",
        method,
        path,
        body.len()
    )?;
    for (name, value) in headers {
        write!(stream, "{}: {}\r\n", name, value)?;
    }
    write!(stream, "\r\n")?;
    stream.write_all(body)?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let head_end = find_head_end(&raw).ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "no response head")
    })?;
    let head = String::from_utf8_lossy(&raw[..head_end]);
    let mut lines = head.split("\r\n");
    let status: u16 = lines
        .next()
        .unwrap_or("")
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status"))?;
    let parsed_headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    Ok(Reply { status, headers: parsed_headers, body: raw[head_end + 4..].to_vec() })
}

/// Streaming HTTP client: writes the request, forwards the response
/// *body* to `on_chunk` as bytes arrive (head excluded), and returns
/// the status once the server closes the connection. Used by the SSE
/// tests and the serve_load bench to measure time-to-first-event.
pub fn request_streaming(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    mut on_chunk: impl FnMut(&[u8]),
) -> std::io::Result<u16> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "{} {} HTTP/1.1\r\nhost: localhost\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        method,
        path,
        body.len()
    )?;
    stream.write_all(body)?;
    let mut raw = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut status: Option<u16> = None;
    let mut seen = 0usize; // body bytes already forwarded
    loop {
        let n = match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e) => return Err(e),
        };
        raw.extend_from_slice(&chunk[..n]);
        if status.is_none() {
            if let Some(head_end) = find_head_end(&raw) {
                let head = String::from_utf8_lossy(&raw[..head_end]);
                status = head
                    .split("\r\n")
                    .next()
                    .unwrap_or("")
                    .split(' ')
                    .nth(1)
                    .and_then(|s| s.parse().ok());
                if status.is_none() {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        "bad status",
                    ));
                }
                seen = head_end + 4;
            }
        }
        if status.is_some() && raw.len() > seen {
            on_chunk(&raw[seen..]);
            seen = raw.len();
        }
    }
    status.ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "no response head"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_str(s: &str) -> ParseOutcome {
        parse_request(s.as_bytes())
    }

    #[test]
    fn parses_get() {
        let raw = "GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n";
        match parse_str(raw) {
            ParseOutcome::Done(req, used) => {
                assert_eq!(req.method, "GET");
                assert_eq!(req.path, "/healthz");
                assert_eq!(req.header("host"), Some("x"));
                assert_eq!(used, raw.len());
            }
            _ => panic!("expected Done"),
        }
    }

    #[test]
    fn parses_post_with_body() {
        let raw = "POST /v1/generate HTTP/1.1\r\ncontent-length: 5\r\n\r\nhello";
        match parse_str(raw) {
            ParseOutcome::Done(req, used) => {
                assert_eq!(req.body, b"hello");
                assert_eq!(used, raw.len());
            }
            _ => panic!("expected Done"),
        }
    }

    #[test]
    fn incomplete_until_body_arrives() {
        let raw = "POST / HTTP/1.1\r\ncontent-length: 10\r\n\r\nhi";
        assert!(matches!(parse_str(raw), ParseOutcome::Incomplete));
    }

    #[test]
    fn rejects_garbage() {
        assert!(matches!(parse_str("\r\n\r\n"), ParseOutcome::Bad(_)));
        assert!(matches!(
            parse_str("GET missing-slash HTTP/1.1\r\n\r\n"),
            ParseOutcome::Bad(_)
        ));
        assert!(matches!(
            parse_str("GET / SPDY/9\r\n\r\n"),
            ParseOutcome::Bad(_)
        ));
        assert!(matches!(
            parse_str("GET / HTTP/1.1\r\ncontent-length: banana\r\n\r\n"),
            ParseOutcome::Bad(_)
        ));
    }

    #[test]
    fn fragmented_parse_is_incomplete() {
        let full = "GET / HTTP/1.1\r\nhost: a\r\n\r\n";
        for cut in 1..full.len() {
            match parse_str(&full[..cut]) {
                ParseOutcome::Incomplete => {}
                ParseOutcome::Done(_, _) if cut == full.len() => {}
                ParseOutcome::Done(_, _) => panic!("premature Done at {}", cut),
                ParseOutcome::Bad(m) => panic!("Bad({}) at cut {}", m, cut),
            }
        }
    }

    #[test]
    fn server_roundtrip() {
        let handler: Handler = Arc::new(|req: &Request| {
            if req.path == "/echo" {
                Response::json(200, String::from_utf8_lossy(&req.body).to_string()).into()
            } else {
                Response::text(404, "nope").into()
            }
        });
        let server = Server::bind("127.0.0.1:0", 2, handler).unwrap();
        let addr = server.local_addr().to_string();
        let stop = server.shutdown_handle();
        let t = std::thread::spawn(move || server.serve());
        let (status, body) = request(&addr, "POST", "/echo", b"{\"x\":1}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"x\":1}");
        let (status, _) = request(&addr, "GET", "/missing", b"").unwrap();
        assert_eq!(status, 404);
        stop.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn response_extra_headers_serialized() {
        let mut out = Vec::new();
        Response::text(429, "queue full")
            .with_header("Retry-After", "1")
            .with_header("x-request-id", "42")
            .write_to(&mut out)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        let head_end = s.find("\r\n\r\n").unwrap();
        assert!(s[..head_end].contains("retry-after: 1"));
        assert!(s[..head_end].contains("x-request-id: 42"));
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
    }

    #[test]
    fn client_reply_exposes_headers() {
        let handler: Handler = Arc::new(|req: &Request| {
            let id = req.header("x-request-id").unwrap_or("none").to_string();
            Response::text(200, "ok").with_header("x-request-id", &id).into()
        });
        let server = Server::bind("127.0.0.1:0", 2, handler).unwrap();
        let addr = server.local_addr().to_string();
        let stop = server.shutdown_handle();
        let t = std::thread::spawn(move || server.serve());
        let r = request_with_headers(&addr, "GET", "/", &[("x-request-id", "abc-7")], b"")
            .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.header("x-request-id"), Some("abc-7"));
        stop.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn streaming_response_roundtrip() {
        let handler: Handler = Arc::new(|_req: &Request| {
            Action::Stream(StreamingResponse {
                status: 200,
                content_type: "text/event-stream".into(),
                headers: vec![("x-request-id".into(), "7".into())],
                body: Box::new(|w| {
                    for i in 0..3 {
                        write!(w, "event: token\ndata: {}\n\n", i)?;
                        w.flush()?;
                    }
                    Ok(())
                }),
            })
        });
        let server = Server::bind("127.0.0.1:0", 2, handler).unwrap();
        let addr = server.local_addr().to_string();
        let stop = server.shutdown_handle();
        let t = std::thread::spawn(move || server.serve());
        let mut got = Vec::new();
        let status =
            request_streaming(&addr, "POST", "/s", b"{}", |c| got.extend_from_slice(c)).unwrap();
        assert_eq!(status, 200);
        let s = String::from_utf8(got).unwrap();
        assert_eq!(s.matches("event: token").count(), 3);
        assert!(s.contains("data: 2"));
        stop.store(true, Ordering::SeqCst);
        t.join().unwrap();
    }

    #[test]
    fn response_serialization() {
        let mut out = Vec::new();
        Response::text(200, "ok").write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("content-length: 2"));
        assert!(s.ends_with("ok"));
    }
}
