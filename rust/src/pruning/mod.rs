//! Token-pruning policies: FastAV's two stages + every baseline the paper
//! evaluates against (Tables 2–4).
//!
//! **Global pruning** (paper §2.2, applied at the middle layer): the
//! deployed FastAV policy is *positional* — calibration (see
//! [`crate::calibration`]) turns the attention-rollout analysis into a
//! per-modality keep rule (visual-position cutoff / keep-first-N audio
//! tokens / keep-first-F frames), so the serving path never touches an
//! attention map. The ablation strategies of Table 2 (random, top/low
//! attentive, top/low informative) are implemented score-based at a fixed
//! keep *budget* so all rows compare at equal FLOPs.
//!
//! **Fine pruning** (paper Eq. 4, every layer after the middle): drops the
//! lowest-P% of remaining AV tokens by last-query importance. Table 3's
//! baselines (random, top-attentive) share the same drop count.
//!
//! Hard safety rules enforced by every policy: control (BOS) and text
//! (question) tokens are never pruned, the final prompt token is never
//! pruned, and keep sets are ascending + unique.

use crate::tokens::Segment;
use crate::util::rng::SplitMix64;

/// Global-stage strategy selector.
#[derive(Debug, Clone, PartialEq)]
pub enum GlobalStrategy {
    /// No global pruning (vanilla).
    None,
    /// FastAV's deployed positional policy from calibration:
    /// keep visual tokens whose *original position* is below `vis_cutoff`,
    /// the first `keep_audio` audio tokens (sequential layouts), or the
    /// first `keep_frames` whole frames (interleaved layouts).
    FastAvPosition { vis_cutoff: usize, keep_audio: usize, keep_frames: usize },
    /// Keep a uniformly random AV subset of size `budget` (Table 2 row 2).
    Random,
    /// Prune the most-attended AV tokens (keep the `budget` *least*
    /// attended) — Table 2 "Top attentive" (degrades badly).
    TopAttentive,
    /// Prune the least-attended AV tokens (keep the `budget` most
    /// attended) — Table 2 "Low attentive".
    LowAttentive,
    /// Prune the most informative (highest rollout influence) — Table 2
    /// "Top informative" (worst).
    TopInformative,
    /// Prune the least informative by attention rollout — Table 2 "Low
    /// informative (Ours)".
    LowInformative,
    /// Visual-tokens-withdrawal baseline (VTW [12]): drop *all* AV tokens.
    Vtw,
    /// FastV-style baseline [11]: prune visual tokens by attention score,
    /// keeping `keep_ratio` of them (audio kept untouched).
    FastV { keep_ratio: f64 },
    /// StreamingLLM/attention-sink-style baseline: keep the first `sink`
    /// and the last `recent` AV tokens by position (the paper's anchor
    /// observation predicts the sink half matters far more).
    StreamingWindow { sink: usize, recent: usize },
}

/// Everything a global strategy may consult.
pub struct GlobalInputs<'a> {
    /// Per-token modality of the original prompt.
    pub segments: &'a [Segment],
    /// Owning frame per token (-1 when not frame-scoped).
    pub frame_of: &'a [i32],
    /// Last-query attention importance at the pruning layer (Eq. 4),
    /// aligned with `segments`. Required by the *attentive* strategies.
    pub scores: Option<&'a [f32]>,
    /// Rollout influence of each token on the final query (last row of
    /// `R^mid`), aligned with `segments`. Required by the *informative*
    /// strategies.
    pub rollout: Option<&'a [f32]>,
    /// Number of AV tokens to keep (budget-matched ablations).
    pub budget: usize,
    /// Seed for the random strategy.
    pub seed: u64,
    /// Modality keep floor: whatever the strategy decides, at least this
    /// many *visual* tokens survive (earliest-position pruned tokens are
    /// added back). `0` = no floor.
    pub min_keep_vis: usize,
    /// Modality keep floor for *audio* tokens (the "Keep What Audio
    /// Cannot Say" guarantee: aggressive budgets can never silence the
    /// audio stream entirely). `0` = no floor.
    pub min_keep_aud: usize,
}

/// Indices of AV (prunable) tokens.
fn av_indices(segments: &[Segment]) -> Vec<usize> {
    segments
        .iter()
        .enumerate()
        .filter(|(_, &g)| g == Segment::Vis || g == Segment::Aud)
        .map(|(i, _)| i)
        .collect()
}

/// Merge always-kept (ctrl/text) indices with a chosen AV subset into an
/// ascending keep set.
fn merge_keep(segments: &[Segment], mut av_keep: Vec<usize>) -> Vec<usize> {
    let mut keep: Vec<usize> = segments
        .iter()
        .enumerate()
        .filter(|(_, &g)| g == Segment::Ctrl || g == Segment::Text)
        .map(|(i, _)| i)
        .collect();
    keep.append(&mut av_keep);
    keep.sort_unstable();
    keep.dedup();
    keep
}

/// Keep the `budget` AV tokens with the best value under `key` (max-first
/// when `descending`), breaking ties by position (earlier wins).
fn budget_select(
    av: &[usize],
    key: impl Fn(usize) -> f32,
    budget: usize,
    descending: bool,
) -> Vec<usize> {
    let mut ranked: Vec<usize> = av.to_vec();
    ranked.sort_by(|&a, &b| {
        let (ka, kb) = (key(a), key(b));
        let ord = if descending {
            kb.partial_cmp(&ka).unwrap()
        } else {
            ka.partial_cmp(&kb).unwrap()
        };
        ord.then(a.cmp(&b))
    });
    let mut chosen: Vec<usize> = ranked.into_iter().take(budget).collect();
    chosen.sort_unstable();
    chosen
}

/// Enforce the per-modality keep floors on a chosen AV keep set: when a
/// strategy kept fewer than `min_keep_vis` visual (or `min_keep_aud`
/// audio) tokens, the earliest-position pruned tokens of that modality
/// are added back until the floor is met or the modality is exhausted.
/// Floors only ever *grow* a keep set, so every safety invariant of the
/// underlying strategy is preserved.
fn apply_floors(segments: &[Segment], inp: &GlobalInputs, mut av_keep: Vec<usize>) -> Vec<usize> {
    if inp.min_keep_vis == 0 && inp.min_keep_aud == 0 {
        return av_keep;
    }
    let kept: std::collections::HashSet<usize> = av_keep.iter().copied().collect();
    for (seg, floor) in [
        (Segment::Vis, inp.min_keep_vis),
        (Segment::Aud, inp.min_keep_aud),
    ] {
        if floor == 0 {
            continue;
        }
        let have = av_keep.iter().filter(|&&i| segments[i] == seg).count();
        if have >= floor {
            continue;
        }
        let mut need = floor - have;
        for (i, &g) in segments.iter().enumerate() {
            if need == 0 {
                break;
            }
            if g == seg && !kept.contains(&i) {
                av_keep.push(i);
                need -= 1;
            }
        }
    }
    av_keep.sort_unstable();
    av_keep
}

/// Compute the global keep set (ascending indices into the original
/// prompt). Panics if a score-based strategy is missing its inputs.
pub fn global_keep(strategy: &GlobalStrategy, inp: &GlobalInputs) -> Vec<usize> {
    let segments = inp.segments;
    let av = av_indices(segments);
    let av_keep: Vec<usize> = match strategy {
        GlobalStrategy::None => av.clone(),
        GlobalStrategy::Vtw => Vec::new(),
        GlobalStrategy::FastAvPosition { vis_cutoff, keep_audio, keep_frames } => {
            let mut out = Vec::new();
            let mut audio_seen = 0usize;
            let interleaved_frames = segments
                .iter()
                .zip(inp.frame_of)
                .any(|(&g, &f)| g == Segment::Aud && f >= 0);
            for &i in &av {
                match segments[i] {
                    Segment::Vis => {
                        if interleaved_frames {
                            if (inp.frame_of[i] as usize) < *keep_frames {
                                out.push(i);
                            }
                        } else if i < *vis_cutoff {
                            out.push(i);
                        }
                    }
                    Segment::Aud => {
                        if interleaved_frames {
                            if (inp.frame_of[i] as usize) < *keep_frames {
                                out.push(i);
                            }
                        } else {
                            if audio_seen < *keep_audio {
                                out.push(i);
                            }
                            audio_seen += 1;
                        }
                    }
                    _ => unreachable!(),
                }
            }
            out
        }
        GlobalStrategy::Random => {
            let mut rng = SplitMix64::new(inp.seed);
            // Partial Fisher–Yates: choose `budget` of the AV tokens.
            let mut pool = av.clone();
            let take = inp.budget.min(pool.len());
            for i in 0..take {
                let j = i + rng.next_below((pool.len() - i) as u64) as usize;
                pool.swap(i, j);
            }
            let mut chosen = pool[..take].to_vec();
            chosen.sort_unstable();
            chosen
        }
        GlobalStrategy::TopAttentive => {
            let s = inp.scores.expect("TopAttentive requires scores");
            budget_select(&av, |i| s[i], inp.budget, false)
        }
        GlobalStrategy::LowAttentive => {
            let s = inp.scores.expect("LowAttentive requires scores");
            budget_select(&av, |i| s[i], inp.budget, true)
        }
        GlobalStrategy::TopInformative => {
            let r = inp.rollout.expect("TopInformative requires rollout");
            budget_select(&av, |i| r[i], inp.budget, false)
        }
        GlobalStrategy::LowInformative => {
            let r = inp.rollout.expect("LowInformative requires rollout");
            budget_select(&av, |i| r[i], inp.budget, true)
        }
        GlobalStrategy::StreamingWindow { sink, recent } => {
            let n_av = av.len();
            let mut out: Vec<usize> = av.iter().take(*sink).copied().collect();
            out.extend(av.iter().skip(n_av.saturating_sub(*recent)).copied());
            out.sort_unstable();
            out.dedup();
            out
        }
        GlobalStrategy::FastV { keep_ratio } => {
            let s = inp.scores.expect("FastV requires scores");
            let vis: Vec<usize> = segments
                .iter()
                .enumerate()
                .filter(|(_, &g)| g == Segment::Vis)
                .map(|(i, _)| i)
                .collect();
            let keep_n = ((vis.len() as f64) * keep_ratio).round() as usize;
            let mut kept_vis = budget_select(&vis, |i| s[i], keep_n, true);
            // All audio tokens survive FastV (it is vision-only).
            let mut out: Vec<usize> = segments
                .iter()
                .enumerate()
                .filter(|(_, &g)| g == Segment::Aud)
                .map(|(i, _)| i)
                .collect();
            out.append(&mut kept_vis);
            out.sort_unstable();
            out
        }
    };
    merge_keep(segments, apply_floors(segments, inp, av_keep))
}

/// Fine-stage strategy selector (Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FineStrategy {
    None,
    Random,
    /// Drop the *most* attended (Table 3 "Top attentive" — degrades).
    TopAttentive,
    /// Drop the *least* attended (FastAV, Table 3 "Low attentive (Ours)").
    LowAttentive,
}

/// Compute the keep set after one fine-pruning step.
///
/// `scores` are this layer's last-query importance over the *live* rows;
/// `segments` gives each live row's modality; `percent` is the paper's P.
/// Exactly `round(percent/100 * prunable)` AV rows are dropped (text/ctrl
/// rows and the final row are untouchable) — except that the modality
/// keep floors `min_keep_vis`/`min_keep_aud` are honored end-to-end:
/// when a drop would leave fewer than the floor of a modality alive, the
/// highest-scoring dropped rows of that modality are put back (so the
/// floor a spec promises at the global stage cannot be eroded layer by
/// layer; `0` = no floor, the exact-count paper semantics).
pub fn fine_keep(
    strategy: FineStrategy,
    scores: &[f32],
    segments: &[Segment],
    percent: f64,
    seed: u64,
    min_keep_vis: usize,
    min_keep_aud: usize,
) -> Vec<usize> {
    let n = scores.len();
    assert_eq!(n, segments.len());
    if n == 0 {
        return Vec::new();
    }
    let last = n - 1;
    let prunable: Vec<usize> = (0..n)
        .filter(|&i| {
            i != last && matches!(segments[i], Segment::Vis | Segment::Aud)
        })
        .collect();
    let drop_n = match strategy {
        FineStrategy::None => 0,
        _ => ((percent / 100.0) * prunable.len() as f64).round() as usize,
    };
    let drop_n = drop_n.min(prunable.len());
    let dropped: Vec<usize> = match strategy {
        FineStrategy::None => Vec::new(),
        FineStrategy::Random => {
            let mut rng = SplitMix64::new(seed);
            let mut pool = prunable.clone();
            for i in 0..drop_n {
                let j = i + rng.next_below((pool.len() - i) as u64) as usize;
                pool.swap(i, j);
            }
            pool[..drop_n].to_vec()
        }
        FineStrategy::TopAttentive => {
            budget_select(&prunable, |i| scores[i], drop_n, true)
        }
        FineStrategy::LowAttentive => {
            budget_select(&prunable, |i| scores[i], drop_n, false)
        }
    };
    let mut drop_set: std::collections::HashSet<usize> = dropped.into_iter().collect();
    // Floor enforcement: put back the best-scoring dropped rows of any
    // modality the drop would push under its floor.
    for (seg, floor) in [(Segment::Vis, min_keep_vis), (Segment::Aud, min_keep_aud)] {
        if floor == 0 {
            continue;
        }
        let alive = (0..n)
            .filter(|&i| segments[i] == seg && !drop_set.contains(&i))
            .count();
        if alive >= floor {
            continue;
        }
        let mut need = floor - alive;
        let mut candidates: Vec<usize> = drop_set
            .iter()
            .copied()
            .filter(|&i| segments[i] == seg)
            .collect();
        // Highest score first (most informative survivors), position ties
        // earlier-first — deterministic across runs.
        candidates.sort_by(|&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
        });
        for i in candidates {
            if need == 0 {
                break;
            }
            drop_set.remove(&i);
            need -= 1;
        }
    }
    (0..n).filter(|i| !drop_set.contains(i)).collect()
}

/// Validate a keep set against the invariants every policy must uphold.
/// Returns an error string for use in tests and debug assertions.
pub fn validate_keep(keep: &[usize], segments: &[Segment]) -> Result<(), String> {
    let n = segments.len();
    if keep.is_empty() {
        return Err("empty keep set".into());
    }
    for w in keep.windows(2) {
        if w[0] >= w[1] {
            return Err(format!("keep not strictly ascending at {:?}", w));
        }
    }
    if *keep.last().unwrap() >= n {
        return Err("keep index out of range".into());
    }
    for (i, &g) in segments.iter().enumerate() {
        if matches!(g, Segment::Ctrl | Segment::Text) && !keep.contains(&i) {
            return Err(format!("non-prunable token {} ({:?}) was pruned", i, g));
        }
    }
    if !keep.contains(&(n - 1)) {
        return Err("last prompt token was pruned".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1 ctrl + 6 vis (frames 0,0,1,1,2,2) + 3 aud + 2 text.
    fn segs() -> (Vec<Segment>, Vec<i32>) {
        let mut s = vec![Segment::Ctrl];
        let mut f = vec![-1];
        for fr in 0..3 {
            for _ in 0..2 {
                s.push(Segment::Vis);
                f.push(fr);
            }
        }
        for _ in 0..3 {
            s.push(Segment::Aud);
            f.push(-1);
        }
        s.push(Segment::Text);
        f.push(-1);
        s.push(Segment::Text);
        f.push(-1);
        (s, f)
    }

    fn inputs<'a>(
        s: &'a [Segment],
        f: &'a [i32],
        scores: Option<&'a [f32]>,
        rollout: Option<&'a [f32]>,
        budget: usize,
    ) -> GlobalInputs<'a> {
        GlobalInputs {
            segments: s,
            frame_of: f,
            scores,
            rollout,
            budget,
            seed: 7,
            min_keep_vis: 0,
            min_keep_aud: 0,
        }
    }

    #[test]
    fn none_keeps_everything() {
        let (s, f) = segs();
        let keep = global_keep(&GlobalStrategy::None, &inputs(&s, &f, None, None, 0));
        assert_eq!(keep, (0..s.len()).collect::<Vec<_>>());
        validate_keep(&keep, &s).unwrap();
    }

    #[test]
    fn vtw_drops_all_av() {
        let (s, f) = segs();
        let keep = global_keep(&GlobalStrategy::Vtw, &inputs(&s, &f, None, None, 0));
        assert_eq!(keep, vec![0, 10, 11]);
        validate_keep(&keep, &s).unwrap();
    }

    #[test]
    fn fastav_position_sequential() {
        let (s, f) = segs();
        // vis positions are 1..=6; cutoff 4 keeps vis 1,2,3. keep_audio=1
        // keeps the first audio token (index 7).
        let strat = GlobalStrategy::FastAvPosition {
            vis_cutoff: 4,
            keep_audio: 1,
            keep_frames: 0,
        };
        let keep = global_keep(&strat, &inputs(&s, &f, None, None, 0));
        assert_eq!(keep, vec![0, 1, 2, 3, 7, 10, 11]);
        validate_keep(&keep, &s).unwrap();
    }

    #[test]
    fn fastav_position_interleaved() {
        // Interleaved: frames own audio too.
        let mut s = vec![Segment::Ctrl];
        let mut f = vec![-1];
        for fr in 0..3 {
            s.extend([Segment::Vis, Segment::Vis, Segment::Aud]);
            f.extend([fr, fr, fr]);
        }
        s.push(Segment::Text);
        f.push(-1);
        let strat = GlobalStrategy::FastAvPosition {
            vis_cutoff: usize::MAX,
            keep_audio: 0,
            keep_frames: 2,
        };
        let keep = global_keep(&strat, &inputs(&s, &f, None, None, 0));
        // BOS + frames 0,1 (indices 1..=6) + text (10).
        assert_eq!(keep, vec![0, 1, 2, 3, 4, 5, 6, 10]);
        validate_keep(&keep, &s).unwrap();
    }

    #[test]
    fn random_respects_budget_and_determinism() {
        let (s, f) = segs();
        let a = global_keep(&GlobalStrategy::Random, &inputs(&s, &f, None, None, 4));
        let b = global_keep(&GlobalStrategy::Random, &inputs(&s, &f, None, None, 4));
        assert_eq!(a, b);
        // ctrl(1) + text(2) + 4 AV.
        assert_eq!(a.len(), 7);
        validate_keep(&a, &s).unwrap();
    }

    #[test]
    fn attentive_strategies_order_by_scores() {
        let (s, f) = segs();
        // Scores: AV indices 1..=9; make index 3 the hottest, 8 coldest.
        let mut scores = vec![0.0f32; s.len()];
        for (i, sc) in scores.iter_mut().enumerate() {
            *sc = i as f32 * 0.01;
        }
        scores[3] = 1.0;
        scores[8] = -1.0;
        let low = global_keep(
            &GlobalStrategy::LowAttentive,
            &inputs(&s, &f, Some(&scores), None, 2),
        );
        assert!(low.contains(&3), "keeps hottest");
        assert!(!low.contains(&8), "drops coldest");
        let top = global_keep(
            &GlobalStrategy::TopAttentive,
            &inputs(&s, &f, Some(&scores), None, 2),
        );
        assert!(!top.contains(&3), "prunes hottest");
        assert!(top.contains(&8), "keeps coldest");
        validate_keep(&low, &s).unwrap();
        validate_keep(&top, &s).unwrap();
    }

    #[test]
    fn informative_strategies_use_rollout() {
        let (s, f) = segs();
        let mut rollout = vec![0.0f32; s.len()];
        rollout[1] = 0.9; // most informative AV token
        rollout[9] = 0.001;
        let low = global_keep(
            &GlobalStrategy::LowInformative,
            &inputs(&s, &f, None, Some(&rollout), 3),
        );
        assert!(low.contains(&1));
        let top = global_keep(
            &GlobalStrategy::TopInformative,
            &inputs(&s, &f, None, Some(&rollout), 3),
        );
        assert!(!top.contains(&1));
    }

    #[test]
    fn fastv_keeps_audio_prunes_vision() {
        let (s, f) = segs();
        let mut scores = vec![0.0f32; s.len()];
        scores[1] = 0.5;
        scores[2] = 0.4;
        let keep = global_keep(
            &GlobalStrategy::FastV { keep_ratio: 0.5 },
            &inputs(&s, &f, Some(&scores), None, 0),
        );
        // 3 of 6 vis kept (the highest-scored), all 3 audio kept.
        let vis_kept = keep.iter().filter(|&&i| s[i] == Segment::Vis).count();
        let aud_kept = keep.iter().filter(|&&i| s[i] == Segment::Aud).count();
        assert_eq!(vis_kept, 3);
        assert_eq!(aud_kept, 3);
        assert!(keep.contains(&1) && keep.contains(&2));
    }

    #[test]
    fn streaming_window_keeps_sink_and_recent() {
        let (s, f) = segs();
        // AV indices are 1..=9; sink 2 keeps {1,2}, recent 3 keeps {7,8,9}.
        let keep = global_keep(
            &GlobalStrategy::StreamingWindow { sink: 2, recent: 3 },
            &inputs(&s, &f, None, None, 0),
        );
        assert_eq!(keep, vec![0, 1, 2, 7, 8, 9, 10, 11]);
        validate_keep(&keep, &s).unwrap();
        // Overlapping windows dedupe cleanly.
        let keep = global_keep(
            &GlobalStrategy::StreamingWindow { sink: 9, recent: 9 },
            &inputs(&s, &f, None, None, 0),
        );
        assert_eq!(keep, (0..s.len()).collect::<Vec<_>>());
    }

    #[test]
    fn floors_top_up_pruned_modalities() {
        let (s, f) = segs();
        // Vtw drops every AV token; a floor of 2 vis + 1 aud adds back
        // the earliest-position tokens of each modality.
        let mut inp = inputs(&s, &f, None, None, 0);
        inp.min_keep_vis = 2;
        inp.min_keep_aud = 1;
        let keep = global_keep(&GlobalStrategy::Vtw, &inp);
        // ctrl(0) + vis 1,2 + aud 7 + text 10,11.
        assert_eq!(keep, vec![0, 1, 2, 7, 10, 11]);
        validate_keep(&keep, &s).unwrap();
    }

    #[test]
    fn floors_saturate_and_noop_when_met() {
        let (s, f) = segs();
        // Floor above the modality's token count keeps everything of it.
        let mut inp = inputs(&s, &f, None, None, 0);
        inp.min_keep_aud = 99;
        let keep = global_keep(&GlobalStrategy::Vtw, &inp);
        let aud_kept = keep.iter().filter(|&&i| s[i] == Segment::Aud).count();
        assert_eq!(aud_kept, 3, "floor saturates at the audio token count");
        // A floor already satisfied changes nothing.
        let mut inp = inputs(&s, &f, None, None, 0);
        inp.min_keep_vis = 3;
        let strat = GlobalStrategy::FastAvPosition {
            vis_cutoff: 4,
            keep_audio: 1,
            keep_frames: 0,
        };
        let keep = global_keep(&strat, &inp);
        assert_eq!(keep, vec![0, 1, 2, 3, 7, 10, 11], "met floor is a no-op");
    }

    #[test]
    fn fine_keep_drops_exact_count() {
        // 8 live rows: ctrl, 5 vis, text, text(last).
        let segments = vec![
            Segment::Ctrl,
            Segment::Vis,
            Segment::Vis,
            Segment::Vis,
            Segment::Vis,
            Segment::Vis,
            Segment::Text,
            Segment::Text,
        ];
        let scores = vec![0.5, 0.01, 0.2, 0.03, 0.4, 0.02, 0.9, 0.9];
        let keep = fine_keep(FineStrategy::LowAttentive, &scores, &segments, 40.0, 0, 0, 0);
        // prunable = 5 vis; drop round(0.4*5)=2 lowest (idx 1: .01, idx 5: .02).
        assert_eq!(keep, vec![0, 2, 3, 4, 6, 7]);
    }

    #[test]
    fn fine_top_attentive_drops_hottest() {
        let segments = vec![Segment::Ctrl, Segment::Vis, Segment::Vis, Segment::Text];
        let scores = vec![0.0, 0.9, 0.1, 0.0];
        let keep = fine_keep(FineStrategy::TopAttentive, &scores, &segments, 50.0, 0, 0, 0);
        assert_eq!(keep, vec![0, 2, 3]);
    }

    #[test]
    fn fine_none_keeps_all() {
        let segments = vec![Segment::Ctrl, Segment::Vis, Segment::Text];
        let keep = fine_keep(FineStrategy::None, &[0.1, 0.2, 0.3], &segments, 20.0, 0, 0, 0);
        assert_eq!(keep, vec![0, 1, 2]);
    }

    #[test]
    fn fine_floor_survives_aggressive_drops() {
        // 1 ctrl + 3 vis + 2 aud + 1 text; 100% drop would erase every
        // AV row — the floors must keep the best-scoring row of each
        // floored modality alive at every layer.
        let segments = vec![
            Segment::Ctrl,
            Segment::Vis,
            Segment::Vis,
            Segment::Vis,
            Segment::Aud,
            Segment::Aud,
            Segment::Text,
        ];
        let scores = vec![0.0, 0.1, 0.9, 0.2, 0.3, 0.7, 0.0];
        let keep =
            fine_keep(FineStrategy::LowAttentive, &scores, &segments, 100.0, 0, 1, 1);
        let vis: Vec<usize> =
            keep.iter().copied().filter(|&i| segments[i] == Segment::Vis).collect();
        let aud: Vec<usize> =
            keep.iter().copied().filter(|&i| segments[i] == Segment::Aud).collect();
        assert_eq!(vis, vec![2], "highest-scoring vis row survives the floor");
        assert_eq!(aud, vec![5], "highest-scoring aud row survives the floor");
        // Floors of zero keep the paper's exact-drop-count semantics.
        let keep =
            fine_keep(FineStrategy::LowAttentive, &scores, &segments, 100.0, 0, 0, 0);
        assert_eq!(keep, vec![0, 6]);
    }

    #[test]
    fn fine_never_drops_last_or_text() {
        let segments = vec![Segment::Vis; 6];
        let mut segments = segments;
        segments[5] = Segment::Vis; // last row is Vis but must survive
        let scores = vec![0.0; 6];
        let keep = fine_keep(FineStrategy::LowAttentive, &scores, &segments, 100.0, 0, 0, 0);
        assert!(keep.contains(&5));
    }

    #[test]
    fn validate_catches_violations() {
        let (s, _) = segs();
        assert!(validate_keep(&[], &s).is_err());
        assert!(validate_keep(&[0, 0, 1], &s).is_err());
        assert!(validate_keep(&[0, 1], &s).is_err()); // text pruned
        let all: Vec<usize> = (0..s.len()).collect();
        assert!(validate_keep(&all, &s).is_ok());
    }
}
