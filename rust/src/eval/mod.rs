//! Benchmark evaluation harness + rule-based scoring.
//!
//! Substitutes the paper's GPT-assisted protocol (DESIGN.md §2): answers in
//! avsynth are structured token sequences, so exact matching scores QA
//! subtasks and keyword recall maps captioning onto the paper's 0–5 scale.
//! The harness also aggregates the efficiency columns of Table 1 (relative
//! FLOPs, per-token latency, peak KV bytes).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::avsynth::{gen_sample, Dataset, Subtask};
use crate::model::{GenerateOptions, ModelEngine, PruningPlan, RequestInput};
use crate::tokens::{EOS, PAD};

/// Exact-match correctness for QA subtasks: the generated tokens up to the
/// first EOS must equal the expected answer (its EOS stripped).
pub fn exact_match(generated: &[u32], expected: &[u32]) -> bool {
    let gen_clean: Vec<u32> = generated
        .iter()
        .copied()
        .take_while(|&t| t != EOS)
        .filter(|&t| t != PAD)
        .collect();
    let want: Vec<u32> = expected
        .iter()
        .copied()
        .take_while(|&t| t != EOS)
        .collect();
    gen_clean == want
}

/// Captioning score on the paper's 0–5 scale: keyword recall over the
/// expected caption tokens (scene + sound), 2.5 points each.
pub fn caption_score(generated: &[u32], expected: &[u32]) -> f64 {
    let want: Vec<u32> = expected
        .iter()
        .copied()
        .take_while(|&t| t != EOS)
        .collect();
    if want.is_empty() {
        return 0.0;
    }
    let gen_set: std::collections::HashSet<u32> = generated
        .iter()
        .copied()
        .take_while(|&t| t != EOS)
        .collect();
    let hits = want.iter().filter(|t| gen_set.contains(t)).count();
    5.0 * hits as f64 / want.len() as f64
}

/// Per-subtask aggregate.
#[derive(Debug, Clone, Default)]
pub struct SubtaskScore {
    pub n: usize,
    pub correct: usize,
    pub caption_sum: f64,
}

impl SubtaskScore {
    pub fn accuracy(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            100.0 * self.correct as f64 / self.n as f64
        }
    }

    pub fn caption_mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.caption_sum / self.n as f64
        }
    }
}

/// Full evaluation report for one (dataset, plan) pair.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub dataset: String,
    pub n: usize,
    pub per_subtask: BTreeMap<String, SubtaskScore>,
    pub mean_rel_flops: f64,
    pub mean_prefill_s: f64,
    pub mean_decode_tok_s: f64,
    pub mean_peak_kv_bytes: f64,
}

impl EvalReport {
    /// Accuracy over all non-captioning samples (the paper's protocol for
    /// AVHBench excludes AV captioning from the accuracy number).
    pub fn accuracy(&self) -> f64 {
        let (mut n, mut c) = (0usize, 0usize);
        for (name, s) in &self.per_subtask {
            if name != "captioning" {
                n += s.n;
                c += s.correct;
            }
        }
        if n == 0 {
            0.0
        } else {
            100.0 * c as f64 / n as f64
        }
    }

    pub fn subtask_accuracy(&self, name: &str) -> Option<f64> {
        self.per_subtask.get(name).map(|s| s.accuracy())
    }

    pub fn caption_mean(&self) -> Option<f64> {
        self.per_subtask.get("captioning").map(|s| s.caption_mean())
    }
}

/// Evaluate `n` samples of `dataset` under a pruning plan.
pub fn evaluate(
    engine: &mut ModelEngine,
    dataset: Dataset,
    n: usize,
    base_seed: u64,
    plan: &PruningPlan,
    max_gen: usize,
) -> Result<EvalReport> {
    let layout = engine.cfg.layout.clone();
    let opts = GenerateOptions { plan: plan.clone(), max_gen, ..Default::default() };
    let mut per_subtask: BTreeMap<String, SubtaskScore> = BTreeMap::new();
    let (mut f_sum, mut p_sum, mut d_sum, mut kv_sum) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut d_count = 0usize;

    for i in 0..n {
        let s = gen_sample(&layout, dataset, i as u64, base_seed);
        let res = engine.generate(&RequestInput::from_sample(&s), &opts)?;
        let entry = per_subtask.entry(s.subtask.name().to_string()).or_default();
        entry.n += 1;
        if s.subtask == Subtask::Captioning {
            entry.caption_sum += caption_score(&res.tokens, &s.answer);
            // Captioning also counts exact match for completeness.
            if exact_match(&res.tokens, &s.answer) {
                entry.correct += 1;
            }
        } else if exact_match(&res.tokens, &s.answer) {
            entry.correct += 1;
        }
        f_sum += res.relative_flops;
        p_sum += res.prefill_seconds;
        if res.decode_steps > 0 {
            d_sum += res.decode_seconds / res.decode_steps as f64;
            d_count += 1;
        }
        kv_sum += res.peak_kv_bytes as f64;
    }

    Ok(EvalReport {
        dataset: dataset.name().to_string(),
        n,
        per_subtask,
        mean_rel_flops: f_sum / n.max(1) as f64,
        mean_prefill_s: p_sum / n.max(1) as f64,
        mean_decode_tok_s: if d_count > 0 { d_sum / d_count as f64 } else { 0.0 },
        mean_peak_kv_bytes: kv_sum / n.max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokens::{scene_token, sound_token, YES};

    #[test]
    fn exact_match_strips_eos() {
        assert!(exact_match(&[YES, EOS], &[YES, EOS]));
        assert!(exact_match(&[YES, EOS, 99], &[YES, EOS])); // post-EOS junk ignored
        assert!(!exact_match(&[YES], &[scene_token(1), EOS]));
        assert!(!exact_match(&[YES, YES, EOS], &[YES, EOS]));
    }

    #[test]
    fn caption_scoring_scale() {
        let want = [scene_token(3), sound_token(5), EOS];
        assert_eq!(caption_score(&[scene_token(3), sound_token(5), EOS], &want), 5.0);
        assert_eq!(caption_score(&[scene_token(3), EOS], &want), 2.5);
        assert_eq!(caption_score(&[scene_token(9), EOS], &want), 0.0);
        // Order-insensitive recall.
        assert_eq!(caption_score(&[sound_token(5), scene_token(3), EOS], &want), 5.0);
    }

    #[test]
    fn report_accuracy_excludes_captioning() {
        let mut per = BTreeMap::new();
        per.insert("hallucination".into(), SubtaskScore { n: 10, correct: 8, caption_sum: 0.0 });
        per.insert("matching".into(), SubtaskScore { n: 10, correct: 5, caption_sum: 0.0 });
        per.insert("captioning".into(), SubtaskScore { n: 10, correct: 0, caption_sum: 30.0 });
        let r = EvalReport {
            dataset: "avhbench".into(),
            n: 30,
            per_subtask: per,
            mean_rel_flops: 0.0,
            mean_prefill_s: 0.0,
            mean_decode_tok_s: 0.0,
            mean_peak_kv_bytes: 0.0,
        };
        assert!((r.accuracy() - 65.0).abs() < 1e-9);
        assert_eq!(r.caption_mean(), Some(3.0));
        assert_eq!(r.subtask_accuracy("matching"), Some(50.0));
    }

    #[test]
    fn subtask_score_edge_cases() {
        let s = SubtaskScore::default();
        assert_eq!(s.accuracy(), 0.0);
        assert_eq!(s.caption_mean(), 0.0);
    }
}
