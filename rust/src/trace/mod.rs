//! Request-lifecycle tracing + per-quantum engine profiling.
//!
//! A [`TraceRecorder`] samples submitted requests (counter-based, so a
//! rate of `1/n` traces exactly every n-th submission) and collects a
//! **well-nested span tree** per sampled request:
//!
//! ```text
//!   request                          (root; duration == the request's
//!   ├─ queue                          fastav_generate_seconds sample)
//!   ├─ admit
//!   │  └─ prefix_probe
//!   ├─ begin | prefix_resume         (embed + front prefill, or a
//!   ├─ prefill_chunk ×L               mid-sequence cache resume)
//!   └─ decode_quantum ×T             (batch size + decode class attrs)
//!      ├─ upload / download / combine   (engine host work, track 0)
//!      └─ dispatch ×D                   (per-shard, tracks 1..=D)
//! ```
//!
//! Spans live on **tracks**: track 0 is the request's serial timeline
//! on its replica thread; track `1 + s` is mesh shard `s`, so per-shard
//! `dispatch` segments that genuinely overlap in wall time never
//! overlap *within* a track (the Chrome exporter maps tracks to
//! threads, one Perfetto lane each).
//!
//! **Cost model:** sampling off (`--trace-sample 0`) is one branch in
//! `try_sample` per submit — no allocation, no clock read, nothing on
//! the per-token path. Sampled requests pay one `Box<ReqTrace>` plus a
//! few clock reads per scheduling quantum. Completed traces land in
//! per-replica ring buffers (`--trace-ring` entries each), so memory is
//! bounded however long the server runs.
//!
//! The clock is a trait ([`Clock`]) so the mock-pool tests drive a
//! [`MockClock`] and assert exact timing identities; production uses
//! the [`MonotonicClock`] (one `Instant` origin per recorder).
//!
//! Engine internals report sub-quantum segments (upload/dispatch/
//! download/combine, prefix lookups) through a **thread-local segment
//! collector** ([`collect_segs`]): the replica loop installs it around
//! a traced quantum, the engine and mesh call [`seg_begin`]/[`seg_end`]
//! /[`push_seg`] unconditionally (a no-op when no collector is active),
//! and no engine trait signature changes — which is what keeps the
//! mock-pool streaming-equivalence tests pinning the untraced path.

pub mod export;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic time source for span timestamps. `Send + Sync` because one
/// recorder (and its clock) is shared by the submit path and every
/// replica thread.
pub trait Clock: Send + Sync {
    /// Nanoseconds since the clock's origin (monotone, never wraps in
    /// practice: u64 ns ≈ 584 years).
    fn now_ns(&self) -> u64;
}

/// Production clock: `Instant` elapsed since recorder construction.
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// Test clock: time advances only when the test says so, making span
/// timestamps (and the root-duration == histogram-sample identity)
/// exactly assertable.
#[derive(Debug, Default)]
pub struct MockClock {
    t: AtomicU64,
}

impl MockClock {
    pub fn new() -> MockClock {
        MockClock::default()
    }

    pub fn advance_ns(&self, d: u64) {
        self.t.fetch_add(d, Ordering::SeqCst);
    }

    pub fn set_ns(&self, t: u64) {
        self.t.store(t, Ordering::SeqCst);
    }
}

impl Clock for MockClock {
    fn now_ns(&self) -> u64 {
        self.t.load(Ordering::SeqCst)
    }
}

/// Track index of the request's serial timeline (its replica thread).
/// Mesh shard `s` segments go on track `1 + s`.
pub const TRACK_REQUEST: u32 = 0;

/// A span attribute value (kept closed over `'static` names so traces
/// allocate only for the span vector itself).
#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    U64(u64),
    F64(f64),
    Str(&'static str),
}

/// One timed interval in a request's trace.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: &'static str,
    /// 0 = request timeline; `1 + s` = mesh shard `s`.
    pub track: u32,
    /// Index of the parent span in [`CompletedTrace::spans`]; `None`
    /// only for the root. Parents always precede children, so the span
    /// vector is a topologically ordered tree.
    pub parent: Option<u32>,
    pub start_ns: u64,
    pub end_ns: u64,
    pub attrs: Vec<(&'static str, AttrValue)>,
}

impl Span {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// How a traced request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Completed,
    Canceled,
    Expired,
    Failed,
}

impl Outcome {
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Canceled => "canceled",
            Outcome::Expired => "expired",
            Outcome::Failed => "failed",
        }
    }
}

/// Result-derived numbers attached at commit (zeroed for requests that
/// never produced a [`crate::model::GenerateResult`]).
#[derive(Debug, Clone, Default)]
pub struct TraceStats {
    pub tokens: u64,
    /// Theoretical FLOPs charged at the resolved pruning spec (the
    /// paper's accounting, from the `flops` module via the engine).
    pub flops_total: u64,
    /// FLOPs relative to the unpruned baseline (×100 = percent).
    pub relative_flops: f64,
    pub prefix_hit: bool,
}

/// An in-flight trace: the open root span plus a stack of open child
/// spans. Travels with the request (inside its pool `Job` / `Active`
/// entry), so all mutation is single-threaded — no locks on the traced
/// path either.
pub struct ReqTrace {
    id: u64,
    profile: Option<String>,
    clock: Arc<dyn Clock>,
    spans: Vec<Span>,
    /// Indices of open spans, innermost last. `stack[0]` is the root,
    /// which only [`TraceRecorder::commit`] closes — so spans are
    /// well-nested by construction.
    stack: Vec<u32>,
    ttft_ns: Option<u64>,
}

impl ReqTrace {
    fn new(id: u64, profile: Option<String>, clock: Arc<dyn Clock>) -> Box<ReqTrace> {
        let start = clock.now_ns();
        let mut t = Box::new(ReqTrace {
            id,
            profile,
            clock,
            spans: Vec::with_capacity(16),
            stack: Vec::with_capacity(4),
            ttft_ns: None,
        });
        t.spans.push(Span {
            name: "request",
            track: TRACK_REQUEST,
            parent: None,
            start_ns: start,
            end_ns: start,
            attrs: Vec::new(),
        });
        t.stack.push(0);
        t
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Current time on the recorder clock (for spans measured by the
    /// caller and recorded afterwards, e.g. around `engine.begin`).
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Open a span as a child of the innermost open span.
    pub fn begin(&mut self, name: &'static str) {
        let parent = self.stack.last().copied();
        let now = self.clock.now_ns();
        let idx = self.spans.len() as u32;
        self.spans.push(Span {
            name,
            track: TRACK_REQUEST,
            parent,
            start_ns: now,
            end_ns: now,
            attrs: Vec::new(),
        });
        self.stack.push(idx);
    }

    /// Close the innermost open span. The root is never closed here
    /// (commit does that), so an extra `end()` is a safe no-op.
    pub fn end(&mut self) {
        if self.stack.len() <= 1 {
            return;
        }
        let idx = self.stack.pop().expect("stack non-empty") as usize;
        self.spans[idx].end_ns = self.clock.now_ns();
    }

    /// Attach an attribute to the innermost open span.
    pub fn attr_u64(&mut self, key: &'static str, v: u64) {
        if let Some(&i) = self.stack.last() {
            self.spans[i as usize].attrs.push((key, AttrValue::U64(v)));
        }
    }

    pub fn attr_str(&mut self, key: &'static str, v: &'static str) {
        if let Some(&i) = self.stack.last() {
            self.spans[i as usize].attrs.push((key, AttrValue::Str(v)));
        }
    }

    /// Record an already-measured closed span as a child of the
    /// innermost open span; returns its index for [`Self::record_under`]
    /// / `attr_*_on`.
    pub fn record(
        &mut self,
        name: &'static str,
        track: u32,
        start_ns: u64,
        end_ns: u64,
    ) -> usize {
        let parent = self.stack.last().copied();
        self.spans.push(Span { name, track, parent, start_ns, end_ns, attrs: Vec::new() });
        self.spans.len() - 1
    }

    /// Record a closed span under an explicit parent (a span returned by
    /// [`Self::record`] — used to hang engine segments off their quantum).
    pub fn record_under(
        &mut self,
        parent: usize,
        name: &'static str,
        track: u32,
        start_ns: u64,
        end_ns: u64,
    ) -> usize {
        self.spans.push(Span {
            name,
            track,
            parent: Some(parent as u32),
            start_ns,
            end_ns,
            attrs: Vec::new(),
        });
        self.spans.len() - 1
    }

    pub fn attr_u64_on(&mut self, idx: usize, key: &'static str, v: u64) {
        self.spans[idx].attrs.push((key, AttrValue::U64(v)));
    }

    pub fn attr_str_on(&mut self, idx: usize, key: &'static str, v: &'static str) {
        self.spans[idx].attrs.push((key, AttrValue::Str(v)));
    }

    /// Stamp time-to-first-token (first call wins; later calls no-op).
    pub fn mark_first_token(&mut self) {
        if self.ttft_ns.is_none() {
            let start = self.spans[0].start_ns;
            self.ttft_ns = Some(self.clock.now_ns().saturating_sub(start));
        }
    }
}

/// A finished trace, as stored in the ring and served over HTTP.
#[derive(Debug, Clone)]
pub struct CompletedTrace {
    pub id: u64,
    pub profile: Option<String>,
    pub replica: usize,
    pub outcome: Outcome,
    pub ttft_ns: Option<u64>,
    pub stats: TraceStats,
    /// Topologically ordered span tree; `spans[0]` is the root.
    pub spans: Vec<Span>,
}

impl CompletedTrace {
    pub fn duration_ns(&self) -> u64 {
        self.spans[0].duration_ns()
    }

    pub fn duration_seconds(&self) -> f64 {
        self.duration_ns() as f64 / 1e9
    }

    /// Summed duration (seconds) of every span with one of `names`.
    pub fn sum_named_seconds(&self, names: &[&str]) -> f64 {
        self.spans
            .iter()
            .filter(|s| names.contains(&s.name))
            .map(|s| s.duration_ns() as f64 / 1e9)
            .sum()
    }
}

/// Sampling recorder + per-replica rings of completed traces.
pub struct TraceRecorder {
    /// Trace every `period`-th submission; 0 = tracing off.
    period: u64,
    counter: AtomicU64,
    ring_cap: usize,
    clock: Arc<dyn Clock>,
    rings: Vec<Mutex<VecDeque<Arc<CompletedTrace>>>>,
}

impl TraceRecorder {
    /// `sample_rate` ∈ [0, 1]: 1.0 traces everything, 0.01 every 100th,
    /// ≤ 0 disables tracing entirely. `ring_cap` bounds each replica's
    /// completed-trace ring.
    pub fn new(
        sample_rate: f64,
        ring_cap: usize,
        replicas: usize,
        clock: Arc<dyn Clock>,
    ) -> TraceRecorder {
        let period = if sample_rate <= 0.0 {
            0
        } else {
            (1.0 / sample_rate.min(1.0)).round().max(1.0) as u64
        };
        TraceRecorder {
            period,
            counter: AtomicU64::new(0),
            ring_cap: ring_cap.max(1),
            clock,
            rings: (0..replicas.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
        }
    }

    /// A disabled recorder (every `try_sample` is the one cheap branch).
    pub fn off() -> TraceRecorder {
        TraceRecorder::new(0.0, 1, 1, Arc::new(MonotonicClock::new()))
    }

    pub fn enabled(&self) -> bool {
        self.period != 0
    }

    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Decide whether to trace one submission. **The untraced path is
    /// exactly one branch** when sampling is off — no counter bump, no
    /// clock read, no allocation.
    pub fn try_sample(&self, id: u64, profile: Option<&str>) -> Option<Box<ReqTrace>> {
        if self.period == 0 {
            return None;
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % self.period != 0 {
            return None;
        }
        Some(ReqTrace::new(id, profile.map(|s| s.to_string()), Arc::clone(&self.clock)))
    }

    /// Close every open span (root last) and move the trace into
    /// `replica`'s ring. Returns the root duration in seconds — the
    /// replica loop observes exactly this value into
    /// `fastav_generate_seconds`, which is what makes the acceptance
    /// identity (root duration == histogram sample) exact.
    pub fn commit(
        &self,
        mut trace: Box<ReqTrace>,
        replica: usize,
        outcome: Outcome,
        stats: TraceStats,
    ) -> f64 {
        let now = trace.clock.now_ns();
        while trace.stack.len() > 1 {
            let i = trace.stack.pop().expect("stack non-empty") as usize;
            trace.spans[i].end_ns = now;
        }
        trace.spans[0].end_ns = now;
        let done = CompletedTrace {
            id: trace.id,
            profile: trace.profile.take(),
            replica,
            outcome,
            ttft_ns: trace.ttft_ns,
            stats,
            spans: std::mem::take(&mut trace.spans),
        };
        let secs = done.duration_seconds();
        let ring = &self.rings[replica.min(self.rings.len() - 1)];
        let mut r = ring.lock().unwrap();
        if r.len() >= self.ring_cap {
            r.pop_front();
        }
        r.push_back(Arc::new(done));
        secs
    }

    /// Fetch a completed trace by request id (newest first within a
    /// ring, so a reused id returns the latest trace).
    pub fn get(&self, id: u64) -> Option<Arc<CompletedTrace>> {
        for ring in &self.rings {
            let r = ring.lock().unwrap();
            if let Some(t) = r.iter().rev().find(|t| t.id == id) {
                return Some(Arc::clone(t));
            }
        }
        None
    }

    /// Most recently finished traces across every replica ring, newest
    /// first (by root end timestamp, then id).
    pub fn recent(&self, limit: usize) -> Vec<Arc<CompletedTrace>> {
        let mut all: Vec<Arc<CompletedTrace>> = Vec::new();
        for ring in &self.rings {
            all.extend(ring.lock().unwrap().iter().cloned());
        }
        all.sort_by(|a, b| {
            b.spans[0]
                .end_ns
                .cmp(&a.spans[0].end_ns)
                .then(b.id.cmp(&a.id))
        });
        all.truncate(limit);
        all
    }

    /// Completed traces currently held across all rings.
    pub fn total(&self) -> usize {
        self.rings.iter().map(|r| r.lock().unwrap().len()).sum()
    }
}

// ---------------------------------------------------------------------
// Thread-local segment collector: how engine/mesh internals report
// sub-quantum timing without trait-signature changes.

/// One engine-internal segment (upload/dispatch/download/combine/
/// prefix_lookup/tier_promote), measured on the recorder clock —
/// `tier_promote` covers deserializing a spill-tier entry back onto
/// the device inside a prefix probe.
#[derive(Debug, Clone)]
pub struct Seg {
    pub name: &'static str,
    /// Mesh shard for per-shard segments; `None` = replica-thread work.
    pub shard: Option<u32>,
    pub start_ns: u64,
    pub end_ns: u64,
    /// True when this segment ran concurrently with a dispatch already
    /// in flight (the pipelined engine marks upload-of-layer-N+1 this
    /// way while layer N executes). Surfaced as an `overlap` attribute
    /// in `GET /v1/trace/{id}` and folded into the
    /// `fastav_upload_overlap_ratio` gauge.
    pub overlap: bool,
}

impl Seg {
    /// Trace track this segment belongs on.
    pub fn track(&self) -> u32 {
        self.shard.map(|s| s + 1).unwrap_or(TRACK_REQUEST)
    }
}

struct SegCtx {
    clock: Arc<dyn Clock>,
    segs: Vec<Seg>,
}

thread_local! {
    static SEG_CTX: RefCell<Option<SegCtx>> = const { RefCell::new(None) };
}

/// Run `f` with a segment collector installed on this thread; returns
/// `f`'s result and the segments the engine reported. Untraced quanta
/// never install a collector, so [`seg_begin`] stays a cheap
/// thread-local read + `None` on the hot path.
pub fn collect_segs<R>(clock: &Arc<dyn Clock>, f: impl FnOnce() -> R) -> (R, Vec<Seg>) {
    SEG_CTX.with(|c| {
        *c.borrow_mut() = Some(SegCtx { clock: Arc::clone(clock), segs: Vec::new() })
    });
    let r = f();
    let segs = SEG_CTX
        .with(|c| c.borrow_mut().take())
        .map(|ctx| ctx.segs)
        .unwrap_or_default();
    (r, segs)
}

/// Start timestamp for a segment, if a collector is active on this
/// thread (`None` otherwise — the caller passes it straight to
/// [`seg_end`], which then no-ops).
pub fn seg_begin() -> Option<u64> {
    SEG_CTX.with(|c| c.borrow().as_ref().map(|ctx| ctx.clock.now_ns()))
}

/// Close a segment opened by [`seg_begin`].
pub fn seg_end(name: &'static str, shard: Option<u32>, started: Option<u64>) {
    seg_end_overlap(name, shard, started, false);
}

/// Close a segment opened by [`seg_begin`], marking whether it
/// overlapped an in-flight dispatch (see [`Seg::overlap`]).
pub fn seg_end_overlap(
    name: &'static str,
    shard: Option<u32>,
    started: Option<u64>,
    overlap: bool,
) {
    let Some(start_ns) = started else { return };
    SEG_CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            let end_ns = ctx.clock.now_ns();
            ctx.segs.push(Seg { name, shard, start_ns, end_ns, overlap });
        }
    });
}

/// The active collector's clock, for work timed off-thread (mesh shard
/// workers measure themselves with a clone and report via [`push_seg`]
/// after the join).
pub fn seg_clock() -> Option<Arc<dyn Clock>> {
    SEG_CTX.with(|c| c.borrow().as_ref().map(|ctx| Arc::clone(&ctx.clock)))
}

/// Report a pre-measured segment (no-op without a collector).
pub fn push_seg(name: &'static str, shard: Option<u32>, start_ns: u64, end_ns: u64) {
    SEG_CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.segs.push(Seg { name, shard, start_ns, end_ns, overlap: false });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_recorder(rate: f64) -> (TraceRecorder, Arc<MockClock>) {
        let clock = Arc::new(MockClock::new());
        let r = TraceRecorder::new(rate, 8, 2, clock.clone() as Arc<dyn Clock>);
        (r, clock)
    }

    #[test]
    fn sampling_period_is_exact() {
        let (r, _) = mock_recorder(0.5);
        let sampled = (0..10).filter(|&i| r.try_sample(i, None).is_some()).count();
        assert_eq!(sampled, 5, "rate 0.5 must trace every 2nd submission");
        let (r, _) = mock_recorder(1.0);
        assert!((0..5).all(|i| r.try_sample(i, None).is_some()));
    }

    #[test]
    fn disabled_recorder_never_samples() {
        let (r, _) = mock_recorder(0.0);
        assert!(!r.enabled());
        assert!((0..100).all(|i| r.try_sample(i, None).is_none()));
        assert_eq!(r.total(), 0);
    }

    #[test]
    fn spans_are_well_nested_and_root_spans_everything() {
        let (r, clock) = mock_recorder(1.0);
        let mut t = r.try_sample(7, Some("balanced")).unwrap();
        t.begin("queue");
        clock.advance_ns(1_000);
        t.end();
        t.begin("admit");
        clock.advance_ns(500);
        let p0 = t.now_ns();
        clock.advance_ns(200);
        t.record("prefix_probe", TRACK_REQUEST, p0, t.now_ns());
        t.end();
        clock.advance_ns(2_000);
        let q = t.record("decode_quantum", TRACK_REQUEST, 1_700, 3_700);
        t.attr_u64_on(q, "batch", 3);
        t.record_under(q, "dispatch", 1, 1_800, 3_600);
        let secs = r.commit(t, 0, Outcome::Completed, TraceStats::default());
        assert!((secs - 3.7e-6).abs() < 1e-12);
        let done = r.get(7).expect("committed trace is fetchable");
        assert_eq!(done.spans[0].name, "request");
        assert_eq!(done.profile.as_deref(), Some("balanced"));
        for (i, s) in done.spans.iter().enumerate() {
            assert!(s.start_ns <= s.end_ns, "span {} inverted", s.name);
            if let Some(p) = s.parent {
                let p = &done.spans[p as usize];
                assert!((p as *const Span as usize) != (s as *const Span as usize));
                assert!(
                    p.start_ns <= s.start_ns && s.end_ns <= p.end_ns,
                    "span {} (#{}) escapes its parent {}",
                    s.name,
                    i,
                    p.name
                );
            } else {
                assert_eq!(i, 0, "only the root may be parentless");
            }
        }
    }

    #[test]
    fn commit_closes_dangling_open_spans() {
        let (r, clock) = mock_recorder(1.0);
        let mut t = r.try_sample(1, None).unwrap();
        t.begin("queue"); // never explicitly ended
        clock.advance_ns(5_000);
        r.commit(t, 1, Outcome::Canceled, TraceStats::default());
        let done = r.get(1).unwrap();
        assert_eq!(done.outcome, Outcome::Canceled);
        let q = done.spans.iter().find(|s| s.name == "queue").unwrap();
        assert_eq!(q.end_ns, 5_000, "commit must close open spans at commit time");
        assert_eq!(done.duration_ns(), 5_000);
    }

    #[test]
    fn rings_are_bounded_and_recent_is_newest_first() {
        let clock = Arc::new(MockClock::new());
        let r = TraceRecorder::new(1.0, 2, 1, clock.clone() as Arc<dyn Clock>);
        for id in 0..5 {
            let t = r.try_sample(id, None).unwrap();
            clock.advance_ns(10);
            r.commit(t, 0, Outcome::Completed, TraceStats::default());
        }
        assert_eq!(r.total(), 2, "ring cap must bound memory");
        let recent = r.recent(10);
        assert_eq!(recent.len(), 2);
        assert_eq!(recent[0].id, 4, "newest first");
        assert_eq!(recent[1].id, 3);
        assert!(r.get(0).is_none(), "evicted traces are gone");
        assert!(r.get(4).is_some());
    }

    #[test]
    fn ttft_is_first_token_only() {
        let (r, clock) = mock_recorder(1.0);
        let mut t = r.try_sample(3, None).unwrap();
        clock.advance_ns(1_500);
        t.mark_first_token();
        clock.advance_ns(9_000);
        t.mark_first_token(); // later tokens must not move it
        r.commit(t, 0, Outcome::Completed, TraceStats::default());
        assert_eq!(r.get(3).unwrap().ttft_ns, Some(1_500));
    }

    #[test]
    fn segment_collector_is_scoped_to_the_closure() {
        assert!(seg_begin().is_none(), "no collector outside collect_segs");
        let clock: Arc<dyn Clock> = Arc::new(MockClock::new());
        let (out, segs) = collect_segs(&clock, || {
            let s = seg_begin();
            assert!(s.is_some());
            seg_end("upload", None, s);
            push_seg("dispatch", Some(1), 5, 9);
            42
        });
        assert_eq!(out, 42);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].name, "upload");
        assert_eq!(segs[1].track(), 2);
        assert!(seg_begin().is_none(), "collector uninstalled after the closure");
        // And the no-collector path is inert.
        seg_end("upload", None, None);
        push_seg("x", None, 0, 1);
    }
}
