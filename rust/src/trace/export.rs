//! Trace serialization: span-tree JSON (`/v1/trace/{id}`), one-line
//! summaries (`/v1/traces` and the `/v2/generate` `timing` block), and
//! Chrome trace-event JSON (`?format=chrome`, loadable in Perfetto /
//! `chrome://tracing` — the replica is the process, each trace track a
//! thread, so parallel mesh shards render as parallel lanes).

use crate::util::json::Json;

use super::{AttrValue, CompletedTrace, Span};

fn attr_json(v: &AttrValue) -> Json {
    match v {
        AttrValue::U64(n) => Json::num(*n as f64),
        AttrValue::F64(f) => Json::num(*f),
        AttrValue::Str(s) => Json::str(s),
    }
}

fn attrs_json(span: &Span) -> Json {
    Json::Obj(
        span.attrs
            .iter()
            .map(|(k, v)| (k.to_string(), attr_json(v)))
            .collect(),
    )
}

fn span_json(t: &CompletedTrace, idx: usize) -> Json {
    let s = &t.spans[idx];
    // Children always follow their parent in the span vector, so this
    // recursion is over strictly increasing indices and terminates.
    let children: Vec<Json> = (idx + 1..t.spans.len())
        .filter(|&j| t.spans[j].parent == Some(idx as u32))
        .map(|j| span_json(t, j))
        .collect();
    let mut fields = vec![
        ("name", Json::str(s.name)),
        ("track", Json::num(s.track as f64)),
        ("start_us", Json::num(s.start_ns as f64 / 1e3)),
        ("duration_us", Json::num(s.duration_ns() as f64 / 1e3)),
    ];
    if !s.attrs.is_empty() {
        fields.push(("attrs", attrs_json(s)));
    }
    if !children.is_empty() {
        fields.push(("children", Json::arr(children)));
    }
    Json::obj(fields)
}

fn opt_str(s: &Option<String>) -> Json {
    s.as_deref().map(Json::str).unwrap_or(Json::Null)
}

/// Full span tree for `GET /v1/trace/{id}`.
pub fn trace_json(t: &CompletedTrace) -> Json {
    Json::obj(vec![
        ("request_id", Json::num(t.id as f64)),
        ("profile", opt_str(&t.profile)),
        ("replica", Json::num(t.replica as f64)),
        ("outcome", Json::str(t.outcome.name())),
        ("root", span_json(t, 0)),
    ])
}

/// One-line breakdown for `/v1/traces` and the `/v2/generate` `timing`
/// block. Phase seconds are sums over the span vocabulary, so gaps
/// (scheduler waits between quanta) show up as
/// `total - (queue + admit + prefill + decode)`.
pub fn summary_json(t: &CompletedTrace) -> Json {
    let queue = t.sum_named_seconds(&["queue"]);
    let admit = t.sum_named_seconds(&["admit"]);
    let prefill = t.sum_named_seconds(&["begin", "prefix_resume", "prefill_chunk"]);
    let decode = t.sum_named_seconds(&["decode_quantum"]);
    Json::obj(vec![
        ("request_id", Json::num(t.id as f64)),
        ("profile", opt_str(&t.profile)),
        ("replica", Json::num(t.replica as f64)),
        ("outcome", Json::str(t.outcome.name())),
        ("total_seconds", Json::num(t.duration_seconds())),
        (
            "ttft_seconds",
            t.ttft_ns
                .map(|ns| Json::num(ns as f64 / 1e9))
                .unwrap_or(Json::Null),
        ),
        ("queue_seconds", Json::num(queue)),
        ("admit_seconds", Json::num(admit)),
        ("prefill_seconds", Json::num(prefill)),
        ("decode_seconds", Json::num(decode)),
        ("tokens", Json::num(t.stats.tokens as f64)),
        ("flops_total", Json::num(t.stats.flops_total as f64)),
        ("relative_flops", Json::num(t.stats.relative_flops)),
        ("prefix_hit", Json::Bool(t.stats.prefix_hit)),
        ("spans", Json::num(t.spans.len() as f64)),
    ])
}

/// Chrome trace-event JSON (the `{"traceEvents": [...]}` object form).
/// `pid` = replica, `tid` = trace track; one `M` (metadata) event names
/// each track, then every span is a `ph:"X"` complete event with µs
/// timestamps.
pub fn chrome_json(t: &CompletedTrace) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(t.spans.len() + 4);
    let mut tracks: Vec<u32> = t.spans.iter().map(|s| s.track).collect();
    tracks.sort_unstable();
    tracks.dedup();
    for track in tracks {
        let label = if track == super::TRACK_REQUEST {
            format!("replica {} request", t.replica)
        } else {
            format!("replica {} shard {}", t.replica, track - 1)
        };
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(t.replica as f64)),
            ("tid", Json::num(track as f64)),
            ("args", Json::obj(vec![("name", Json::str(&label))])),
        ]));
    }
    for s in &t.spans {
        events.push(Json::obj(vec![
            ("ph", Json::str("X")),
            ("name", Json::str(s.name)),
            ("cat", Json::str("fastav")),
            ("pid", Json::num(t.replica as f64)),
            ("tid", Json::num(s.track as f64)),
            ("ts", Json::num(s.start_ns as f64 / 1e3)),
            ("dur", Json::num(s.duration_ns() as f64 / 1e3)),
            ("args", attrs_json(s)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::{Clock, MockClock, Outcome, TraceRecorder, TraceStats, TRACK_REQUEST};
    use super::*;

    fn sample_trace() -> Arc<CompletedTrace> {
        let clock = Arc::new(MockClock::new());
        let rec = TraceRecorder::new(1.0, 4, 1, clock.clone() as Arc<dyn Clock>);
        let mut t = rec.try_sample(11, Some("fast")).unwrap();
        t.begin("queue");
        clock.advance_ns(2_000);
        t.end();
        clock.advance_ns(1_000);
        let q = t.record("decode_quantum", TRACK_REQUEST, 2_000, 3_000);
        t.attr_u64_on(q, "batch", 2);
        t.record_under(q, "dispatch", 1, 2_100, 2_900);
        t.mark_first_token();
        rec.commit(
            t,
            0,
            Outcome::Completed,
            TraceStats { tokens: 5, flops_total: 1_000, relative_flops: 0.55, prefix_hit: true },
        );
        rec.get(11).unwrap()
    }

    #[test]
    fn tree_export_nests_children_and_roundtrips() {
        let t = sample_trace();
        let v = Json::parse(&trace_json(&t).to_string()).unwrap();
        assert_eq!(v.get("request_id").as_usize(), Some(11));
        assert_eq!(v.get("outcome").as_str(), Some("completed"));
        let root = v.get("root");
        assert_eq!(root.get("name").as_str(), Some("request"));
        let kids = root.get("children").as_arr().unwrap();
        let names: Vec<&str> = kids.iter().map(|k| k.get("name").as_str().unwrap()).collect();
        assert_eq!(names, vec!["queue", "decode_quantum"]);
        let quantum = &kids[1];
        assert_eq!(quantum.get("attrs").get("batch").as_usize(), Some(2));
        let seg = &quantum.get("children").as_arr().unwrap()[0];
        assert_eq!(seg.get("name").as_str(), Some("dispatch"));
        assert_eq!(seg.get("track").as_usize(), Some(1));
    }

    #[test]
    fn summary_breaks_down_phases() {
        let t = sample_trace();
        let v = Json::parse(&summary_json(&t).to_string()).unwrap();
        assert_eq!(v.get("profile").as_str(), Some("fast"));
        assert!((v.get("queue_seconds").as_f64().unwrap() - 2e-6).abs() < 1e-12);
        assert!((v.get("decode_seconds").as_f64().unwrap() - 1e-6).abs() < 1e-12);
        assert!((v.get("total_seconds").as_f64().unwrap() - 3e-6).abs() < 1e-12);
        assert_eq!(v.get("tokens").as_usize(), Some(5));
        assert_eq!(v.get("prefix_hit").as_bool(), Some(true));
        assert!((v.get("relative_flops").as_f64().unwrap() - 0.55).abs() < 1e-12);
    }

    #[test]
    fn chrome_export_is_valid_trace_event_json() {
        let t = sample_trace();
        let v = Json::parse(&chrome_json(&t).to_string()).unwrap();
        let events = v.get("traceEvents").as_arr().unwrap();
        // 2 tracks (request + shard 1) + 4 spans.
        let metas: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").as_str() == Some("M")).collect();
        let xs: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").as_str() == Some("X")).collect();
        assert_eq!(metas.len(), 2);
        assert_eq!(xs.len(), t.spans.len());
        for e in &xs {
            assert!(e.get("ts").as_f64().is_some());
            assert!(e.get("dur").as_f64().unwrap() >= 0.0);
            assert!(e.get("pid").as_usize().is_some());
            assert!(e.get("tid").as_usize().is_some());
        }
        assert!(xs.iter().any(|e| e.get("name").as_str() == Some("request")));
        assert!(xs
            .iter()
            .any(|e| e.get("name").as_str() == Some("dispatch")
                && e.get("tid").as_usize() == Some(1)));
    }
}
