//! Metrics registry: counters, gauges, and latency histograms with
//! Prometheus-text export (substrate — no metrics crate on this image).
//!
//! Lock-free counters (atomics); histograms use fixed log-spaced latency
//! buckets suited to the 10µs–10s range the engine operates in.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Format a labeled series name: `labeled("x_total", "replica", "0")`
/// → `x_total{replica="0"}`. [`Registry::export`] emits one `# TYPE`
/// line per base family, so labeled series group correctly under
/// Prometheus scraping. Values are escaped per the Prometheus text
/// format (`\\`, `\"`, `\n`), so operator-supplied strings (policy
/// profile names, error classes) cannot corrupt the exposition.
pub fn labeled(base: &str, key: &str, value: &str) -> String {
    format!("{}{{{}=\"{}\"}}", base, key, escape_label_value(value))
}

/// Escape a label value for the Prometheus text exposition format:
/// backslash → `\\`, double-quote → `\"`, line-feed → `\n`.
pub fn escape_label_value(value: &str) -> String {
    let mut out = String::with_capacity(value.len());
    for c in value.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Fixed size-class labels for the decode-batch occupancy distribution
/// (`fastav_decode_batch_occupancy{size="..."}`): histogram-style gauges
/// over how many requests each fused decode quantum advanced. Coarse
/// power-of-two-ish classes keep the family bounded however large the
/// compiled batch buckets grow.
pub const OCCUPANCY_BUCKETS: [&str; 6] = ["1", "2", "3-4", "5-8", "9-16", "17+"];

/// Index into [`OCCUPANCY_BUCKETS`] for a decode batch of `b` requests.
pub fn occupancy_bucket(b: usize) -> usize {
    match b {
        0 | 1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        _ => 5,
    }
}

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (u64; store scaled values for floats).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn max(&self, n: u64) {
        self.v.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Latency histogram: 32 log-spaced buckets from 10µs to ~21s (×1.6 per
/// bucket), plus count/sum for mean.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds: Vec<f64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        let mut bounds = Vec::with_capacity(32);
        let mut b = 10e-6;
        for _ in 0..32 {
            bounds.push(b);
            b *= 1.6;
        }
        Histogram {
            buckets: (0..33).map(|_| AtomicU64::new(0)).collect(),
            bounds,
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, secs: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| secs <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6 / c as f64
    }

    /// Total observed seconds (the Prometheus `_sum` series).
    pub fn sum_seconds(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap()
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

/// Named-metric registry; export() renders Prometheus text format.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Snapshot of every registered histogram, as `(name, handle)`
    /// pairs in family order — lets `/v1/pool` summarize the
    /// per-profile latency families without re-deriving the names.
    pub fn histogram_entries(&self) -> Vec<(String, std::sync::Arc<Histogram>)> {
        self.histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Prometheus text exposition. Labeled series (`name{k="v"}`) emit
    /// one `# TYPE` line per base family, in the family's first
    /// position (BTreeMap order keeps families contiguous).
    pub fn export(&self) -> String {
        fn base(name: &str) -> &str {
            name.split('{').next().unwrap_or(name)
        }
        let mut out = String::new();
        let mut last_family = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            let fam = base(name);
            if fam != last_family {
                out.push_str(&format!("# TYPE {} counter\n", fam));
                last_family = fam.to_string();
            }
            out.push_str(&format!("{} {}\n", name, c.get()));
        }
        last_family.clear();
        for (name, g) in self.gauges.lock().unwrap().iter() {
            let fam = base(name);
            if fam != last_family {
                out.push_str(&format!("# TYPE {} gauge\n", fam));
                last_family = fam.to_string();
            }
            out.push_str(&format!("{} {}\n", name, g.get()));
        }
        // Histograms render as Prometheus summaries. A registered name
        // may carry labels (`fam{k="v"}`): the suffix and quantile
        // label must merge INSIDE the braces — `fam_count{k="v"}` and
        // `fam{k="v",quantile="0.5"}` — never `fam{k="v"}_count`,
        // which is invalid exposition.
        last_family.clear();
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let (fam, labels) = match name.split_once('{') {
                Some((fam, rest)) => (fam, rest.trim_end_matches('}')),
                None => (name.as_str(), ""),
            };
            if fam != last_family {
                out.push_str(&format!("# TYPE {} summary\n", fam));
                last_family = fam.to_string();
            }
            let braced = |extra: &str| -> String {
                match (labels.is_empty(), extra.is_empty()) {
                    (true, true) => String::new(),
                    (true, false) => format!("{{{}}}", extra),
                    (false, true) => format!("{{{}}}", labels),
                    (false, false) => format!("{{{},{}}}", labels, extra),
                }
            };
            out.push_str(&format!("{}_count{} {}\n", fam, braced(""), h.count()));
            out.push_str(&format!("{}_sum{} {:.6}\n", fam, braced(""), h.sum_seconds()));
            for q in ["0.5", "0.95", "0.99"] {
                out.push_str(&format!(
                    "{}{} {:.6}\n",
                    fam,
                    braced(&format!("quantile=\"{}\"", q)),
                    h.quantile(q.parse().unwrap()),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::default();
        r.counter("reqs").inc();
        r.counter("reqs").add(4);
        assert_eq!(r.counter("reqs").get(), 5);
        r.gauge("kv_bytes").set(100);
        r.gauge("kv_bytes").max(50);
        assert_eq!(r.gauge("kv_bytes").get(), 100);
        r.gauge("kv_bytes").max(200);
        assert_eq!(r.gauge("kv_bytes").get(), 200);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-5); // 10µs .. 10ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 > 1e-3 && p50 < 1e-2);
        assert!((h.mean() - 5.0e-3).abs() < 1e-3);
    }

    #[test]
    fn histogram_empty_safe() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn export_contains_all() {
        let r = Registry::default();
        r.counter("a_total").inc();
        r.gauge("b_bytes").set(7);
        r.histogram("lat_seconds").observe(0.01);
        let text = r.export();
        assert!(text.contains("a_total 1"));
        assert!(text.contains("b_bytes 7"));
        assert!(text.contains("lat_seconds_count 1"));
        assert!(text.contains("quantile=\"0.95\""));
    }

    #[test]
    fn labeled_series_share_one_type_line() {
        let r = Registry::default();
        r.gauge(&labeled("pool_active", "replica", "0")).set(2);
        r.gauge(&labeled("pool_active", "replica", "1")).set(5);
        let text = r.export();
        assert!(text.contains("pool_active{replica=\"0\"} 2"));
        assert!(text.contains("pool_active{replica=\"1\"} 5"));
        assert_eq!(text.matches("# TYPE pool_active gauge").count(), 1);
    }

    #[test]
    fn occupancy_buckets_cover_all_sizes() {
        assert_eq!(occupancy_bucket(0), 0);
        assert_eq!(occupancy_bucket(1), 0);
        assert_eq!(occupancy_bucket(2), 1);
        assert_eq!(occupancy_bucket(4), 2);
        assert_eq!(occupancy_bucket(8), 3);
        assert_eq!(occupancy_bucket(16), 4);
        assert_eq!(occupancy_bucket(500), 5);
        // Every class has a label; classes are monotone in b.
        let mut last = 0;
        for b in 0..64 {
            let c = occupancy_bucket(b);
            assert!(c < OCCUPANCY_BUCKETS.len());
            assert!(c >= last);
            last = c;
        }
    }

    #[test]
    fn labeled_escapes_hostile_values() {
        assert_eq!(labeled("m", "k", "plain"), "m{k=\"plain\"}");
        assert_eq!(labeled("m", "k", "a\"b"), "m{k=\"a\\\"b\"}");
        assert_eq!(labeled("m", "k", "a\\b"), "m{k=\"a\\\\b\"}");
        assert_eq!(labeled("m", "k", "a\nb"), "m{k=\"a\\nb\"}");
        // A value trying to terminate the series and inject its own
        // sample line stays inside the quotes.
        let evil = labeled("m", "profile", "x\"} 999\nother_metric 1");
        assert_eq!(evil, "m{profile=\"x\\\"} 999\\nother_metric 1\"}");
        assert_eq!(evil.matches('\n').count(), 0);
    }

    #[test]
    fn histogram_exposition_is_spec_shaped() {
        // Golden test: unlabeled + labeled summaries render with the
        // suffix before the braces and quantile merged into them.
        let r = Registry::default();
        r.histogram("gen_seconds").observe(1.0);
        r.histogram("gen_seconds").observe(3.0);
        r.histogram(&labeled("gen_seconds", "profile", "fast")).observe(0.5);
        let text = r.export();
        assert_eq!(text.matches("# TYPE gen_seconds summary").count(), 1);
        assert!(text.contains("gen_seconds_count 2\n"));
        assert!(text.contains("gen_seconds_sum 4.000000\n"));
        assert!(text.contains("gen_seconds_count{profile=\"fast\"} 1\n"));
        assert!(text.contains("gen_seconds_sum{profile=\"fast\"} 0.500000\n"));
        assert!(text.contains("gen_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("gen_seconds{profile=\"fast\",quantile=\"0.99\"}"));
        // The pre-fix invalid shapes must be gone.
        assert!(!text.contains("}_count"));
        assert!(!text.contains("}_sum"));
        assert!(!text.contains("_mean_seconds"));
    }

    #[test]
    fn histogram_sum_tracks_observations() {
        let h = Histogram::default();
        h.observe(0.25);
        h.observe(0.75);
        assert!((h.sum_seconds() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn same_name_same_instance() {
        let r = Registry::default();
        let c1 = r.counter("x");
        let c2 = r.counter("x");
        c1.inc();
        assert_eq!(c2.get(), 1);
    }
}
