//! Timing harness for `cargo bench` targets (substrate — criterion is not
//! on this image; bench targets use `harness = false` and call this).
//!
//! Methodology: warmup runs, then `iters` timed runs; reports min / mean /
//! p50 / p95 / max. Deliberately simple but honest — each sample is a full
//! closure invocation, no statistical smoothing.

use std::time::Instant;

/// Result of one benchmark: per-iteration wall-clock stats in seconds.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "{:<44} {:>7} iters  min {:>10}  mean {:>10}  p50 {:>10}  p95 {:>10}  max {:>10}",
            self.name,
            self.iters,
            fmt_secs(self.min),
            fmt_secs(self.mean),
            fmt_secs(self.p50),
            fmt_secs(self.p95),
            fmt_secs(self.max),
        );
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3}s", s)
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    stats_from(name, samples)
}

/// Build stats from raw per-iteration samples (for harnesses that time
/// internally, e.g. end-to-end request latencies).
pub fn stats_from(name: &str, mut samples: Vec<f64>) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        min: samples[0],
        mean,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        max: samples[n - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computes_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = stats_from("t", samples);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 51.0);
        assert_eq!(s.p95, 96.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_closure() {
        let mut count = 0;
        let s = bench("noop", 2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(s.iters, 10);
        assert!(s.min >= 0.0 && s.min <= s.p50 && s.p50 <= s.max);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("us"));
    }
}
