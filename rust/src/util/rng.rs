//! SplitMix64 — deterministic RNG shared bit-exactly with the python side
//! (`python/compile/rng.py`). The avsynth generators on both sides must
//! produce identical sample streams; reference vectors are pinned in both
//! test suites.

/// SplitMix64 PRNG (Steele et al.); 64-bit state, 64-bit output.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)` via 64-bit modulo (bias negligible and —
    /// critically — identical to the python implementation).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform float in `[0, 1)` with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// Per-(stream, sample) seed derivation — mirrors `rng.derive_seed`.
pub fn derive_seed(base_seed: u64, stream: u64, index: u64) -> u64 {
    let mixed = base_seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ index;
    SplitMix64::new(mixed).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // Same pins as python/tests/test_avsynth.py.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r.next_u64(), 0x06C45D188009454F);
        assert_eq!(r.next_u64(), 0xF88BB8A8724C81EC);
        let mut r = SplitMix64::new(0xDEADBEEF);
        assert_eq!(r.next_u64(), 0x4ADFB90F68C9EB9B);
    }

    #[test]
    fn derive_seed_reference() {
        assert_eq!(derive_seed(1234, 3, 42), 0x9EEB26CDE5FC895C);
    }

    #[test]
    fn next_below_reference() {
        let mut r = SplitMix64::new(999);
        let got: Vec<u64> = (0..8).map(|_| r.next_below(16)).collect();
        assert_eq!(got, vec![12, 14, 6, 11, 10, 5, 3, 1]);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_reference() {
        let mut r = SplitMix64::new(999);
        let got: Vec<f64> = (0..4).map(|_| (r.next_f64() * 1e6).round() / 1e6).collect();
        assert_eq!(got, vec![0.408483, 0.911126, 0.768437, 0.457035]);
    }
}
