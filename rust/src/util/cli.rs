//! Tiny CLI argument parser (substrate — clap is not on this image).
//!
//! Grammar: `binary [subcommand] [--flag] [--key value]...`. Unknown
//! options are an error so typos fail fast.

use std::collections::BTreeMap;

/// Parsed command line: optional subcommand + option map + bare flags.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    allowed: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()[1..]`. `allowed` lists every legal option /
    /// flag name (without `--`); anything else aborts with a usage error.
    pub fn parse(
        argv: impl IntoIterator<Item = String>,
        allowed: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args {
            allowed: allowed.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(arg) = it.next() {
            let name = arg
                .strip_prefix("--")
                .ok_or_else(|| format!("unexpected positional argument '{}'", arg))?;
            if !out.allowed.iter().any(|a| a == name) {
                return Err(format!("unknown option '--{}'", name));
            }
            // An option takes a value if the next token is not another option.
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                }
                _ => out.flags.push(name.to_string()),
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{} expects an integer, got '{}'", name, v)),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{} expects a number, got '{}'", name, v)),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_opts() {
        let a = Args::parse(
            argv(&["serve", "--port", "8080", "--verbose"]),
            &["port", "verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(argv(&["--wat"]), &["port"]).is_err());
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(argv(&["--n", "5", "--p", "0.25"]), &["n", "p"]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert_eq!(a.get_f64("p", 0.0).unwrap(), 0.25);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let bad = Args::parse(argv(&["--n", "abc"]), &["n"]).unwrap();
        assert!(bad.get_usize("n", 0).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(argv(&["--x", "1"]), &["x"]).unwrap();
        assert_eq!(a.subcommand, None);
    }
}
