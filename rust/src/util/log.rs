//! Leveled logger substrate (no `log`/`tracing` crates on this image).
//!
//! Plain stderr lines: `LEVEL target: message`, with a process-global
//! level filter. Cheap enough for the serving path at Info; Debug/Trace
//! guard their formatting behind the level check via the macros.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level (also reads `FASTAV_LOG` at first use of `init`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the `FASTAV_LOG` environment variable (if set).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("FASTAV_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one log line (used by the macros; callable directly).
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        eprintln!("{:5} {}: {}", level.name(), target, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target,
                               format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::log($crate::util::log::Level::Debug, $target,
                                   format_args!($($arg)*))
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_names() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn macros_compile_and_run() {
        log_info!("test", "hello {}", 1);
        log_warn!("test", "warn {}", 2);
        log_debug!("test", "debug {}", 3);
    }
}
