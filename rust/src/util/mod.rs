//! Std-only utility substrates.
//!
//! This image has no network access and only the `xla`/`anyhow` crates
//! vendored, so the usual ecosystem crates (serde, clap, tokio, criterion,
//! proptest) are unavailable. The substrates here replace exactly what the
//! rest of the crate needs from them — nothing speculative:
//!
//! * [`json`]       — recursive-descent JSON parser + writer (manifest,
//!   model.json, calibration files, HTTP bodies).
//! * [`cli`]        — flag/option argument parsing for the binaries.
//! * [`threadpool`] — fixed worker pool for the HTTP server and client
//!   load generators.
//! * [`bench`]      — timing harness used by `cargo bench` targets
//!   (`harness = false`).
//! * [`proptest`]   — miniature property-testing driver (seeded shrinking
//!   over integer vectors) used by the invariant tests.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod threadpool;
