//! Minimal JSON parser + writer (substrate — serde is not on this image).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! stored as `f64` (adequate: all consumers are manifests, configs, and
//! HTTP bodies with small integers). Parsing is a single-pass recursive
//! descent over bytes with precise error offsets.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering
/// (stable round-trips, stable test output).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error: message + byte offset into the input.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn keyword(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates are rejected (manifests never use them).
                            let c = char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?;
                            out.push(c);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn error_offsets() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "f": 3.5}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("f").as_usize(), None);
        assert_eq!(v.get("missing").as_usize(), None);
        assert_eq!(v.get("f").as_f64(), Some(3.5));
    }
}
