//! Tiny CSV writer/reader (experiment outputs; no csv crate on this image).
//!
//! RFC-4180-lite: comma separator, `"`-quoting when a field contains
//! comma/quote/newline, `""` escaping inside quotes. The experiment
//! drivers emit every table/figure as CSV under `results/` so EXPERIMENTS
//! numbers are regenerable artifacts, not transcript copies.

use std::io::Write;
use std::path::Path;

/// Incremental CSV writer over any `Write`.
pub struct CsvWriter<W: Write> {
    out: W,
    cols: usize,
}

impl CsvWriter<std::io::BufWriter<std::fs::File>> {
    /// Create (truncate) a CSV file with a header row.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = std::io::BufWriter::new(std::fs::File::create(path)?);
        let mut w = CsvWriter { out: f, cols: header.len() };
        w.write_row(header)?;
        Ok(w)
    }
}

impl<W: Write> CsvWriter<W> {
    pub fn from_writer(out: W, cols: usize) -> Self {
        CsvWriter { out, cols }
    }

    pub fn write_row<S: AsRef<str>>(&mut self, fields: &[S]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.cols, "row width != header width");
        let mut first = true;
        for f in fields {
            if !first {
                self.out.write_all(b",")?;
            }
            first = false;
            write_field(&mut self.out, f.as_ref())?;
        }
        self.out.write_all(b"\n")
    }

    /// Convenience: numeric row with fixed precision.
    pub fn write_nums(&mut self, label: &str, nums: &[f64]) -> std::io::Result<()> {
        let mut fields = vec![label.to_string()];
        fields.extend(nums.iter().map(|v| format!("{:.6}", v)));
        self.write_row(&fields)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn write_field(out: &mut impl Write, s: &str) -> std::io::Result<()> {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        out.write_all(b"\"")?;
        out.write_all(s.replace('"', "\"\"").as_bytes())?;
        out.write_all(b"\"")
    } else {
        out.write_all(s.as_bytes())
    }
}

/// Parse CSV text into rows of fields (used by tests and tooling).
pub fn parse(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut row = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' if chars.peek() == Some(&'"') => {
                    chars.next();
                    field.push('"');
                }
                '"' => in_quotes = false,
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => row.push(std::mem::take(&mut field)),
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                '\r' => {}
                c => field.push(c),
            }
        }
    }
    if !field.is_empty() || !row.is_empty() {
        row.push(field);
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::from_writer(&mut buf, 3);
            w.write_row(&["a", "b", "c"]).unwrap();
            w.write_row(&["1", "2", "3"]).unwrap();
        }
        let rows = parse(std::str::from_utf8(&buf).unwrap());
        assert_eq!(rows, vec![vec!["a", "b", "c"], vec!["1", "2", "3"]]);
    }

    #[test]
    fn quoting_roundtrip() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::from_writer(&mut buf, 2);
            w.write_row(&["has,comma", "has\"quote"]).unwrap();
            w.write_row(&["multi\nline", "plain"]).unwrap();
        }
        let rows = parse(std::str::from_utf8(&buf).unwrap());
        assert_eq!(rows[0], vec!["has,comma", "has\"quote"]);
        assert_eq!(rows[1], vec!["multi\nline", "plain"]);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut buf = Vec::new();
        let mut w = CsvWriter::from_writer(&mut buf, 2);
        w.write_row(&["only-one"]).unwrap();
    }

    #[test]
    fn write_nums_formats() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::from_writer(&mut buf, 3);
            w.write_nums("row", &[1.0, 2.5]).unwrap();
        }
        let rows = parse(std::str::from_utf8(&buf).unwrap());
        assert_eq!(rows[0][0], "row");
        assert!(rows[0][1].starts_with("1.0"));
    }

    #[test]
    fn file_create_with_header(){
        let path = std::env::temp_dir().join(format!("fastav-csv-{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&path, &["x", "y"]).unwrap();
            w.write_row(&["1", "2"]).unwrap();
            w.flush().unwrap();
        }
        let rows = parse(&std::fs::read_to_string(&path).unwrap());
        assert_eq!(rows[0], vec!["x", "y"]);
        let _ = std::fs::remove_file(path);
    }
}
