//! Fixed-size worker thread pool (substrate — tokio is not on this image).
//!
//! Used by the HTTP server (connection handling) and by client-side load
//! generators. Jobs are `FnOnce() + Send` closures over an mpsc channel
//! guarded by a mutex (the classic "channel of boxed jobs" design).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads. Dropping the pool joins all workers
/// after draining the queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(size);
        for idx in 0..size {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            workers.push(
                thread::Builder::new()
                    .name(format!("pool-{}", idx))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { sender: Some(tx), workers, queued }
    }

    /// Enqueue a job. Panics if the pool is shutting down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Block until every submitted job has finished (simple spin+yield —
    /// call sites are tests and shutdown paths, not hot loops).
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close the channel; workers drain + exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_after_drain() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..50 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop here must wait for all 50
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_worker_is_serial() {
        let pool = ThreadPool::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..10 {
            let log = Arc::clone(&log);
            pool.execute(move || log.lock().unwrap().push(i));
        }
        pool.wait_idle();
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }
}
