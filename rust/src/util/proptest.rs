//! Miniature property-testing driver (substrate — the proptest crate is
//! not on this image).
//!
//! Generates seeded random cases from a [`Gen`] source, runs the property,
//! and on failure performs greedy input shrinking for `Vec`-shaped inputs
//! before panicking with the minimal counterexample. Deterministic: every
//! failure message includes the case seed for replay.

use super::rng::SplitMix64;

/// Random-input source handed to properties.
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: SplitMix64::new(seed) }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector of length in `[0, max_len]` with elements from `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `prop` over `cases` random cases. The property receives a fresh
/// `Gen`; it should build inputs from it and panic (assert) on violation.
/// The driver reports the failing case seed.
pub fn run_prop(name: &str, cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = 0xF057_A000u64 ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{}' failed on case {} (seed {:#x}): {}",
                name, case, seed, msg
            );
        }
    }
}

/// Greedy shrink of a failing `Vec` input: repeatedly try removing chunks
/// while `fails` keeps failing; returns the minimal failing vector.
pub fn shrink_vec<T: Clone>(input: &[T], fails: impl Fn(&[T]) -> bool) -> Vec<T> {
    assert!(fails(input), "shrink_vec requires a failing input");
    let mut cur: Vec<T> = input.to_vec();
    let mut chunk = (cur.len() / 2).max(1);
    while chunk >= 1 {
        let mut i = 0;
        let mut progressed = false;
        while i < cur.len() {
            let mut candidate = cur.clone();
            let end = (i + chunk).min(candidate.len());
            candidate.drain(i..end);
            if !candidate.is_empty() || cur.len() > chunk {
                if fails(&candidate) {
                    cur = candidate;
                    progressed = true;
                    continue; // retry same index at shorter length
                }
            }
            i += chunk;
        }
        if chunk == 1 && !progressed {
            break;
        }
        chunk = if chunk == 1 { if progressed { 1 } else { 0 } } else { chunk / 2 };
        if chunk == 0 {
            break;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(1);
        let mut b = Gen::new(1);
        for _ in 0..50 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn usize_in_bounds() {
        let mut g = Gen::new(2);
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn run_prop_passes_trivial() {
        run_prop("trivial", 50, |g| {
            let v = g.vec(10, |g| g.usize_in(0, 5));
            assert!(v.len() <= 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn run_prop_reports_failure() {
        run_prop("always-fails", 5, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn shrink_finds_minimal() {
        // Failing predicate: contains a 7.
        let input = vec![1, 2, 7, 3, 7, 4];
        let shrunk = shrink_vec(&input, |v| v.contains(&7));
        assert_eq!(shrunk, vec![7]);
    }

    #[test]
    fn choose_picks_member() {
        let mut g = Gen::new(3);
        let items = [10, 20, 30];
        for _ in 0..20 {
            assert!(items.contains(g.choose(&items)));
        }
    }
}
