//! L3 coordinator: admission, scheduling, and the engine worker loop.
//!
//! Architecture (vLLM-router-shaped, scaled to this substrate):
//!
//! ```text
//!   clients ──► Coordinator::submit ──► SchedulerQueue (bounded, 2-class)
//!                                            │ pop_blocking
//!                                       engine worker thread
//!                                       (owns ModelEngine — PJRT handles
//!                                        are not Send; one thread owns
//!                                        all device interaction)
//!                                            │ per-token stream + final
//!                                       mpsc back to the caller
//! ```
//!
//! Backpressure: a full queue rejects at admission (HTTP 429 upstream).
//! Shutdown: closing the queue drains in-flight work, then the worker
//! exits and `join` completes.

pub mod scheduler;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

pub use scheduler::{Priority, SchedStats, SchedulerQueue};

use crate::metrics::Registry;
use crate::model::{GenerateOptions, GenerateResult, ModelEngine, RequestInput};
use crate::tokens::Segment;

/// A generation request (owned data — crosses threads).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub segments: Vec<Segment>,
    pub frame_of: Vec<i32>,
    pub opts: GenerateOptions,
    pub priority: Priority,
}

/// Streaming events delivered to the submitter.
#[derive(Debug)]
pub enum Event {
    /// One generated token (streamed as decoding progresses).
    Token(u32),
    /// Generation finished; full result attached.
    Done(Box<GenerateResult>),
    /// Generation failed.
    Error(String),
}

struct Job {
    id: u64,
    req: GenRequest,
    enqueued: Instant,
    events: Sender<Event>,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    queue: Arc<SchedulerQueue<Job>>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Registry>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Start a coordinator over one engine worker thread.
    ///
    /// `artifact_root`/`model` locate the AOT artifacts; `queue_cap` bounds
    /// admission (backpressure). The engine is constructed *on* the worker
    /// thread (PJRT handles never cross threads).
    pub fn start(
        artifact_root: std::path::PathBuf,
        model: String,
        queue_cap: usize,
        warmup: bool,
    ) -> Result<Coordinator> {
        let queue: Arc<SchedulerQueue<Job>> = Arc::new(SchedulerQueue::new(queue_cap));
        let metrics = Arc::new(Registry::default());
        // Pre-register the serving metrics so /metrics is complete from
        // the first scrape, before any traffic.
        for c in [
            "fastav_requests_total",
            "fastav_requests_rejected_total",
            "fastav_requests_completed_total",
            "fastav_requests_failed_total",
            "fastav_tokens_generated_total",
        ] {
            metrics.counter(c);
        }
        metrics.gauge("fastav_queue_depth");
        metrics.gauge("fastav_kv_peak_bytes");
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();

        let worker = {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("engine-worker".into())
                .spawn(move || {
                    let mut engine = match ModelEngine::load(&artifact_root, &model) {
                        Ok(e) => e,
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("engine load: {:#}", e)));
                            return;
                        }
                    };
                    if warmup {
                        if let Err(e) = engine.warmup() {
                            let _ = ready_tx.send(Err(format!("warmup: {:#}", e)));
                            return;
                        }
                    }
                    let _ = ready_tx.send(Ok(()));
                    worker_loop(&mut engine, &queue, &metrics);
                })
                .map_err(|e| anyhow!("spawn engine worker: {}", e))?
        };

        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => {
                return Err(anyhow!(msg));
            }
            Err(_) => return Err(anyhow!("engine worker died during startup")),
        }

        Ok(Coordinator {
            queue,
            worker: Some(worker),
            metrics,
            next_id: AtomicU64::new(1),
        })
    }

    /// Submit a request; returns the streaming event receiver, or the
    /// request back when the queue is full (backpressure).
    pub fn submit(&self, req: GenRequest) -> Result<Receiver<Event>, GenRequest> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let prio = req.priority;
        let job = Job { id, req, enqueued: Instant::now(), events: tx };
        self.metrics.counter("fastav_requests_total").inc();
        match self.queue.try_push(job, prio) {
            Ok(()) => {
                self.metrics
                    .gauge("fastav_queue_depth")
                    .set(self.queue.len() as u64);
                Ok(rx)
            }
            Err(job) => {
                self.metrics.counter("fastav_requests_rejected_total").inc();
                Err(job.req)
            }
        }
    }

    /// Submit and wait for the final result (drops streamed tokens).
    pub fn submit_blocking(&self, req: GenRequest) -> Result<GenerateResult> {
        let rx = self
            .submit(req)
            .map_err(|_| anyhow!("queue full (backpressure)"))?;
        for ev in rx {
            match ev {
                Event::Token(_) => {}
                Event::Done(res) => return Ok(*res),
                Event::Error(e) => return Err(anyhow!(e)),
            }
        }
        Err(anyhow!("worker dropped the request"))
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn sched_stats(&self) -> SchedStats {
        self.queue.stats()
    }

    /// Drain and stop the worker.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(engine: &mut ModelEngine, queue: &SchedulerQueue<Job>, metrics: &Registry) {
    let queue_hist = metrics.histogram("fastav_queue_seconds");
    let gen_hist = metrics.histogram("fastav_generate_seconds");
    let prefill_hist = metrics.histogram("fastav_prefill_seconds");
    let tok_hist = metrics.histogram("fastav_decode_token_seconds");
    let completed = metrics.counter("fastav_requests_completed_total");
    let failed = metrics.counter("fastav_requests_failed_total");
    let tokens_out = metrics.counter("fastav_tokens_generated_total");
    let kv_peak = metrics.gauge("fastav_kv_peak_bytes");

    while let Some(job) = queue.pop_blocking() {
        let _ = job.id;
        queue_hist.observe(job.enqueued.elapsed().as_secs_f64());
        let t0 = Instant::now();
        let input = RequestInput {
            prompt: &job.req.prompt,
            segments: &job.req.segments,
            frame_of: &job.req.frame_of,
        };
        let events = job.events;
        let result = engine.generate_with(&input, &job.req.opts, |tok| {
            let _ = events.send(Event::Token(tok));
        });
        gen_hist.observe(t0.elapsed().as_secs_f64());
        match result {
            Ok(res) => {
                completed.inc();
                tokens_out.add(res.tokens.len() as u64);
                prefill_hist.observe(res.prefill_seconds);
                if res.decode_steps > 0 {
                    tok_hist.observe(res.decode_seconds / res.decode_steps as f64);
                }
                kv_peak.max(res.peak_kv_bytes as u64);
                let _ = events.send(Event::Done(Box::new(res)));
            }
            Err(e) => {
                failed.inc();
                let _ = events.send(Event::Error(format!("{:#}", e)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_request_is_clonable_and_send() {
        fn assert_send<T: Send>() {}
        assert_send::<GenRequest>();
        assert_send::<Event>();
    }

    #[test]
    fn startup_fails_cleanly_on_missing_artifacts() {
        let err = Coordinator::start(
            std::path::PathBuf::from("/nonexistent"),
            "ghost".into(),
            4,
            false,
        );
        assert!(err.is_err());
    }
}
