//! L3 coordinator: the serving facade over the replica pool.
//!
//! Architecture (continuous-batching, scaled to this substrate):
//!
//! ```text
//!   clients ──► Coordinator::submit ──► ReplicaPool (least-loaded dispatch)
//!                                            │ per-replica SchedulerQueue
//!                                       replica threads (each owns a
//!                                       ModelEngine — PJRT handles are
//!                                       not Send; one thread per engine)
//!                                            │ step scheduler interleaves
//!                                            │ prefill layers/decode steps
//!                                       per-token stream + final mpsc
//!                                       back to the caller
//! ```
//!
//! Backpressure: full queues reject at admission (HTTP 429 upstream);
//! closed queues reject as shutting-down (HTTP 503). Shutdown drains
//! in-flight work, then the replicas exit and `join` completes. The
//! single-worker constructor [`Coordinator::start`] is the historical
//! surface — it builds a pool of one replica.

pub mod scheduler;

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

pub use scheduler::{Priority, PushError, SchedStats, SchedulerQueue};

use crate::metrics::Registry;
use crate::model::{GenerateOptions, GenerateResult, Sampling};
use crate::policy::PruningSpec;
use crate::serving::{PoolConfig, PoolStats, ReplicaPool, ReplicaStatus, SubmitError};
use crate::tokens::Segment;

/// A generation request (owned data — crosses threads). The pruning
/// policy travels with the request as a validated [`PruningSpec`]; the
/// engine resolves it to its [`crate::model::PruningPlan`] at `begin`,
/// and the serving layers consult the spec directly for admission
/// (effective keep budget), prefix affinity (pruning-config hash), and
/// decode-batch compatibility.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub segments: Vec<Segment>,
    pub frame_of: Vec<i32>,
    /// Per-request pruning policy (profile-resolved at the API layer).
    pub spec: PruningSpec,
    /// Generation cap for this request.
    pub max_gen: usize,
    /// Token-selection parameters.
    pub sampling: Sampling,
    pub priority: Priority,
    /// Optional per-request deadline, measured from submission; an
    /// expired request aborts between scheduling quanta.
    pub deadline: Option<Duration>,
    /// Policy profile this request resolved from (observability only:
    /// labels the per-profile latency histogram and sampled traces).
    pub profile: Option<String>,
}

impl GenRequest {
    /// A request running `spec` with defaults for everything request-
    /// shaping (normal priority, no deadline, default sampling).
    pub fn with_spec(
        prompt: Vec<u32>,
        segments: Vec<Segment>,
        frame_of: Vec<i32>,
        spec: PruningSpec,
        max_gen: usize,
    ) -> GenRequest {
        GenRequest {
            prompt,
            segments,
            frame_of,
            spec,
            max_gen,
            sampling: Sampling::default(),
            priority: Priority::Normal,
            deadline: None,
            profile: None,
        }
    }

    /// Resolve the spec into the engine's per-request options.
    pub fn options(&self) -> GenerateOptions {
        GenerateOptions {
            plan: self.spec.to_plan(),
            max_gen: self.max_gen,
            sampling: self.sampling.clone(),
        }
    }
}

/// Streaming events delivered to the submitter.
#[derive(Debug)]
pub enum Event {
    /// One generated token (streamed as decoding progresses).
    Token(u32),
    /// Generation finished; full result attached.
    Done(Box<GenerateResult>),
    /// Generation failed, was canceled, or missed its deadline.
    Error(String),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    pool: ReplicaPool,
    pub metrics: Arc<Registry>,
}

impl Coordinator {
    /// Start a coordinator over a single engine replica (the historical
    /// one-worker surface; see [`Coordinator::start_pool`]).
    ///
    /// `artifact_root`/`model` locate the AOT artifacts; `queue_cap`
    /// bounds admission (backpressure).
    pub fn start(
        artifact_root: std::path::PathBuf,
        model: String,
        queue_cap: usize,
        warmup: bool,
    ) -> Result<Coordinator> {
        Self::start_pool(
            artifact_root,
            model,
            PoolConfig { replicas: 1, queue_cap, warmup, ..PoolConfig::default() },
        )
    }

    /// Start a coordinator over a replica pool. Engines are constructed
    /// on their replica threads (PJRT handles never cross threads).
    pub fn start_pool(
        artifact_root: std::path::PathBuf,
        model: String,
        cfg: PoolConfig,
    ) -> Result<Coordinator> {
        let metrics = Arc::new(Registry::default());
        let pool = ReplicaPool::start(artifact_root, model, cfg, Arc::clone(&metrics))?;
        Ok(Coordinator { pool, metrics })
    }

    /// Wrap an already-running pool (mock-engine pools in tests, chaos
    /// harness runs) in the coordinator facade, so the HTTP layer can be
    /// exercised against any [`crate::serving::ReplicaEngine`].
    pub fn from_pool(pool: ReplicaPool) -> Coordinator {
        let metrics = Arc::clone(pool.metrics());
        Coordinator { pool, metrics }
    }

    /// Submit a request; returns the streaming event receiver, or a
    /// [`SubmitError`] carrying the request back on backpressure.
    pub fn submit(&self, req: GenRequest) -> Result<Receiver<Event>, SubmitError> {
        self.submit_with_id(req).map(|(_, rx)| rx)
    }

    /// [`Self::submit`], also returning the request id usable with
    /// [`Self::cancel`].
    pub fn submit_with_id(
        &self,
        req: GenRequest,
    ) -> Result<(u64, Receiver<Event>), SubmitError> {
        self.pool.submit(req)
    }

    /// Submit for *streamed* delivery: the returned
    /// [`crate::streaming::StreamReceiver`] yields tokens as they decode
    /// plus exactly one terminal event. Dropping it mid-stream cancels
    /// the request within one scheduling quantum; a receiver that stops
    /// draining parks the request without stalling its batchmates (see
    /// `docs/STREAMING.md`).
    pub fn submit_streaming(
        &self,
        req: GenRequest,
    ) -> Result<(u64, crate::streaming::StreamReceiver), SubmitError> {
        self.pool.submit_streaming(req)
    }

    /// Streaming-session accounting (active/parked/completed), the
    /// `streams` block of `GET /v1/pool`.
    pub fn stream_stats(&self) -> crate::streaming::StreamStats {
        self.pool.stream_stats()
    }

    /// Submit and wait for the final result (drops streamed tokens).
    pub fn submit_blocking(&self, req: GenRequest) -> Result<GenerateResult> {
        let rx = self.submit(req).map_err(|e| match e {
            SubmitError::Full(_) => anyhow!("queue full (backpressure)"),
            SubmitError::Closed(_) => anyhow!("shutting down"),
        })?;
        for ev in rx {
            match ev {
                Event::Token(_) => {}
                Event::Done(res) => return Ok(*res),
                Event::Error(e) => return Err(anyhow!(e)),
            }
        }
        Err(anyhow!("worker dropped the request"))
    }

    /// Cooperatively cancel a submitted request by id.
    pub fn cancel(&self, id: u64) -> bool {
        self.pool.cancel(id)
    }

    pub fn queue_depth(&self) -> usize {
        self.pool.queue_depth()
    }

    /// Aggregate queue counters (admitted/rejected/dequeued).
    pub fn sched_stats(&self) -> SchedStats {
        self.pool.sched_stats()
    }

    /// Pool-wide conservation ledger.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Per-replica status snapshots.
    pub fn pool_status(&self) -> Vec<ReplicaStatus> {
        self.pool.status()
    }

    /// Request-lifecycle trace recorder (sampled; see the `trace`
    /// module and `GET /v1/traces`).
    pub fn tracer(&self) -> &Arc<crate::trace::TraceRecorder> {
        self.pool.tracer()
    }

    /// AV-prefix cache accounting (hits/misses/evictions, entries, bytes).
    pub fn prefix_stats(&self) -> crate::kvcache::PrefixCacheStats {
        self.pool.prefix_stats()
    }

    /// Per-pruning-config prefix-cache accounting: one row per config
    /// hash with its own entries/bytes/hit/miss counters, so
    /// mixed-profile pools report per-spec reuse instead of one
    /// aggregate (the `per_config` block of `GET /v1/pool`).
    pub fn prefix_per_config(&self) -> Vec<crate::kvcache::PerConfigPrefixStats> {
        self.pool.prefix_cache().per_config_stats()
    }

    /// Pool-wide decode-batch accounting: `(quanta, tokens)`; their
    /// ratio is the mean fused-decode batch occupancy.
    pub fn decode_batch_stats(&self) -> (u64, u64) {
        self.pool.decode_batch_stats()
    }

    /// Shared KV block-pool accounting (used/shared/free blocks).
    pub fn block_stats(&self) -> crate::kvcache::BlockPoolStats {
        self.pool.prefix_cache().pool().stats()
    }

    /// Evict every lease-free prefix entry; returns
    /// `(entries_evicted, bytes_freed)` (the `POST /v1/cache/flush`
    /// endpoint).
    pub fn flush_prefix_cache(&self) -> (usize, usize) {
        self.pool.flush_prefix_cache()
    }

    /// Drain every cache tier — device, host RAM, and disk — and reset
    /// the tier pruner's checkpoint (`POST /v1/cache/flush`).
    pub fn flush_all_tiers(&self) -> crate::serving::CacheFlushReport {
        self.pool.flush_all_tiers()
    }

    /// Spill-tier accounting (the `tier` block of `GET /v1/pool`);
    /// `None` when the pool runs device-only.
    pub fn tier_stats(&self) -> Option<crate::kvcache::TierStats> {
        self.pool.tier_stats()
    }

    pub fn replica_count(&self) -> usize {
        self.pool.replica_count()
    }

    /// Replicas currently healthy (serving, not restarting or dead).
    pub fn healthy_count(&self) -> usize {
        self.pool.healthy_count()
    }

    /// Whether every replica is dead (circuit breaker / rebuild
    /// failure) — `GET /v1/health` reports 503 exactly then.
    pub fn all_dead(&self) -> bool {
        self.pool.all_dead()
    }

    /// Drain and stop every replica.
    pub fn shutdown(self) {
        // ReplicaPool::drop closes the queues and joins the threads;
        // consuming self here makes the drain explicit at call sites.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_request_is_clonable_and_send() {
        fn assert_send<T: Send>() {}
        assert_send::<GenRequest>();
        assert_send::<Event>();
    }

    #[test]
    fn startup_fails_cleanly_on_missing_artifacts() {
        let err = Coordinator::start(
            std::path::PathBuf::from("/nonexistent"),
            "ghost".into(),
            4,
            false,
        );
        assert!(err.is_err());
    }

    #[test]
    fn pool_startup_fails_cleanly_on_missing_artifacts() {
        let err = Coordinator::start_pool(
            std::path::PathBuf::from("/nonexistent"),
            "ghost".into(),
            PoolConfig { replicas: 3, ..PoolConfig::default() },
        );
        assert!(err.is_err());
    }
}
