//! Request scheduler: bounded two-class priority queue with FIFO order
//! within each class, blocking pop, and conservation counters.
//!
//! Invariants (property-tested in `rust/tests/test_coordinator.rs`):
//! * FIFO within a priority class;
//! * High class always dequeues before Normal;
//! * `admitted == completed + rejected + in_queue + in_flight` at any
//!   quiescent point (conservation);
//! * `try_push` fails exactly when the queue is at capacity (backpressure).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Scheduling class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    High,
    Normal,
}

/// Why an admission failed. The HTTP layer maps `Full` to 429 (+
/// `Retry-After`) and `Closed` to 503; both return the item so callers
/// can retry elsewhere (e.g. another replica's queue).
pub enum PushError<T> {
    /// At capacity — backpressure; retrying later can succeed.
    Full(T),
    /// Closed for shutdown; retrying can never succeed.
    Closed(T),
}

impl<T> PushError<T> {
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(t) | PushError::Closed(t) => t,
        }
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, PushError::Closed(_))
    }
}

// Manual impl: `T` (a queued job) need not be Debug for `unwrap()` at
// call sites to work.
impl<T> std::fmt::Debug for PushError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full(_) => write!(f, "PushError::Full"),
            PushError::Closed(_) => write!(f, "PushError::Closed"),
        }
    }
}

/// Every `FAIR_EVERY`-th fair dequeue serves the Normal class first, so
/// a sustained High-priority stream cannot starve Normal admissions.
pub const FAIR_EVERY: u64 = 4;

/// Counters for the conservation invariant.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SchedStats {
    pub admitted: u64,
    pub rejected: u64,
    pub dequeued: u64,
}

struct Inner<T> {
    high: VecDeque<T>,
    normal: VecDeque<T>,
    closed: bool,
    stats: SchedStats,
}

/// Bounded blocking priority queue.
pub struct SchedulerQueue<T> {
    inner: Mutex<Inner<T>>,
    notify: Condvar,
    capacity: usize,
}

impl<T> SchedulerQueue<T> {
    pub fn new(capacity: usize) -> SchedulerQueue<T> {
        SchedulerQueue {
            inner: Mutex::new(Inner {
                high: VecDeque::new(),
                normal: VecDeque::new(),
                closed: false,
                stats: SchedStats::default(),
            }),
            notify: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Admit a request; fails with [`PushError::Full`] at capacity
    /// (backpressure → HTTP 429) or [`PushError::Closed`] during
    /// shutdown (→ HTTP 503). The item rides back in the error.
    pub fn try_push(&self, item: T, prio: Priority) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            g.stats.rejected += 1;
            return Err(PushError::Closed(item));
        }
        if g.high.len() + g.normal.len() >= self.capacity {
            g.stats.rejected += 1;
            return Err(PushError::Full(item));
        }
        match prio {
            Priority::High => g.high.push_back(item),
            Priority::Normal => g.normal.push_back(item),
        }
        g.stats.admitted += 1;
        drop(g);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop: High before Normal, FIFO within class; `None` once
    /// closed and drained.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.high.pop_front().or_else(|| g.normal.pop_front()) {
                g.stats.dequeued += 1;
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Non-blocking pop (tests / drain loops).
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.high.pop_front().or_else(|| g.normal.pop_front());
        if item.is_some() {
            g.stats.dequeued += 1;
        }
        item
    }

    /// Non-blocking pop with anti-starvation: High first, except every
    /// [`FAIR_EVERY`]-th dequeue serves Normal first. Replica admission
    /// loops use this so a saturating High stream cannot starve Normal
    /// requests out of the step scheduler.
    pub fn try_pop_fair(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let normal_first = g.stats.dequeued % FAIR_EVERY == FAIR_EVERY - 1;
        let item = if normal_first {
            g.normal.pop_front().or_else(|| g.high.pop_front())
        } else {
            g.high.pop_front().or_else(|| g.normal.pop_front())
        };
        if item.is_some() {
            g.stats.dequeued += 1;
        }
        item
    }

    /// Whether `close` has been called (new pushes will fail).
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.high.len() + g.normal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> SchedStats {
        self.inner.lock().unwrap().stats
    }

    /// Close the queue: pending items still drain; new pushes fail.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_class() {
        let q = SchedulerQueue::new(10);
        for i in 0..5 {
            q.try_push(i, Priority::Normal).unwrap();
        }
        let order: Vec<i32> = (0..5).map(|_| q.try_pop().unwrap()).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn high_preempts_normal() {
        let q = SchedulerQueue::new(10);
        q.try_push("n1", Priority::Normal).unwrap();
        q.try_push("h1", Priority::High).unwrap();
        q.try_push("n2", Priority::Normal).unwrap();
        q.try_push("h2", Priority::High).unwrap();
        let order: Vec<&str> = (0..4).map(|_| q.try_pop().unwrap()).collect();
        assert_eq!(order, vec!["h1", "h2", "n1", "n2"]);
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = SchedulerQueue::new(2);
        assert!(q.try_push(1, Priority::Normal).is_ok());
        assert!(q.try_push(2, Priority::High).is_ok());
        assert!(q.try_push(3, Priority::Normal).is_err());
        assert_eq!(q.stats().rejected, 1);
        q.try_pop().unwrap();
        assert!(q.try_push(3, Priority::Normal).is_ok());
    }

    #[test]
    fn conservation_counters() {
        let q = SchedulerQueue::new(100);
        for i in 0..30 {
            q.try_push(i, if i % 3 == 0 { Priority::High } else { Priority::Normal })
                .unwrap();
        }
        let mut popped = 0;
        while q.try_pop().is_some() {
            popped += 1;
        }
        let s = q.stats();
        assert_eq!(s.admitted, 30);
        assert_eq!(s.dequeued, 30);
        assert_eq!(popped, 30);
        assert_eq!(s.admitted, s.dequeued + q.len() as u64);
    }

    #[test]
    fn close_drains_then_none() {
        let q = Arc::new(SchedulerQueue::new(10));
        q.try_push(1, Priority::Normal).unwrap();
        q.close();
        assert!(q.try_push(2, Priority::Normal).is_err());
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn full_vs_closed_push_errors() {
        let q = SchedulerQueue::new(1);
        q.try_push(1, Priority::Normal).unwrap();
        assert!(matches!(q.try_push(2, Priority::Normal), Err(PushError::Full(2))));
        q.close();
        assert!(matches!(q.try_push(3, Priority::Normal), Err(PushError::Closed(3))));
        assert_eq!(q.stats().rejected, 2);
    }

    #[test]
    fn fair_pop_bounds_normal_wait() {
        let q = SchedulerQueue::new(64);
        for i in 0..12 {
            q.try_push(i, Priority::High).unwrap();
        }
        q.try_push(100, Priority::Normal).unwrap();
        let mut order = Vec::new();
        while let Some(v) = q.try_pop_fair() {
            order.push(v);
        }
        let pos = order.iter().position(|&v| v == 100).unwrap();
        assert!(
            pos < FAIR_EVERY as usize,
            "normal item starved to position {}",
            pos
        );
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let q = Arc::new(SchedulerQueue::new(4));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.try_push(42, Priority::Normal).unwrap();
        assert_eq!(t.join().unwrap(), Some(42));
    }
}
