//! Request scheduler: bounded two-class priority queue with FIFO order
//! within each class, blocking pop, and conservation counters.
//!
//! Invariants (property-tested in `rust/tests/test_coordinator.rs`):
//! * FIFO within a priority class;
//! * High class always dequeues before Normal;
//! * `admitted == completed + rejected + in_queue + in_flight` at any
//!   quiescent point (conservation);
//! * `try_push` fails exactly when the queue is at capacity (backpressure).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Scheduling class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    High,
    Normal,
}

/// Counters for the conservation invariant.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SchedStats {
    pub admitted: u64,
    pub rejected: u64,
    pub dequeued: u64,
}

struct Inner<T> {
    high: VecDeque<T>,
    normal: VecDeque<T>,
    closed: bool,
    stats: SchedStats,
}

/// Bounded blocking priority queue.
pub struct SchedulerQueue<T> {
    inner: Mutex<Inner<T>>,
    notify: Condvar,
    capacity: usize,
}

impl<T> SchedulerQueue<T> {
    pub fn new(capacity: usize) -> SchedulerQueue<T> {
        SchedulerQueue {
            inner: Mutex::new(Inner {
                high: VecDeque::new(),
                normal: VecDeque::new(),
                closed: false,
                stats: SchedStats::default(),
            }),
            notify: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Admit a request; `Err(item)` when the queue is full or closed
    /// (backpressure — the caller turns this into HTTP 429/503).
    pub fn try_push(&self, item: T, prio: Priority) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.high.len() + g.normal.len() >= self.capacity {
            g.stats.rejected += 1;
            return Err(item);
        }
        match prio {
            Priority::High => g.high.push_back(item),
            Priority::Normal => g.normal.push_back(item),
        }
        g.stats.admitted += 1;
        drop(g);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop: High before Normal, FIFO within class; `None` once
    /// closed and drained.
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.high.pop_front().or_else(|| g.normal.pop_front()) {
                g.stats.dequeued += 1;
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Non-blocking pop (tests / drain loops).
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.high.pop_front().or_else(|| g.normal.pop_front());
        if item.is_some() {
            g.stats.dequeued += 1;
        }
        item
    }

    pub fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.high.len() + g.normal.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> SchedStats {
        self.inner.lock().unwrap().stats
    }

    /// Close the queue: pending items still drain; new pushes fail.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_class() {
        let q = SchedulerQueue::new(10);
        for i in 0..5 {
            q.try_push(i, Priority::Normal).unwrap();
        }
        let order: Vec<i32> = (0..5).map(|_| q.try_pop().unwrap()).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn high_preempts_normal() {
        let q = SchedulerQueue::new(10);
        q.try_push("n1", Priority::Normal).unwrap();
        q.try_push("h1", Priority::High).unwrap();
        q.try_push("n2", Priority::Normal).unwrap();
        q.try_push("h2", Priority::High).unwrap();
        let order: Vec<&str> = (0..4).map(|_| q.try_pop().unwrap()).collect();
        assert_eq!(order, vec!["h1", "h2", "n1", "n2"]);
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = SchedulerQueue::new(2);
        assert!(q.try_push(1, Priority::Normal).is_ok());
        assert!(q.try_push(2, Priority::High).is_ok());
        assert!(q.try_push(3, Priority::Normal).is_err());
        assert_eq!(q.stats().rejected, 1);
        q.try_pop().unwrap();
        assert!(q.try_push(3, Priority::Normal).is_ok());
    }

    #[test]
    fn conservation_counters() {
        let q = SchedulerQueue::new(100);
        for i in 0..30 {
            q.try_push(i, if i % 3 == 0 { Priority::High } else { Priority::Normal })
                .unwrap();
        }
        let mut popped = 0;
        while q.try_pop().is_some() {
            popped += 1;
        }
        let s = q.stats();
        assert_eq!(s.admitted, 30);
        assert_eq!(s.dequeued, 30);
        assert_eq!(popped, 30);
        assert_eq!(s.admitted, s.dequeued + q.len() as u64);
    }

    #[test]
    fn close_drains_then_none() {
        let q = Arc::new(SchedulerQueue::new(10));
        q.try_push(1, Priority::Normal).unwrap();
        q.close();
        assert!(q.try_push(2, Priority::Normal).is_err());
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        let q = Arc::new(SchedulerQueue::new(4));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.try_push(42, Priority::Normal).unwrap();
        assert_eq!(t.join().unwrap(), Some(42));
    }
}
