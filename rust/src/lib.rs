//! # FastAV — efficient token pruning for audio-visual LLM inference
//!
//! Rust coordinator (L3) of the three-layer FastAV stack. The JAX/Pallas
//! layers (L2/L1, `python/compile/`) are AOT-lowered to HLO-text artifacts
//! at build time; this crate loads them through the PJRT C API and owns
//! everything on the request path: tokenization, embedding lookup, the
//! staged prefill/decode pipeline, KV-cache management, and — the paper's
//! contribution — the two-stage FastAV pruning (global at the middle
//! layer, fine in every later layer) plus the baseline policies it is
//! evaluated against.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`]        — std-only substrates: JSON, CLI parsing, thread pool.
//! * [`tokens`]      — vocabulary + modality segment layout (mirrors python).
//! * [`avsynth`]     — synthetic AV benchmark generators (bit-identical to
//!   the python training-side generators via a shared SplitMix64).
//! * [`runtime`]     — PJRT client wrapper, HLO artifact registry, bucket
//!   selection, literal helpers.
//! * [`model`]       — model config, weights, and the staged execution
//!   engine (prefill front, back layers, decode loop).
//! * [`kvcache`]     — paged per-layer KV caches over a refcounted block
//!   pool, with copy-on-write compaction and a trie prefix cache that
//!   shares the post-global-prune AV-prefix K/V across requests.
//! * [`pruning`]     — FastAV global + fine pruning and all baselines.
//! * [`policy`]      — per-request pruning policy: the typed/validated
//!   `PruningSpec`, spec hashing, and the named profile registry behind
//!   `/v2/generate` (`quality`/`balanced`/`aggressive`/`off` built-ins,
//!   operator-extensible via `--policies`).
//! * [`calibration`] — offline rollout calibration (paper Figs. 1–2).
//! * [`flops`]       — theoretical FLOPs accounting (paper's protocol).
//! * [`eval`]        — benchmark evaluation harness + scoring.
//! * [`metrics`]    — counters/histograms with Prometheus-style export.
//! * [`trace`]       — sampled request-lifecycle tracer + per-quantum
//!   engine profiler: well-nested span trees in per-replica rings,
//!   Chrome trace-event export, mock-clock deterministic in tests.
//! * [`serving`]     — continuous-batching replica pool: N engine threads,
//!   per-replica step scheduler (chunked prefill + iteration-level decode),
//!   KV-byte admission, cancellation/deadlines, and fault-domain
//!   supervision (panic-isolated quanta, respawn with backoff + circuit
//!   breaker, poison-batch quarantine, seeded chaos harness).
//! * [`streaming`]   — per-request token delivery: bounded token
//!   channels from the replica loop (park-based backpressure, one-quantum
//!   disconnect cancel), SSE events on `/v2/generate`, and a hand-rolled
//!   h2c gRPC front door (`fastav.v1.FastAV`).
//! * [`coordinator`] — serving facade: request ids, streaming, shutdown.
//! * [`http`]        — minimal HTTP/1.1 server (std::net, no framework).

pub mod avsynth;
pub mod calibration;
pub mod coordinator;
pub mod eval;
pub mod flops;
pub mod http;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod pruning;
pub mod runtime;
pub mod serving;
pub mod streaming;
pub mod tokens;
pub mod trace;
pub mod util;
