//! Per-request pruning policy: the typed [`PruningSpec`] and the named
//! [`PolicyRegistry`] behind the versioned serving API.
//!
//! FastAV's contribution *is* a tunable two-stage pruning strategy, so
//! the serving stack treats the pruning configuration as **request
//! data**, not process configuration: every
//! [`GenRequest`](crate::coordinator::GenRequest) carries a
//! `PruningSpec`, the engine resolves it to a
//! [`PruningPlan`] at `begin`, admission charges KV against the spec's
//! effective keep budget, prefix-cache keys include the spec's pruning-
//! config hash, and fused decode batches only mix spec-compatible
//! requests. One pool therefore serves mixed quality/latency tiers, A/B
//! pruning sweeps, and query-conditioned budgets concurrently.
//!
//! A `PruningSpec` is a *validated* wrapper over the engine's resolved
//! [`PruningPlan`]:
//!
//! * constructed only through validating paths ([`PruningSpec::from_plan`],
//!   [`PruningSpec::from_json`], [`PruningSpec::with_overrides`]) so an
//!   in-flight spec is well-formed by construction;
//! * canonicalized (an `off` fine stage zeroes its percent and decode
//!   flag) so equal policies serialize — and therefore hash — equally;
//! * hashable ([`PruningSpec::spec_hash`] — FNV over the canonical JSON)
//!   for metrics, logs, and per-spec cache accounting;
//! * JSON-codable with **strict unknown-key rejection** at every level,
//!   so client typos fail loudly instead of silently using defaults.
//!
//! The [`PolicyRegistry`] maps operator-facing profile names to specs.
//! Four built-ins ship with every calibrated server — `quality` /
//! `balanced` / `aggressive` / `off` (the `off` profile subsumes the
//! legacy `no_pruning` request flag) — and operators extend or override
//! them with a JSON file via `fastav serve --policies <file>` (schema in
//! `ROADMAP.md`, example in `examples/policies.example.json`).

use std::collections::BTreeMap;

use crate::calibration::Calibration;
use crate::kvcache::prefix::hash_bytes;
use crate::model::{plan_effective_keep_len, PruningPlan};
use crate::pruning::{FineStrategy, GlobalStrategy};
use crate::tokens::Segment;
use crate::util::json::Json;

// ---------------------------------------------------------------- spec

/// A validated, hashable, per-request pruning policy. See the module
/// docs; the inner [`PruningPlan`] is private so every spec in flight
/// went through validation.
#[derive(Debug, Clone, PartialEq)]
pub struct PruningSpec {
    plan: PruningPlan,
}

/// Names accepted for the global stage, in the order they are listed in
/// error messages.
const GLOBAL_NAMES: &str =
    "off|fastav_position|random|top_attentive|low_attentive|top_informative|\
     low_informative|vtw|fastv|streaming_window";
const FINE_NAMES: &str = "off|random|top_attentive|low_attentive";

fn global_name(g: &GlobalStrategy) -> &'static str {
    match g {
        GlobalStrategy::None => "off",
        GlobalStrategy::FastAvPosition { .. } => "fastav_position",
        GlobalStrategy::Random => "random",
        GlobalStrategy::TopAttentive => "top_attentive",
        GlobalStrategy::LowAttentive => "low_attentive",
        GlobalStrategy::TopInformative => "top_informative",
        GlobalStrategy::LowInformative => "low_informative",
        GlobalStrategy::Vtw => "vtw",
        GlobalStrategy::FastV { .. } => "fastv",
        GlobalStrategy::StreamingWindow { .. } => "streaming_window",
    }
}

fn fine_name(f: FineStrategy) -> &'static str {
    match f {
        FineStrategy::None => "off",
        FineStrategy::Random => "random",
        FineStrategy::TopAttentive => "top_attentive",
        FineStrategy::LowAttentive => "low_attentive",
    }
}

/// Strict unknown-key rejection shared by the spec/profile parsers and
/// the HTTP body validators: any key outside `allowed` is an error
/// naming both the offenders and the allowed set.
pub fn check_keys(
    o: &BTreeMap<String, Json>,
    allowed: &[&str],
    ctx: &str,
) -> Result<(), String> {
    let unknown: Vec<&str> = o
        .keys()
        .map(|s| s.as_str())
        .filter(|k| !allowed.contains(k))
        .collect();
    if unknown.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "unknown field(s) in {}: {} (allowed: {})",
            ctx,
            unknown.join(", "),
            allowed.join(", ")
        ))
    }
}

fn usize_of(v: &Json, ctx: &str) -> Result<usize, String> {
    v.as_usize()
        .ok_or_else(|| format!("{} must be a non-negative integer", ctx))
}

fn f64_of(v: &Json, ctx: &str) -> Result<f64, String> {
    v.as_f64().ok_or_else(|| format!("{} must be a number", ctx))
}

fn global_to_json(g: &GlobalStrategy) -> Json {
    let mut pairs = vec![("strategy", Json::str(global_name(g)))];
    match g {
        GlobalStrategy::FastAvPosition { vis_cutoff, keep_audio, keep_frames } => {
            pairs.push(("vis_cutoff", Json::num(*vis_cutoff as f64)));
            pairs.push(("keep_audio", Json::num(*keep_audio as f64)));
            pairs.push(("keep_frames", Json::num(*keep_frames as f64)));
        }
        GlobalStrategy::FastV { keep_ratio } => {
            pairs.push(("keep_ratio", Json::num(*keep_ratio)));
        }
        GlobalStrategy::StreamingWindow { sink, recent } => {
            pairs.push(("sink", Json::num(*sink as f64)));
            pairs.push(("recent", Json::num(*recent as f64)));
        }
        _ => {}
    }
    Json::obj(pairs)
}

/// Parse a `"global"` object. `base` supplies defaults: when the object
/// keeps the base's strategy, unmentioned parameters carry over; when it
/// switches strategies, parameters start from zero-defaults (stale
/// parameters of the old strategy are rejected as unknown keys).
fn parse_global(j: &Json, base: &GlobalStrategy) -> Result<GlobalStrategy, String> {
    let Some(o) = j.as_obj() else {
        return Err("'global' must be a JSON object".into());
    };
    let name = match o.get("strategy") {
        Some(v) => v
            .as_str()
            .ok_or_else(|| format!("global.strategy must be one of {}", GLOBAL_NAMES))?,
        None => global_name(base),
    };
    let same = name == global_name(base);
    match name {
        "off" => {
            check_keys(o, &["strategy"], "global (strategy 'off')")?;
            Ok(GlobalStrategy::None)
        }
        "random" => {
            check_keys(o, &["strategy"], "global (strategy 'random')")?;
            Ok(GlobalStrategy::Random)
        }
        "top_attentive" => {
            check_keys(o, &["strategy"], "global (strategy 'top_attentive')")?;
            Ok(GlobalStrategy::TopAttentive)
        }
        "low_attentive" => {
            check_keys(o, &["strategy"], "global (strategy 'low_attentive')")?;
            Ok(GlobalStrategy::LowAttentive)
        }
        "top_informative" => {
            check_keys(o, &["strategy"], "global (strategy 'top_informative')")?;
            Ok(GlobalStrategy::TopInformative)
        }
        "low_informative" => {
            check_keys(o, &["strategy"], "global (strategy 'low_informative')")?;
            Ok(GlobalStrategy::LowInformative)
        }
        "vtw" => {
            check_keys(o, &["strategy"], "global (strategy 'vtw')")?;
            Ok(GlobalStrategy::Vtw)
        }
        "fastav_position" => {
            check_keys(
                o,
                &["strategy", "vis_cutoff", "keep_audio", "keep_frames"],
                "global (strategy 'fastav_position')",
            )?;
            let (mut vc, mut ka, mut kf) = match (same, base) {
                (true, GlobalStrategy::FastAvPosition { vis_cutoff, keep_audio, keep_frames }) => {
                    (*vis_cutoff, *keep_audio, *keep_frames)
                }
                _ => (0, 0, 0),
            };
            if let Some(v) = o.get("vis_cutoff") {
                vc = usize_of(v, "global.vis_cutoff")?;
            }
            if let Some(v) = o.get("keep_audio") {
                ka = usize_of(v, "global.keep_audio")?;
            }
            if let Some(v) = o.get("keep_frames") {
                kf = usize_of(v, "global.keep_frames")?;
            }
            Ok(GlobalStrategy::FastAvPosition {
                vis_cutoff: vc,
                keep_audio: ka,
                keep_frames: kf,
            })
        }
        "fastv" => {
            check_keys(o, &["strategy", "keep_ratio"], "global (strategy 'fastv')")?;
            let mut kr = match (same, base) {
                (true, GlobalStrategy::FastV { keep_ratio }) => *keep_ratio,
                _ => 0.5,
            };
            if let Some(v) = o.get("keep_ratio") {
                kr = f64_of(v, "global.keep_ratio")?;
            }
            if !kr.is_finite() || !(0.0..=1.0).contains(&kr) {
                return Err("global.keep_ratio must be within [0, 1]".into());
            }
            Ok(GlobalStrategy::FastV { keep_ratio: kr })
        }
        "streaming_window" => {
            check_keys(
                o,
                &["strategy", "sink", "recent"],
                "global (strategy 'streaming_window')",
            )?;
            let (mut sink, mut recent) = match (same, base) {
                (true, GlobalStrategy::StreamingWindow { sink, recent }) => (*sink, *recent),
                _ => (0, 0),
            };
            if let Some(v) = o.get("sink") {
                sink = usize_of(v, "global.sink")?;
            }
            if let Some(v) = o.get("recent") {
                recent = usize_of(v, "global.recent")?;
            }
            Ok(GlobalStrategy::StreamingWindow { sink, recent })
        }
        other => Err(format!(
            "unknown global strategy '{}' (one of {})",
            other, GLOBAL_NAMES
        )),
    }
}

/// Parse a `"fine"` object with the same override semantics as the
/// global stage: keeping the base's strategy merges parameters onto it,
/// switching strategies resets `percent`/`during_decode` to defaults,
/// and strategy `off` rejects stale parameters as unknown keys.
fn parse_fine(j: &Json, plan: &mut PruningPlan) -> Result<(), String> {
    let Some(o) = j.as_obj() else {
        return Err("'fine' must be a JSON object".into());
    };
    let name = match o.get("strategy") {
        Some(v) => v
            .as_str()
            .ok_or_else(|| format!("fine.strategy must be one of {}", FINE_NAMES))?,
        None => fine_name(plan.fine),
    };
    let strategy = match name {
        "off" => {
            check_keys(o, &["strategy"], "fine (strategy 'off')")?;
            plan.fine = FineStrategy::None;
            plan.fine_percent = 0.0;
            plan.fine_during_decode = false;
            return Ok(());
        }
        "random" => FineStrategy::Random,
        "top_attentive" => FineStrategy::TopAttentive,
        "low_attentive" => FineStrategy::LowAttentive,
        other => {
            return Err(format!(
                "unknown fine strategy '{}' (one of {})",
                other, FINE_NAMES
            ))
        }
    };
    check_keys(o, &["strategy", "percent", "during_decode"], "fine")?;
    if strategy != plan.fine {
        // Strategy switch: parameters start from defaults, not the old
        // strategy's leftovers.
        plan.fine_percent = 0.0;
        plan.fine_during_decode = false;
    }
    plan.fine = strategy;
    if let Some(v) = o.get("percent") {
        plan.fine_percent = f64_of(v, "fine.percent")?;
    }
    if let Some(v) = o.get("during_decode") {
        plan.fine_during_decode = v
            .as_bool()
            .ok_or_else(|| "fine.during_decode must be a boolean".to_string())?;
    }
    Ok(())
}

impl PruningSpec {
    /// The `off` spec: no pruning at all (subsumes the legacy
    /// `no_pruning` request flag).
    pub fn off() -> PruningSpec {
        PruningSpec { plan: PruningPlan::vanilla() }
    }

    /// The deployed FastAV policy (positional global pruning +
    /// low-attentive fine pruning at `p` percent).
    pub fn fastav(vis_cutoff: usize, keep_audio: usize, keep_frames: usize, p: f64) -> PruningSpec {
        PruningSpec::from_plan(PruningPlan::fastav(vis_cutoff, keep_audio, keep_frames, p))
            .expect("fastav plan is always valid")
    }

    /// Validate and canonicalize an engine plan into a spec. Errors on
    /// out-of-range numbers (`fine_percent` outside [0, 100], a zero
    /// `global_layer`, a non-finite/off-range FastV `keep_ratio`).
    pub fn from_plan(mut plan: PruningPlan) -> Result<PruningSpec, String> {
        if !plan.fine_percent.is_finite() || !(0.0..=100.0).contains(&plan.fine_percent) {
            return Err(format!(
                "fine.percent must be within [0, 100], got {}",
                plan.fine_percent
            ));
        }
        if plan.global_layer == Some(0) {
            return Err("global_layer must be >= 1 (layer 0 has no split)".into());
        }
        if let GlobalStrategy::FastV { keep_ratio } = plan.global {
            if !keep_ratio.is_finite() || !(0.0..=1.0).contains(&keep_ratio) {
                return Err("global.keep_ratio must be within [0, 1]".into());
            }
        }
        // Seeds travel through JSON numbers (f64): anything past 2^53
        // would round-trip to a *different* seed — and therefore a
        // different keep set than the spec the API echoes back.
        const SEED_MAX: u64 = 1 << 53;
        if plan.seed > SEED_MAX {
            return Err(format!(
                "seed must be <= 2^53 ({}) to survive JSON round-trips, got {}",
                SEED_MAX, plan.seed
            ));
        }
        // Canonicalize: an off fine stage carries no percent/decode flag,
        // so equal policies hash equally.
        if plan.fine == FineStrategy::None {
            plan.fine_percent = 0.0;
            plan.fine_during_decode = false;
        }
        Ok(PruningSpec { plan })
    }

    /// The resolved engine plan (borrowed).
    pub fn plan(&self) -> &PruningPlan {
        &self.plan
    }

    /// The resolved engine plan (owned) — what `ModelEngine::begin`
    /// executes.
    pub fn to_plan(&self) -> PruningPlan {
        self.plan.clone()
    }

    /// Whether this spec performs no pruning at all.
    pub fn is_off(&self) -> bool {
        self.plan.global == GlobalStrategy::None && self.plan.fine == FineStrategy::None
    }

    /// Whether the spec's AV-prefix KV is query-independent and may use
    /// the shared prefix cache (insert *and* resume). The typed home of
    /// the engine's former inline `!needs_scores` gating.
    pub fn prefix_shareable(&self) -> bool {
        self.plan.prefix_shareable()
    }

    /// Effective keep budget over a concrete prompt layout: live rows
    /// entering the back layers, computable host-side for
    /// query-independent specs ([`plan_effective_keep_len`]). Serving
    /// admission charges KV bytes against this.
    pub fn effective_keep_len(&self, segments: &[Segment], frame_of: &[i32]) -> Option<usize> {
        plan_effective_keep_len(&self.plan, segments, frame_of)
    }

    /// Stable identity of this policy: FNV-1a over the canonical JSON
    /// encoding (objects serialize key-sorted, so equal specs hash
    /// equally across processes).
    pub fn spec_hash(&self) -> u64 {
        hash_bytes(self.to_json().to_string().as_bytes())
    }

    /// [`Self::spec_hash`] as the fixed-width hex string used in API
    /// responses and `/v1/pool` per-config stats.
    pub fn spec_hash_hex(&self) -> String {
        format!("{:016x}", self.spec_hash())
    }

    /// Decode-batching compatibility class. Requests whose class matches
    /// may advance in one fused `decode_batch` dispatch. Specs without
    /// decode-time pruning all share class `0` (rows are independent, so
    /// any such mix fuses); specs with `fine.during_decode` batch only
    /// with identical decode policies — cache compaction mid-quantum
    /// under mixed policies would make joint bucket picks thrash.
    pub fn decode_class(&self) -> u64 {
        if !self.plan.fine_during_decode || self.plan.fine == FineStrategy::None {
            return 0;
        }
        // Everything that shapes a decode-time fine-pruning step is part
        // of the class: strategy, percent, seed, and the modality floors
        // (floors bind in the fine stage too, so they change keep sets).
        hash_bytes(
            format!(
                "decode|{}|{:016x}|{}|{}|{}",
                fine_name(self.plan.fine),
                self.plan.fine_percent.to_bits(),
                self.plan.seed,
                self.plan.min_keep_vis,
                self.plan.min_keep_aud
            )
            .as_bytes(),
        )
    }

    /// Canonical JSON encoding (all fields present; `global_layer` is
    /// `null` for the model default).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("global", global_to_json(&self.plan.global)),
            ("global_budget", Json::num(self.plan.global_budget as f64)),
            (
                "global_layer",
                match self.plan.global_layer {
                    Some(g) => Json::num(g as f64),
                    None => Json::Null,
                },
            ),
            (
                "fine",
                Json::obj(vec![
                    ("strategy", Json::str(fine_name(self.plan.fine))),
                    ("percent", Json::num(self.plan.fine_percent)),
                    ("during_decode", Json::Bool(self.plan.fine_during_decode)),
                ]),
            ),
            (
                "min_keep",
                Json::obj(vec![
                    ("vis", Json::num(self.plan.min_keep_vis as f64)),
                    ("aud", Json::num(self.plan.min_keep_aud as f64)),
                ]),
            ),
            ("seed", Json::num(self.plan.seed as f64)),
        ])
    }

    /// Parse a spec from JSON. Missing fields take the `off` defaults;
    /// unknown fields are rejected with a message listing them.
    pub fn from_json(j: &Json) -> Result<PruningSpec, String> {
        PruningSpec::off().with_overrides(j)
    }

    /// Apply a (possibly partial) JSON override object on top of this
    /// spec and re-validate — the `/v2/generate` `"pruning"` body field
    /// and the `--policies` profile entries both resolve through here.
    /// `global`/`fine` objects merge field-wise while the strategy is
    /// unchanged and reset to that strategy's defaults when it switches;
    /// all other fields replace.
    pub fn with_overrides(&self, overrides: &Json) -> Result<PruningSpec, String> {
        let Some(o) = overrides.as_obj() else {
            return Err("pruning spec must be a JSON object".into());
        };
        check_keys(
            o,
            &["global", "global_budget", "global_layer", "fine", "min_keep", "seed"],
            "pruning spec",
        )?;
        let mut plan = self.plan.clone();
        if let Some(g) = o.get("global") {
            plan.global = parse_global(g, &self.plan.global)?;
        }
        if let Some(v) = o.get("global_budget") {
            plan.global_budget = usize_of(v, "global_budget")?;
        }
        if let Some(v) = o.get("global_layer") {
            plan.global_layer = match v {
                Json::Null => None,
                other => Some(usize_of(other, "global_layer")?),
            };
        }
        if let Some(f) = o.get("fine") {
            parse_fine(f, &mut plan)?;
        }
        if let Some(m) = o.get("min_keep") {
            let Some(mo) = m.as_obj() else {
                return Err("'min_keep' must be a JSON object".into());
            };
            check_keys(mo, &["vis", "aud"], "min_keep")?;
            if let Some(v) = mo.get("vis") {
                plan.min_keep_vis = usize_of(v, "min_keep.vis")?;
            }
            if let Some(v) = mo.get("aud") {
                plan.min_keep_aud = usize_of(v, "min_keep.aud")?;
            }
        }
        if let Some(v) = o.get("seed") {
            plan.seed = usize_of(v, "seed")? as u64;
        }
        PruningSpec::from_plan(plan)
    }
}

// ------------------------------------------------------------ registry

/// Profile names must be metric-label and log safe.
fn valid_profile_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Named pruning profiles an operator serves. Always contains `off`.
#[derive(Debug, Clone)]
pub struct PolicyRegistry {
    profiles: BTreeMap<String, PruningSpec>,
    default_name: String,
}

impl PolicyRegistry {
    /// A registry with only the `off` profile (serving without a
    /// calibration — the `fastav serve --no-pruning` surface).
    pub fn off_only() -> PolicyRegistry {
        let mut profiles = BTreeMap::new();
        profiles.insert("off".to_string(), PruningSpec::off());
        PolicyRegistry { profiles, default_name: "off".to_string() }
    }

    /// A registry whose default is `spec` under `name`, plus `off` —
    /// the adapter tests and examples use to serve one fixed plan.
    pub fn with_default_spec(name: &str, spec: PruningSpec) -> PolicyRegistry {
        assert!(valid_profile_name(name), "invalid profile name '{}'", name);
        let mut r = PolicyRegistry::off_only();
        r.profiles.insert(name.to_string(), spec);
        r.default_name = name.to_string();
        r
    }

    /// The four built-in profiles derived from a calibration, with
    /// `balanced` (the paper's deployed policy at fine ratio `p`,
    /// default 20) as the default:
    ///
    /// * `quality`   — calibrated cutoffs, fine at `p/2`: minimal
    ///   accuracy risk, moderate savings.
    /// * `balanced`  — `calibration.plan(p)` exactly (what `fastav
    ///   serve` served before profiles existed, keeping `/v1/generate`
    ///   behavior unchanged).
    /// * `aggressive` — cutoffs scaled to 2/3, fine at `min(2p, 60)`,
    ///   with an audio keep floor of 1 so the audio stream is never
    ///   fully silenced.
    /// * `off`       — no pruning (subsumes `no_pruning`).
    pub fn builtin(calib: &Calibration, p: f64) -> PolicyRegistry {
        let p = p.clamp(0.0, 100.0);
        let scale23 = |n: usize| (n * 2 / 3).max(1);
        let mut aggressive_plan = PruningPlan::fastav(
            scale23(calib.vis_cutoff),
            scale23(calib.keep_audio),
            if calib.keep_frames > 0 { scale23(calib.keep_frames) } else { 0 },
            (p * 2.0).min(60.0),
        );
        aggressive_plan.global_budget = scale23(calib.budget);
        aggressive_plan.min_keep_aud = 1;
        let mut r = PolicyRegistry::off_only();
        r.profiles.insert(
            "quality".into(),
            PruningSpec::from_plan(calib.plan(p / 2.0)).expect("calibrated plan is valid"),
        );
        r.profiles.insert(
            "balanced".into(),
            PruningSpec::from_plan(calib.plan(p)).expect("calibrated plan is valid"),
        );
        r.profiles.insert(
            "aggressive".into(),
            PruningSpec::from_plan(aggressive_plan).expect("aggressive plan is valid"),
        );
        r.default_name = "balanced".into();
        r
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.profiles.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&PruningSpec> {
        self.profiles.get(name)
    }

    pub fn default_name(&self) -> &str {
        &self.default_name
    }

    pub fn default_spec(&self) -> &PruningSpec {
        &self.profiles[&self.default_name]
    }

    /// Add or replace a profile. The `off` name is reserved: it backs
    /// the legacy `no_pruning` request flag, and redefining it would
    /// silently turn "no pruning" into *some* pruning for v1 clients.
    pub fn insert(&mut self, name: &str, spec: PruningSpec) -> Result<(), String> {
        if !valid_profile_name(name) {
            return Err(format!(
                "invalid profile name '{}' (1-64 chars of [A-Za-z0-9_-])",
                name
            ));
        }
        if name == "off" {
            return Err(
                "the 'off' profile is reserved (it backs the legacy no_pruning flag) \
                 and cannot be redefined"
                    .into(),
            );
        }
        self.profiles.insert(name.to_string(), spec);
        Ok(())
    }

    /// Change the default profile; the name must exist.
    pub fn set_default(&mut self, name: &str) -> Result<(), String> {
        if !self.profiles.contains_key(name) {
            return Err(format!(
                "unknown profile '{}' (known: {})",
                name,
                self.names().join(", ")
            ));
        }
        self.default_name = name.to_string();
        Ok(())
    }

    /// Merge a `--policies` JSON document:
    ///
    /// ```json
    /// {
    ///   "default": "tier-gold",
    ///   "profiles": {
    ///     "tier-gold":  {"base": "quality", "fine": {"percent": 5.0}},
    ///     "audio-safe": {"base": "balanced", "min_keep": {"aud": 8}}
    ///   }
    /// }
    /// ```
    ///
    /// Each profile body is a spec-override object plus an optional
    /// `"base"` naming the profile it starts from (default `off`); a
    /// base must already exist — a built-in or a profile earlier in
    /// alphabetical order, since entries merge in key order. Returns the
    /// number of profiles added or replaced.
    pub fn merge_policies_json(&mut self, text: &str) -> Result<usize, String> {
        let root = Json::parse(text).map_err(|e| format!("policies file: {}", e))?;
        let Some(o) = root.as_obj() else {
            return Err("policies file must be a JSON object".into());
        };
        check_keys(o, &["default", "profiles"], "policies file")?;
        let mut added = 0;
        if let Some(profiles) = o.get("profiles") {
            let Some(po) = profiles.as_obj() else {
                return Err("'profiles' must be a JSON object".into());
            };
            for (name, body) in po {
                let Some(bo) = body.as_obj() else {
                    return Err(format!("profile '{}' must be a JSON object", name));
                };
                let base_name = match bo.get("base") {
                    Some(v) => v
                        .as_str()
                        .ok_or_else(|| format!("profile '{}': 'base' must be a string", name))?,
                    None => "off",
                };
                let base = self
                    .get(base_name)
                    .cloned()
                    .ok_or_else(|| {
                        format!(
                            "profile '{}': unknown base '{}' (known: {})",
                            name,
                            base_name,
                            self.names().join(", ")
                        )
                    })?;
                let mut overrides = bo.clone();
                overrides.remove("base");
                let spec = base
                    .with_overrides(&Json::Obj(overrides))
                    .map_err(|e| format!("profile '{}': {}", name, e))?;
                self.insert(name, spec)?;
                added += 1;
            }
        }
        if let Some(d) = o.get("default") {
            let name = d
                .as_str()
                .ok_or_else(|| "'default' must be a string".to_string())?;
            self.set_default(name)?;
        }
        Ok(added)
    }

    /// The `GET /v1/policies` payload: default name + every profile's
    /// canonical spec, hash, and prefix-shareability.
    pub fn to_json(&self) -> Json {
        let profiles = Json::Obj(
            self.profiles
                .iter()
                .map(|(name, spec)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("spec", spec.to_json()),
                            ("spec_hash", Json::str(&spec.spec_hash_hex())),
                            ("prefix_shareable", Json::Bool(spec.prefix_shareable())),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("default", Json::str(&self.default_name)),
            ("profiles", profiles),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calib() -> Calibration {
        Calibration {
            model: "tiny".into(),
            samples: 8,
            threshold: 0.01,
            vis_cutoff: 6,
            keep_audio: 3,
            keep_frames: 0,
            budget: 9,
            profile: Vec::new(),
        }
    }

    #[test]
    fn off_spec_subsumes_no_pruning() {
        let off = PruningSpec::off();
        assert!(off.is_off());
        assert_eq!(off.to_plan(), PruningPlan::vanilla());
        assert!(off.prefix_shareable());
    }

    #[test]
    fn plan_roundtrip_is_identity() {
        let plans = [
            PruningPlan::vanilla(),
            PruningPlan::fastav(40, 4, 2, 20.0),
            {
                let mut p = PruningPlan::fastav(8, 2, 0, 35.0);
                p.fine_during_decode = true;
                p.global_budget = 12;
                p.global_layer = Some(3);
                p.min_keep_aud = 2;
                p.seed = 7;
                p
            },
        ];
        for plan in plans {
            let spec = PruningSpec::from_plan(plan.clone()).unwrap();
            assert_eq!(spec.to_plan(), plan, "from_plan/to_plan must round-trip");
        }
    }

    #[test]
    fn json_roundtrip_every_strategy() {
        let globals = [
            GlobalStrategy::None,
            GlobalStrategy::FastAvPosition { vis_cutoff: 9, keep_audio: 2, keep_frames: 1 },
            GlobalStrategy::Random,
            GlobalStrategy::TopAttentive,
            GlobalStrategy::LowAttentive,
            GlobalStrategy::TopInformative,
            GlobalStrategy::LowInformative,
            GlobalStrategy::Vtw,
            GlobalStrategy::FastV { keep_ratio: 0.5 },
            GlobalStrategy::StreamingWindow { sink: 4, recent: 8 },
        ];
        for g in globals {
            let mut plan = PruningPlan::fastav(0, 0, 0, 15.0);
            plan.global = g;
            plan.global_budget = 5;
            let spec = PruningSpec::from_plan(plan).unwrap();
            let back = PruningSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec, "JSON round-trip for {:?}", spec.plan().global);
            assert_eq!(back.spec_hash(), spec.spec_hash());
        }
    }

    #[test]
    fn canonicalization_makes_equal_policies_hash_equal() {
        let mut a = PruningPlan::vanilla();
        a.fine_percent = 33.0; // meaningless with fine off
        a.fine_during_decode = true;
        let a = PruningSpec::from_plan(a).unwrap();
        let b = PruningSpec::off();
        assert_eq!(a, b);
        assert_eq!(a.spec_hash(), b.spec_hash());
    }

    #[test]
    fn validation_rejects_out_of_range() {
        let mut p = PruningPlan::fastav(8, 2, 0, 120.0);
        assert!(PruningSpec::from_plan(p.clone()).is_err(), "percent > 100");
        p.fine_percent = -1.0;
        assert!(PruningSpec::from_plan(p.clone()).is_err(), "negative percent");
        p.fine_percent = 20.0;
        p.global_layer = Some(0);
        assert!(PruningSpec::from_plan(p.clone()).is_err(), "layer 0");
        p.global_layer = None;
        p.global = GlobalStrategy::FastV { keep_ratio: 1.5 };
        assert!(PruningSpec::from_plan(p).is_err(), "keep_ratio > 1");
    }

    #[test]
    fn unknown_keys_rejected_at_every_level() {
        let top = Json::parse(r#"{"globl": {"strategy": "off"}}"#).unwrap();
        let err = PruningSpec::from_json(&top).unwrap_err();
        assert!(err.contains("globl"), "message must name the typo: {}", err);
        let nested =
            Json::parse(r#"{"global": {"strategy": "vtw", "vis_cutoff": 3}}"#).unwrap();
        let err = PruningSpec::from_json(&nested).unwrap_err();
        assert!(err.contains("vis_cutoff"), "stale params rejected: {}", err);
        let fine = Json::parse(r#"{"fine": {"pct": 10}}"#).unwrap();
        assert!(PruningSpec::from_json(&fine).is_err());
    }

    #[test]
    fn overrides_merge_params_and_reset_on_strategy_switch() {
        let base = PruningSpec::fastav(40, 4, 2, 20.0);
        // Same strategy: unmentioned params carry over.
        let o = Json::parse(r#"{"global": {"vis_cutoff": 10}}"#).unwrap();
        let merged = base.with_overrides(&o).unwrap();
        assert_eq!(
            merged.plan().global,
            GlobalStrategy::FastAvPosition { vis_cutoff: 10, keep_audio: 4, keep_frames: 2 }
        );
        assert_eq!(merged.plan().fine_percent, 20.0, "fine stage untouched");
        // Strategy switch: old params do not leak through.
        let o = Json::parse(r#"{"global": {"strategy": "streaming_window", "sink": 3}}"#)
            .unwrap();
        let merged = base.with_overrides(&o).unwrap();
        assert_eq!(
            merged.plan().global,
            GlobalStrategy::StreamingWindow { sink: 3, recent: 0 }
        );
        // Partial fine override.
        let o = Json::parse(r#"{"fine": {"percent": 35.0}, "min_keep": {"aud": 2}}"#).unwrap();
        let merged = base.with_overrides(&o).unwrap();
        assert_eq!(merged.plan().fine_percent, 35.0);
        assert_eq!(merged.plan().fine, FineStrategy::LowAttentive);
        assert_eq!(merged.plan().min_keep_aud, 2);
        assert_eq!(merged.plan().min_keep_vis, 0);
        // Fine strategy switch resets percent/during_decode to defaults
        // (no leftovers from the old strategy)...
        let o = Json::parse(r#"{"fine": {"strategy": "random"}}"#).unwrap();
        let merged = base.with_overrides(&o).unwrap();
        assert_eq!(merged.plan().fine, FineStrategy::Random);
        assert_eq!(merged.plan().fine_percent, 0.0, "switch resets percent");
        // ...and `off` rejects stale parameters like the global stage.
        let o = Json::parse(r#"{"fine": {"strategy": "off", "percent": 50}}"#).unwrap();
        let err = base.with_overrides(&o).unwrap_err();
        assert!(err.contains("percent"), "stale fine params rejected: {}", err);
        // Seeds past 2^53 cannot survive a JSON round-trip: rejected.
        let mut big = PruningPlan::vanilla();
        big.seed = u64::MAX;
        assert!(PruningSpec::from_plan(big).is_err());
    }

    #[test]
    fn decode_class_groups_only_decode_pruners() {
        let plain_a = PruningSpec::fastav(40, 4, 2, 20.0);
        let plain_b = PruningSpec::off();
        assert_eq!(plain_a.decode_class(), 0);
        assert_eq!(plain_b.decode_class(), 0, "all non-decode-pruning specs fuse");
        let mut p = PruningPlan::fastav(40, 4, 2, 20.0);
        p.fine_during_decode = true;
        let dec_a = PruningSpec::from_plan(p.clone()).unwrap();
        assert_ne!(dec_a.decode_class(), 0);
        assert_eq!(dec_a.decode_class(), dec_a.clone().decode_class());
        p.fine_percent = 30.0;
        let dec_b = PruningSpec::from_plan(p.clone()).unwrap();
        assert_ne!(dec_a.decode_class(), dec_b.decode_class());
        // Floors bind in the fine stage, so they split classes too.
        p.min_keep_aud = 4;
        let dec_c = PruningSpec::from_plan(p).unwrap();
        assert_ne!(dec_b.decode_class(), dec_c.decode_class());
    }

    #[test]
    fn builtin_registry_has_four_profiles() {
        let r = PolicyRegistry::builtin(&calib(), 20.0);
        assert_eq!(r.names(), vec!["aggressive", "balanced", "off", "quality"]);
        assert_eq!(r.default_name(), "balanced");
        // balanced == the pre-profile serving plan, byte-for-byte.
        assert_eq!(r.default_spec().to_plan(), calib().plan(20.0));
        assert!(r.get("off").unwrap().is_off());
        let agg = r.get("aggressive").unwrap().plan();
        assert_eq!(agg.min_keep_aud, 1, "aggressive never silences audio");
        assert!(agg.fine_percent > 20.0);
    }

    #[test]
    fn policies_file_merges_with_bases() {
        let mut r = PolicyRegistry::builtin(&calib(), 20.0);
        let n = r
            .merge_policies_json(
                r#"{
                  "default": "tier-gold",
                  "profiles": {
                    "tier-gold": {"base": "quality", "fine": {"percent": 5.0}},
                    "audio-safe": {"base": "balanced", "min_keep": {"aud": 8}}
                  }
                }"#,
            )
            .unwrap();
        assert_eq!(n, 2);
        assert_eq!(r.default_name(), "tier-gold");
        assert_eq!(r.get("tier-gold").unwrap().plan().fine_percent, 5.0);
        assert_eq!(
            r.get("tier-gold").unwrap().plan().global,
            r.get("quality").unwrap().plan().global,
            "base's global stage carries over"
        );
        assert_eq!(r.get("audio-safe").unwrap().plan().min_keep_aud, 8);
        // Errors: unknown base, bad default, bad name, unknown key.
        assert!(r
            .merge_policies_json(r#"{"profiles": {"x": {"base": "nope"}}}"#)
            .is_err());
        assert!(r.merge_policies_json(r#"{"default": "nope"}"#).is_err());
        assert!(r
            .merge_policies_json(r#"{"profiles": {"bad name!": {}}}"#)
            .is_err());
        assert!(r.merge_policies_json(r#"{"profils": {}}"#).is_err());
        // The off profile is reserved: a file cannot silently turn the
        // legacy no_pruning flag into some pruning.
        let err = r
            .merge_policies_json(r#"{"profiles": {"off": {"base": "balanced"}}}"#)
            .unwrap_err();
        assert!(err.contains("reserved"), "{}", err);
        assert!(r.get("off").unwrap().is_off(), "off profile untouched");
    }

    #[test]
    fn registry_json_lists_profiles() {
        let r = PolicyRegistry::builtin(&calib(), 20.0);
        let j = r.to_json();
        assert_eq!(j.get("default").as_str(), Some("balanced"));
        let profiles = j.get("profiles").as_obj().unwrap();
        assert_eq!(profiles.len(), 4);
        let b = &profiles["balanced"];
        assert!(b.get("spec").get("global").get("strategy").as_str().is_some());
        assert_eq!(b.get("spec_hash").as_str().unwrap().len(), 16);
        assert_eq!(b.get("prefix_shareable").as_bool(), Some(true));
    }

    #[test]
    fn spec_hash_is_stable_and_discriminating() {
        let a = PruningSpec::fastav(40, 4, 2, 20.0);
        assert_eq!(a.spec_hash(), a.clone().spec_hash());
        let b = PruningSpec::fastav(40, 4, 2, 25.0);
        assert_ne!(a.spec_hash(), b.spec_hash());
        assert_eq!(a.spec_hash_hex().len(), 16);
    }

    #[test]
    fn effective_keep_len_delegates() {
        let mut segments = vec![Segment::Ctrl];
        segments.extend([Segment::Vis; 4]);
        segments.push(Segment::Text);
        let frames = vec![-1i32; segments.len()];
        let spec = PruningSpec::fastav(3, 0, 0, 0.0);
        // ctrl + vis{1,2} + text = 4.
        assert_eq!(spec.effective_keep_len(&segments, &frames), Some(4));
        assert_eq!(
            PruningSpec::off().effective_keep_len(&segments, &frames),
            Some(segments.len())
        );
    }
}
