//! Deterministic chaos harness: a [`ReplicaEngine`] wrapper that
//! injects faults at the four engine call sites from a seeded
//! [`FaultPlan`].
//!
//! [`ChaosEngine`] wraps any engine and consults the plan before every
//! delegated `begin` / `step` / `step_batch` / `finish`. A matching
//! [`FaultRule`] injects an [`anyhow`] error, a panic (caught by the
//! replica loop's quantum isolation and converted into a poisoning), or
//! extra latency. Matching is driven by **per-site call counters** and a
//! **seeded SplitMix64** stream held in a shared [`FaultState`] — two
//! runs with the same plan, seed, and call sequence inject exactly the
//! same faults, which is what lets `rust/tests/test_chaos.rs` pin the
//! conservation-ledger / admission-byte / prefix-lease invariants under
//! fault storms instead of merely sampling them.
//!
//! The [`FaultState`] is `Arc`-shared *outside* the engine, so a
//! factory closure can hold it across engine rebuilds: a respawned
//! replica keeps consuming the same fault schedule rather than
//! restarting it, and tests can read injection counts after the run.
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use fastav::serving::{ChaosEngine, FaultKind, FaultPlan, FaultRule, FaultSite, FaultState, FaultWhen};
//! # fn make_engine() -> anyhow::Result<()> { unimplemented!() }
//! let state = FaultState::new(FaultPlan {
//!     seed: 7,
//!     rules: vec![FaultRule {
//!         site: FaultSite::Step,
//!         when: FaultWhen::AtCall(3),
//!         kind: FaultKind::Panic,
//!         max_injections: 1,
//!     }],
//! });
//! // inside a pool factory: move a clone of `state` in, so the fault
//! // schedule survives supervisor respawns:
//! // ReplicaPool::start_with_factory(cfg, metrics, move |_| {
//! //     Ok(ChaosEngine::new(build_mock(), Arc::clone(&state)))
//! // })
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::GenRequest;
use crate::kvcache::PrefixCache;
use crate::model::{GenerateResult, StepEvent};

use super::admission::PrefixCharge;
use super::replica::ReplicaEngine;

/// Engine call sites a fault can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    Begin = 0,
    Step = 1,
    StepBatch = 2,
    Finish = 3,
}

const SITES: usize = 4;

impl FaultSite {
    fn idx(self) -> usize {
        self as usize
    }

    fn name(self) -> &'static str {
        match self {
            FaultSite::Begin => "begin",
            FaultSite::Step => "step",
            FaultSite::StepBatch => "step_batch",
            FaultSite::Finish => "finish",
        }
    }
}

/// When a rule fires, against the 1-based per-site call counter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultWhen {
    /// Exactly the n-th call to the site (1-based).
    AtCall(u64),
    /// Every n-th call (n = 0 never fires).
    Every(u64),
    /// Each call independently with probability `p`, drawn from the
    /// plan's seeded stream (deterministic for a fixed call sequence).
    WithProbability(f64),
}

/// What an injection does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Return an `anyhow` error: a transient engine fault (the replica
    /// loop attributes it to the request or quarantines the batch).
    Err,
    /// Panic: caught by quantum isolation, poisons the engine, and
    /// drives the supervisor's respawn path. At the infallible `finish`
    /// site, [`FaultKind::Err`] also panics.
    Panic,
    /// Sleep this long, then proceed normally (tail-latency injection).
    Latency(Duration),
}

/// One injection rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRule {
    pub site: FaultSite,
    pub when: FaultWhen,
    pub kind: FaultKind,
    /// Cap on how many times this rule may fire; `0` = unlimited.
    pub max_injections: u64,
}

/// A seeded fault schedule.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Seed for the [`FaultWhen::WithProbability`] stream.
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

/// SplitMix64: tiny, seedable, and good enough for fault sampling.
/// (No `rand` dependency — the container is offline.)
struct SplitMix64(u64);

impl SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Shared, thread-safe fault bookkeeping: per-site call counters,
/// per-rule injection counters, the seeded probability stream, and
/// aggregate injection counts for test assertions. Held in an `Arc` by
/// both the [`ChaosEngine`] and the test (and the pool factory closure,
/// so the schedule survives engine rebuilds).
pub struct FaultState {
    rules: Vec<FaultRule>,
    calls: [AtomicU64; SITES],
    injected: Vec<AtomicU64>,
    errs: AtomicU64,
    panics: AtomicU64,
    delays: AtomicU64,
    rng: Mutex<SplitMix64>,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Arc<FaultState> {
        let injected = plan.rules.iter().map(|_| AtomicU64::new(0)).collect();
        Arc::new(FaultState {
            injected,
            calls: Default::default(),
            errs: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            delays: AtomicU64::new(0),
            rng: Mutex::new(SplitMix64(plan.seed)),
            rules: plan.rules,
        })
    }

    /// Total calls observed at a site.
    pub fn calls(&self, site: FaultSite) -> u64 {
        self.calls[site.idx()].load(Ordering::SeqCst)
    }

    /// Times rule `i` (plan order) has fired.
    pub fn injections(&self, i: usize) -> u64 {
        self.injected.get(i).map(|c| c.load(Ordering::SeqCst)).unwrap_or(0)
    }

    /// Injected `Err` faults (including those escalated to panics at
    /// the `Finish` site).
    pub fn errs(&self) -> u64 {
        self.errs.load(Ordering::SeqCst)
    }

    /// Injected panics.
    pub fn panics(&self) -> u64 {
        self.panics.load(Ordering::SeqCst)
    }

    /// Injected latency sleeps.
    pub fn delays(&self) -> u64 {
        self.delays.load(Ordering::SeqCst)
    }

    /// Record one call at `site` and return the fault to inject, if any.
    /// The first matching rule (plan order) with injection budget wins.
    fn decide(&self, site: FaultSite) -> Option<(FaultKind, u64)> {
        let call = self.calls[site.idx()].fetch_add(1, Ordering::SeqCst) + 1; // 1-based
        for (i, r) in self.rules.iter().enumerate() {
            if r.site != site {
                continue;
            }
            if r.max_injections != 0 && self.injected[i].load(Ordering::SeqCst) >= r.max_injections
            {
                continue;
            }
            let hit = match r.when {
                FaultWhen::AtCall(n) => call == n,
                FaultWhen::Every(n) => n != 0 && call % n == 0,
                FaultWhen::WithProbability(p) => {
                    super::lock_clean(&self.rng).next_f64() < p
                }
            };
            if hit {
                self.injected[i].fetch_add(1, Ordering::SeqCst);
                return Some((r.kind, call));
            }
        }
        None
    }

    /// Apply the decision for a fallible site: `Ok(())` to proceed.
    fn inject(&self, site: FaultSite) -> Result<()> {
        match self.decide(site) {
            None => Ok(()),
            Some((FaultKind::Latency(d), _)) => {
                self.delays.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(d);
                Ok(())
            }
            Some((FaultKind::Err, call)) => {
                self.errs.fetch_add(1, Ordering::SeqCst);
                Err(anyhow!("chaos: injected error at {} call #{}", site.name(), call))
            }
            Some((FaultKind::Panic, call)) => {
                self.panics.fetch_add(1, Ordering::SeqCst);
                panic!("chaos: injected panic at {} call #{}", site.name(), call);
            }
        }
    }

    /// Apply the decision at the infallible `finish` site: `Err`
    /// escalates to a panic (there is no error channel to return it on).
    fn inject_infallible(&self, site: FaultSite) {
        match self.decide(site) {
            None => {}
            Some((FaultKind::Latency(d), _)) => {
                self.delays.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(d);
            }
            Some((FaultKind::Err, call)) | Some((FaultKind::Panic, call)) => {
                self.panics.fetch_add(1, Ordering::SeqCst);
                panic!("chaos: injected panic at {} call #{}", site.name(), call);
            }
        }
    }
}

/// A [`ReplicaEngine`] wrapper that injects the plan's faults before
/// delegating to the inner engine. Everything the plan does not target
/// passes straight through, so a `ChaosEngine<MockEngine>` behaves
/// byte-identically to the bare mock on fault-free call sequences.
pub struct ChaosEngine<E> {
    inner: E,
    state: Arc<FaultState>,
}

impl<E> ChaosEngine<E> {
    pub fn new(inner: E, state: Arc<FaultState>) -> ChaosEngine<E> {
        ChaosEngine { inner, state }
    }

    /// The shared fault bookkeeping (test assertions).
    pub fn state(&self) -> &Arc<FaultState> {
        &self.state
    }
}

impl<E: ReplicaEngine> ReplicaEngine for ChaosEngine<E> {
    type Gen = E::Gen;

    fn begin(&mut self, req: &GenRequest) -> Result<Self::Gen> {
        self.state.inject(FaultSite::Begin)?;
        self.inner.begin(req)
    }

    fn step(&mut self, gen: &mut Self::Gen) -> Result<StepEvent> {
        self.state.inject(FaultSite::Step)?;
        self.inner.step(gen)
    }

    fn is_decoding(&self, gen: &Self::Gen) -> bool {
        self.inner.is_decoding(gen)
    }

    fn max_decode_batch(&self) -> usize {
        self.inner.max_decode_batch()
    }

    fn step_batch(&mut self, gens: &mut [&mut Self::Gen]) -> Result<Vec<StepEvent>> {
        // Injected *before* delegation, honoring the transactional
        // step_batch contract: an injected batch error advances nobody,
        // so the quarantine bisect may re-step members safely.
        self.state.inject(FaultSite::StepBatch)?;
        self.inner.step_batch(gens)
    }

    fn is_done(&self, gen: &Self::Gen) -> bool {
        self.inner.is_done(gen)
    }

    fn finish(&mut self, gen: Self::Gen) -> GenerateResult {
        self.state.inject_infallible(FaultSite::Finish);
        self.inner.finish(gen)
    }

    fn kv_bytes(&self, gen: &Self::Gen) -> usize {
        self.inner.kv_bytes(gen)
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        self.inner.estimate_bytes(req)
    }

    fn attach_prefix_cache(&mut self, cache: Arc<PrefixCache>, replica: usize) {
        self.inner.attach_prefix_cache(cache, replica);
    }

    fn prefix_probe(&self, req: &GenRequest) -> Option<PrefixCharge> {
        self.inner.prefix_probe(req)
    }

    fn prefix_hit(&self, gen: &Self::Gen) -> bool {
        self.inner.prefix_hit(gen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(rules: Vec<FaultRule>, seed: u64) -> Arc<FaultState> {
        FaultState::new(FaultPlan { seed, rules })
    }

    #[test]
    fn at_call_fires_exactly_once_at_the_named_call() {
        let s = plan(
            vec![FaultRule {
                site: FaultSite::Step,
                when: FaultWhen::AtCall(3),
                kind: FaultKind::Err,
                max_injections: 0,
            }],
            0,
        );
        let outcomes: Vec<bool> = (0..6).map(|_| s.inject(FaultSite::Step).is_err()).collect();
        assert_eq!(outcomes, vec![false, false, true, false, false, false]);
        assert_eq!(s.errs(), 1);
        assert_eq!(s.calls(FaultSite::Step), 6);
    }

    #[test]
    fn every_n_fires_periodically_and_sites_count_independently() {
        let s = plan(
            vec![FaultRule {
                site: FaultSite::Begin,
                when: FaultWhen::Every(2),
                kind: FaultKind::Err,
                max_injections: 0,
            }],
            0,
        );
        let begins: Vec<bool> = (0..6).map(|_| s.inject(FaultSite::Begin).is_err()).collect();
        assert_eq!(begins, vec![false, true, false, true, false, true]);
        // Step calls do not consume Begin's schedule.
        for _ in 0..10 {
            assert!(s.inject(FaultSite::Step).is_ok());
        }
        assert_eq!(s.calls(FaultSite::Begin), 6);
        assert_eq!(s.calls(FaultSite::Step), 10);
        assert_eq!(s.errs(), 3);
    }

    #[test]
    fn max_injections_caps_a_rule() {
        let s = plan(
            vec![FaultRule {
                site: FaultSite::Step,
                when: FaultWhen::Every(1),
                kind: FaultKind::Err,
                max_injections: 2,
            }],
            0,
        );
        let outcomes: Vec<bool> = (0..5).map(|_| s.inject(FaultSite::Step).is_err()).collect();
        assert_eq!(outcomes, vec![true, true, false, false, false]);
        assert_eq!(s.injections(0), 2);
    }

    #[test]
    fn probability_stream_is_seed_deterministic() {
        let run = |seed: u64| -> Vec<bool> {
            let s = plan(
                vec![FaultRule {
                    site: FaultSite::Step,
                    when: FaultWhen::WithProbability(0.5),
                    kind: FaultKind::Err,
                    max_injections: 0,
                }],
                seed,
            );
            (0..64).map(|_| s.inject(FaultSite::Step).is_err()).collect()
        };
        assert_eq!(run(42), run(42), "same seed, same schedule");
        assert_ne!(run(42), run(43), "different seeds diverge");
        let fired = run(42).iter().filter(|&&b| b).count();
        assert!((10..=54).contains(&fired), "p=0.5 should fire roughly half: {}", fired);
    }

    #[test]
    #[should_panic(expected = "chaos: injected panic at finish")]
    fn finish_site_escalates_err_to_panic() {
        let s = plan(
            vec![FaultRule {
                site: FaultSite::Finish,
                when: FaultWhen::AtCall(1),
                kind: FaultKind::Err,
                max_injections: 0,
            }],
            0,
        );
        s.inject_infallible(FaultSite::Finish);
    }
}
