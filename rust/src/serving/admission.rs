//! Per-replica admission control: a KV-cache byte budget plus an
//! in-flight slot cap, with pool-level accounting for shared AV-prefix
//! blocks.
//!
//! Each replica owns one [`Admission`] (single-threaded — the replica
//! thread is the only caller, so no locking). A request is admitted into
//! the step scheduler only when its estimated KV footprint fits under
//! the remaining budget. The estimate is split:
//!
//! * **unique bytes** — the request's own suffix/decode blocks
//!   (conservative dense upper bound, see `ModelEngine::estimate_kv_bytes`),
//!   charged per request;
//! * **shared bytes** — the refcounted AV-prefix blocks the request will
//!   borrow from the prefix cache, charged **once per prefix entry** no
//!   matter how many concurrent requests share it (a refcount map keyed
//!   by the entry). This is what makes KV accounting for K same-prefix
//!   requests grow sub-linearly in K instead of K × slab.
//!
//! Estimates are upper bounds, so the replica can never oversubscribe
//! device-adjacent host memory no matter how pruning plays out. (One
//! benign race: if a probed entry is evicted between admission and
//! `begin`, the request re-prefills and its actual unique footprint can
//! transiently exceed the probe split; the dense per-request bound still
//! caps it.)

use std::collections::HashMap;

/// Shareable portion of a request's estimate: the prefix-cache entry it
/// will borrow, keyed so concurrent borrowers are charged once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCharge {
    /// Cache entry key (see `kvcache::prefix`).
    pub key: u64,
    /// Entry payload bytes.
    pub bytes: usize,
}

/// Outcome of an admission check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Admitted; the budget now accounts for the request.
    Granted,
    /// Does not fit *right now*; park it and retry when a running
    /// request completes.
    Defer,
    /// Can never fit — the single request exceeds the whole budget.
    /// Reject it instead of deadlocking the replica.
    Oversize,
}

impl Admit {
    /// Stable lowercase label (the `outcome` attribute on a trace's
    /// `admit` span).
    pub fn name(self) -> &'static str {
        match self {
            Admit::Granted => "granted",
            Admit::Defer => "defer",
            Admit::Oversize => "oversize",
        }
    }
}

/// KV-byte + slot accounting for one replica.
#[derive(Debug)]
pub struct Admission {
    budget_bytes: usize,
    max_inflight: usize,
    used_bytes: usize,
    inflight: usize,
    /// Shared-prefix charges: entry key → (bytes, borrower count).
    shared: HashMap<u64, (usize, usize)>,
}

impl Admission {
    /// `budget_bytes == 0` means "unlimited" (slot cap still applies).
    pub fn new(budget_bytes: usize, max_inflight: usize) -> Admission {
        Admission {
            budget_bytes: if budget_bytes == 0 { usize::MAX } else { budget_bytes },
            max_inflight: max_inflight.max(1),
            used_bytes: 0,
            inflight: 0,
            shared: HashMap::new(),
        }
    }

    /// Whether another request may even be popped from the queue.
    pub fn has_slot(&self) -> bool {
        self.inflight < self.max_inflight
    }

    /// Try to admit a request estimated at `bytes`, all unique; on
    /// `Granted` the caller must later `release(bytes)` exactly once.
    pub fn check(&mut self, bytes: usize) -> Admit {
        self.check_prefixed(bytes, None)
    }

    /// Try to admit a request whose estimate splits into `unique_bytes`
    /// plus an optional shared-prefix charge. The shared bytes count
    /// against the budget only for the entry's *first* concurrent
    /// borrower; later borrowers are charged their unique bytes alone.
    /// On `Granted` the caller must later call
    /// [`release_prefixed`](Self::release_prefixed) with the same
    /// arguments exactly once.
    pub fn check_prefixed(&mut self, unique_bytes: usize, prefix: Option<PrefixCharge>) -> Admit {
        let shared_new = match prefix {
            Some(p) if !self.shared.contains_key(&p.key) => p.bytes,
            _ => 0,
        };
        let needed = unique_bytes.saturating_add(shared_new);
        if needed > self.budget_bytes {
            return Admit::Oversize;
        }
        if !self.has_slot() || self.used_bytes.saturating_add(needed) > self.budget_bytes {
            return Admit::Defer;
        }
        self.used_bytes += needed;
        self.inflight += 1;
        if let Some(p) = prefix {
            let e = self.shared.entry(p.key).or_insert((p.bytes, 0));
            e.1 += 1;
        }
        Admit::Granted
    }

    /// Return a previously granted all-unique reservation.
    pub fn release(&mut self, bytes: usize) {
        self.release_prefixed(bytes, None);
    }

    /// Return a reservation granted by [`check_prefixed`](Self::check_prefixed).
    /// The shared charge is refunded when the *last* borrower of the
    /// entry releases.
    pub fn release_prefixed(&mut self, unique_bytes: usize, prefix: Option<PrefixCharge>) {
        debug_assert!(self.inflight > 0, "release without admit");
        let mut refund = unique_bytes;
        if let Some(p) = prefix {
            if let Some(e) = self.shared.get_mut(&p.key) {
                e.1 = e.1.saturating_sub(1);
                if e.1 == 0 {
                    refund = refund.saturating_add(e.0);
                    self.shared.remove(&p.key);
                }
            }
        }
        self.used_bytes = self.used_bytes.saturating_sub(refund);
        self.inflight = self.inflight.saturating_sub(1);
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn inflight(&self) -> usize {
        self.inflight
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Distinct prefix entries currently charged (observability).
    pub fn shared_entries(&self) -> usize {
        self.shared.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grants_until_budget_then_defers() {
        let mut a = Admission::new(100, 8);
        assert_eq!(a.check(40), Admit::Granted);
        assert_eq!(a.check(40), Admit::Granted);
        assert_eq!(a.check(40), Admit::Defer); // 120 > 100
        a.release(40);
        assert_eq!(a.check(40), Admit::Granted);
        assert_eq!(a.used_bytes(), 80);
    }

    #[test]
    fn oversize_is_terminal_not_deferred() {
        let mut a = Admission::new(100, 8);
        assert_eq!(a.check(101), Admit::Oversize);
        // Even with the budget fully free, oversize stays oversize.
        assert_eq!(a.inflight(), 0);
        assert_eq!(a.check(101), Admit::Oversize);
    }

    #[test]
    fn slot_cap_defers_independently_of_bytes() {
        let mut a = Admission::new(0, 2); // unlimited bytes, 2 slots
        assert_eq!(a.check(1), Admit::Granted);
        assert_eq!(a.check(1), Admit::Granted);
        assert!(!a.has_slot());
        assert_eq!(a.check(1), Admit::Defer);
        a.release(1);
        assert_eq!(a.check(1), Admit::Granted);
    }

    #[test]
    fn release_is_saturating() {
        let mut a = Admission::new(10, 1);
        assert_eq!(a.check(10), Admit::Granted);
        a.release(10);
        a.release(10); // double release must not underflow
        assert_eq!(a.used_bytes(), 0);
        assert_eq!(a.inflight(), 0);
    }

    #[test]
    fn shared_prefix_charged_once_across_borrowers() {
        let mut a = Admission::new(1000, 8);
        let p = Some(PrefixCharge { key: 42, bytes: 600 });
        // First borrower pays unique + shared.
        assert_eq!(a.check_prefixed(100, p), Admit::Granted);
        assert_eq!(a.used_bytes(), 700);
        // Later borrowers pay only their unique bytes: sub-linear in K.
        assert_eq!(a.check_prefixed(100, p), Admit::Granted);
        assert_eq!(a.check_prefixed(100, p), Admit::Granted);
        assert_eq!(a.used_bytes(), 900);
        assert_eq!(a.shared_entries(), 1);
        // Without sharing, the third request would not have fit.
        assert!(3 * (100 + 600) > 1000);
        // Shared bytes are refunded only at the last release.
        a.release_prefixed(100, p);
        a.release_prefixed(100, p);
        assert_eq!(a.used_bytes(), 700);
        a.release_prefixed(100, p);
        assert_eq!(a.used_bytes(), 0);
        assert_eq!(a.shared_entries(), 0);
    }

    #[test]
    fn distinct_prefixes_charged_separately() {
        let mut a = Admission::new(0, 8);
        assert_eq!(
            a.check_prefixed(10, Some(PrefixCharge { key: 1, bytes: 100 })),
            Admit::Granted
        );
        assert_eq!(
            a.check_prefixed(10, Some(PrefixCharge { key: 2, bytes: 200 })),
            Admit::Granted
        );
        assert_eq!(a.used_bytes(), 320);
        assert_eq!(a.shared_entries(), 2);
        a.release_prefixed(10, Some(PrefixCharge { key: 1, bytes: 100 }));
        a.release_prefixed(10, Some(PrefixCharge { key: 2, bytes: 200 }));
        assert_eq!(a.used_bytes(), 0);
    }

    #[test]
    fn oversize_counts_first_borrower_shared_bytes() {
        let mut a = Admission::new(500, 8);
        let p = Some(PrefixCharge { key: 7, bytes: 600 });
        // unique + first-borrower shared exceeds the whole budget.
        assert_eq!(a.check_prefixed(10, p), Admit::Oversize);
        // Once someone else holds the entry, the same request fits.
        let q = Some(PrefixCharge { key: 8, bytes: 400 });
        assert_eq!(a.check_prefixed(10, q), Admit::Granted);
        assert_eq!(a.check_prefixed(10, q), Admit::Granted);
        assert_eq!(a.used_bytes(), 420);
    }
}
