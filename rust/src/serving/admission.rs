//! Per-replica admission control: a KV-cache byte budget plus an
//! in-flight slot cap.
//!
//! Each replica owns one [`Admission`] (single-threaded — the replica
//! thread is the only caller, so no locking). A request is admitted
//! into the step scheduler only when its *estimated* KV footprint
//! (unpruned prompt + full generation budget, bucket-rounded — see
//! `ModelEngine::estimate_kv_bytes`) fits under the remaining budget.
//! Estimates are conservative upper bounds, so the replica can never
//! oversubscribe device-adjacent host memory no matter how pruning
//! plays out.

/// Outcome of an admission check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Admitted; the budget now accounts for the request.
    Granted,
    /// Does not fit *right now*; park it and retry when a running
    /// request completes.
    Defer,
    /// Can never fit — the single request exceeds the whole budget.
    /// Reject it instead of deadlocking the replica.
    Oversize,
}

/// KV-byte + slot accounting for one replica.
#[derive(Debug)]
pub struct Admission {
    budget_bytes: usize,
    max_inflight: usize,
    used_bytes: usize,
    inflight: usize,
}

impl Admission {
    /// `budget_bytes == 0` means "unlimited" (slot cap still applies).
    pub fn new(budget_bytes: usize, max_inflight: usize) -> Admission {
        Admission {
            budget_bytes: if budget_bytes == 0 { usize::MAX } else { budget_bytes },
            max_inflight: max_inflight.max(1),
            used_bytes: 0,
            inflight: 0,
        }
    }

    /// Whether another request may even be popped from the queue.
    pub fn has_slot(&self) -> bool {
        self.inflight < self.max_inflight
    }

    /// Try to admit a request estimated at `bytes`; on `Granted` the
    /// caller must later `release(bytes)` exactly once.
    pub fn check(&mut self, bytes: usize) -> Admit {
        if bytes > self.budget_bytes {
            return Admit::Oversize;
        }
        if !self.has_slot() || self.used_bytes.saturating_add(bytes) > self.budget_bytes {
            return Admit::Defer;
        }
        self.used_bytes += bytes;
        self.inflight += 1;
        Admit::Granted
    }

    /// Return a previously granted reservation.
    pub fn release(&mut self, bytes: usize) {
        debug_assert!(self.inflight > 0, "release without admit");
        self.used_bytes = self.used_bytes.saturating_sub(bytes);
        self.inflight = self.inflight.saturating_sub(1);
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn inflight(&self) -> usize {
        self.inflight
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grants_until_budget_then_defers() {
        let mut a = Admission::new(100, 8);
        assert_eq!(a.check(40), Admit::Granted);
        assert_eq!(a.check(40), Admit::Granted);
        assert_eq!(a.check(40), Admit::Defer); // 120 > 100
        a.release(40);
        assert_eq!(a.check(40), Admit::Granted);
        assert_eq!(a.used_bytes(), 80);
    }

    #[test]
    fn oversize_is_terminal_not_deferred() {
        let mut a = Admission::new(100, 8);
        assert_eq!(a.check(101), Admit::Oversize);
        // Even with the budget fully free, oversize stays oversize.
        assert_eq!(a.inflight(), 0);
        assert_eq!(a.check(101), Admit::Oversize);
    }

    #[test]
    fn slot_cap_defers_independently_of_bytes() {
        let mut a = Admission::new(0, 2); // unlimited bytes, 2 slots
        assert_eq!(a.check(1), Admit::Granted);
        assert_eq!(a.check(1), Admit::Granted);
        assert!(!a.has_slot());
        assert_eq!(a.check(1), Admit::Defer);
        a.release(1);
        assert_eq!(a.check(1), Admit::Granted);
    }

    #[test]
    fn release_is_saturating() {
        let mut a = Admission::new(10, 1);
        assert_eq!(a.check(10), Admit::Granted);
        a.release(10);
        a.release(10); // double release must not underflow
        assert_eq!(a.used_bytes(), 0);
        assert_eq!(a.inflight(), 0);
    }
}
