//! Serving subsystem: a continuous-batching replica pool.
//!
//! ```text
//!   clients ──► ReplicaPool::submit ── least-loaded dispatch ──┐
//!                                                              │
//!                  ┌───────────────────────────────────────────┤
//!                  ▼                                           ▼
//!        SchedulerQueue (replica 0)                 SchedulerQueue (N-1)
//!                  │ pop (fair)                                │
//!        replica thread 0                            replica thread N-1
//!        owns one ModelEngine                        owns one ModelEngine
//!        ┌────────────────────────┐
//!        │ StepScheduler: advance │   one *quantum* at a time:
//!        │ one in-flight gen by   │   a chunked-prefill layer or
//!        │ one quantum, weighted  │   one decode step — short
//!        │ round-robin            │   answers interleave with
//!        └────────────────────────┘   long generations
//! ```
//!
//! Why this shape: FastAV pruning cuts per-token FLOPs, but a single
//! blocking worker converts that only into single-request latency. The
//! pool converts it into *throughput* — N engines run in parallel
//! (thread-per-replica because PJRT handles are not `Send`), and within
//! each replica the [`step_scheduler`] interleaves decode steps across
//! requests so an 8-token answer never waits behind a 256-token
//! generation (no head-of-line blocking). [`admission`] gates entry on
//! a per-replica KV-cache byte budget; cancellation flags and deadlines
//! are honored between quanta.
//!
//! **Quantum model (prefill = 1 chunk, decode = 1 batch):** a scheduling
//! quantum is either one chunked-prefill layer for a single generation
//! (keeping the weighted-round-robin no-starvation bound for prefill),
//! or — when the round-robin cursor lands on a decode-ready generation —
//! one **fused decode batch**: [`StepScheduler::pick_batch`] drains up
//! to a batch-bucket's worth of decode-ready generations and the engine
//! advances them all with one `decode_batch<B>` artifact dispatch per
//! layer ([`ReplicaEngine::step_batch`]), instead of one single-token
//! dispatch per request per layer. Post-prune contexts are short, so the
//! whole batch fits one modest `[B, cap]` upload materialized straight
//! from the paged block lists (`LayerCache::padded_kv_batch_into`).
//! Batching is the default whenever ≥ 2 requests are decode-ready and
//! the artifact set carries batch buckets; ragged leftovers beyond the
//! largest bucket stay first in line for the next quantum, and engines
//! without batched artifacts degrade to the single-token path. Per-
//! quantum batch occupancy is exported as
//! `fastav_decode_batch_occupancy{size=...}` and in the `decode_batch`
//! block of `GET /v1/pool`.
//!
//! [`StepScheduler::pick_batch`]: step_scheduler::StepScheduler::pick_batch
//!
//! **Prefix reuse:** the pool owns one process-wide
//! [`PrefixCache`] (refcounted AV-prefix K/V blocks over the paged
//! [`crate::kvcache::BlockPool`]); every engine gets it at startup via
//! [`ReplicaEngine::attach_prefix_cache`]. Dispatch is prefix-affine —
//! requests sharing a cached AV prefix land on the replica that built
//! the entry — and [`admission`] charges shared prefix bytes once per
//! entry across concurrent borrowers, so KV accounting for K
//! same-prefix requests grows sub-linearly in K. `GET /v1/pool` exposes
//! the cache stats; `POST /v1/cache/flush` evicts lease-free entries.
//!
//! **Fault domains (see `docs/RELIABILITY.md`):** every engine call in
//! the replica loop runs under `catch_unwind`, so a panicking dispatch
//! becomes an attributed per-request failure instead of thread death.
//! A panic poisons the engine; the in-thread supervisor ([`supervise`])
//! rebuilds it through the factory with exponential backoff, redirects
//! stranded jobs to healthy peers (bounded per-request retries — only
//! requests that have not streamed a token are retried), and a
//! sliding-window circuit breaker marks a flapping replica
//! [`ReplicaHealth::Dead`]. `submit` routes healthy-first, excludes dead
//! replicas, and returns `Closed` (503) only when the whole pool is
//! dead. A failed *fused* decode dispatch is bisected by single-request
//! retries so only the poison generation fails. The deterministic
//! [`chaos::ChaosEngine`] wrapper injects seeded faults at every engine
//! call site to property-test all of this (`rust/tests/test_chaos.rs`).
//!
//! The pool is generic over [`replica::ReplicaEngine`], so every
//! scheduling/conservation property is testable with a mock engine and
//! no AOT artifacts (`rust/tests/test_scheduling.rs`,
//! `rust/tests/test_prefix.rs`).

pub mod admission;
pub mod chaos;
pub mod replica;
pub mod step_scheduler;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{Event, GenRequest, PushError, SchedStats, SchedulerQueue};
use crate::kvcache::{
    PrefixCache, PrefixCacheStats, PruneBudget, TierConfig, TierFlush, TierStats, TieredStore,
};
use crate::metrics::Registry;
use crate::model::{request_prefix_affinity, ModelEngine};
use crate::streaming::{EventSink, StreamReceiver, StreamStats, TokenChannel};
use crate::trace::{Clock, MonotonicClock, TraceRecorder};

pub use admission::PrefixCharge;
pub use chaos::{ChaosEngine, FaultKind, FaultPlan, FaultRule, FaultSite, FaultState, FaultWhen};
pub use replica::ReplicaEngine;
use replica::Job;

/// Pool sizing and per-replica policy.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Engine replicas (one OS thread + one `ModelEngine` each).
    pub replicas: usize,
    /// Queue capacity per replica (admission backpressure).
    pub queue_cap: usize,
    /// Max generations interleaved inside one replica.
    pub max_inflight: usize,
    /// Per-replica KV-cache byte budget; `0` = unlimited.
    pub kv_budget_bytes: usize,
    /// Byte budget for the shared AV-prefix cache (LRU eviction over
    /// lease-free entries); `0` = unlimited.
    pub prefix_cache_bytes: usize,
    /// Pre-compile serving artifacts on every replica at startup.
    pub warmup: bool,
    /// Deadline applied to requests that don't carry their own.
    pub default_deadline: Option<Duration>,
    /// Cap on the fused decode batch per quantum: `0` = whatever the
    /// engine's artifacts support ([`ReplicaEngine::max_decode_batch`]),
    /// `1` = force the single-token path (A/B benchmarking), `n` =
    /// min(n, engine limit).
    pub max_decode_batch: usize,
    /// Tensor-parallel degree per replica: each replica becomes a
    /// device *group* of this many mesh devices, the model head-sharded
    /// across them (`fastav serve --tp`). Admission charges KV bytes
    /// against the group's pooled capacity (`kv_budget_bytes` ×
    /// `tp_degree`). `1` (or `0`) = today's one-device replicas.
    pub tp_degree: usize,
    /// Request-trace sampling rate in [0, 1] (`fastav serve
    /// --trace-sample`). `0` disables tracing: one branch at submit,
    /// nothing allocated on the request path.
    pub trace_sample: f64,
    /// Completed traces retained per replica (`--trace-ring`); bounds
    /// tracer memory regardless of uptime.
    pub trace_ring: usize,
    /// First respawn delay after an engine panic; doubles per restart
    /// inside the circuit window up to [`Self::restart_backoff_max`].
    pub restart_backoff: Duration,
    /// Ceiling on the exponential respawn backoff.
    pub restart_backoff_max: Duration,
    /// Circuit breaker: more than this many restarts inside
    /// [`Self::circuit_window`] marks the replica [`ReplicaHealth::Dead`]
    /// (its queue closes and `submit` stops routing to it).
    pub circuit_restarts: usize,
    /// Sliding window the circuit breaker counts restarts over.
    pub circuit_window: Duration,
    /// Times one request may be re-enqueued after its replica poisons
    /// before it fails with the attributed engine error. Only requests
    /// that have not yet streamed a token are retried (re-running a
    /// partially streamed generation would duplicate tokens client-side).
    pub max_request_retries: u32,
    /// Pipelined quantum execution (`fastav serve --pipeline`): overlap
    /// layer `l+1`'s KV gather + literal build with layer `l`'s
    /// in-flight dispatch, with per-layer delta-append staging buffers.
    /// Token-for-token identical to the strict ordering; `false`
    /// forces the sequential upload→dispatch path (A/B benchmarking).
    pub pipeline: bool,
    /// Host-RAM spill tier budget below the device prefix cache
    /// (`fastav serve --tier-ram-mb`); `0` disables the RAM tier.
    /// Device evictions demote into the tier instead of dropping; see
    /// `docs/TIERED_KV.md`.
    pub tier_ram_bytes: usize,
    /// Disk spill tier backing file (`--tier-disk-path`); `None`
    /// disables the disk tier.
    pub tier_disk_path: Option<std::path::PathBuf>,
    /// Disk-tier live-payload budget (`--tier-disk-mb`); `0` =
    /// unlimited (the file still compacts when half dead).
    pub tier_disk_bytes: usize,
    /// Background pruner: max entries one run may move
    /// (`--tier-prune-budget`); the checkpointed cursor resumes an
    /// exhausted run where it stopped.
    pub tier_prune_entries: usize,
    /// Background pruner: max serialized payload bytes one run may move.
    pub tier_prune_bytes: usize,
    /// Sleep between pruner runs once the backlog is drained.
    pub tier_prune_interval: Duration,
    /// Per-request token-channel capacity for streaming submissions
    /// (`fastav serve --stream-channel`): the *park threshold* — a
    /// streaming request whose client has this many undelivered tokens
    /// is parked (skips decode quanta, KV stays charged) until the
    /// client drains. Buffered (non-streaming) requests are unaffected.
    pub stream_channel_cap: usize,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            replicas: 1,
            queue_cap: 64,
            max_inflight: 4,
            kv_budget_bytes: 0,
            prefix_cache_bytes: 0,
            warmup: false,
            default_deadline: None,
            max_decode_batch: 0,
            tp_degree: 1,
            trace_sample: 0.0,
            trace_ring: 256,
            restart_backoff: Duration::from_millis(20),
            restart_backoff_max: Duration::from_secs(2),
            circuit_restarts: 5,
            circuit_window: Duration::from_secs(60),
            max_request_retries: 2,
            pipeline: true,
            tier_ram_bytes: 0,
            tier_disk_path: None,
            tier_disk_bytes: 0,
            tier_prune_entries: 32,
            tier_prune_bytes: 64 << 20,
            tier_prune_interval: Duration::from_millis(50),
            stream_channel_cap: 32,
        }
    }
}

impl PoolConfig {
    fn normalized(mut self) -> PoolConfig {
        self.replicas = self.replicas.max(1);
        self.queue_cap = self.queue_cap.max(1);
        self.max_inflight = self.max_inflight.max(1);
        self.tp_degree = self.tp_degree.max(1);
        self.stream_channel_cap = self.stream_channel_cap.max(1);
        self
    }

    /// The KV-byte budget one replica (device group) admits against:
    /// the per-device budget pooled across its mesh devices.
    pub fn group_kv_budget_bytes(&self) -> usize {
        self.kv_budget_bytes.saturating_mul(self.tp_degree.max(1))
    }

    /// Spill-tier sizing described by this config's tier flags.
    pub fn tier_config(&self) -> TierConfig {
        TierConfig {
            ram_bytes: self.tier_ram_bytes,
            disk_path: self.tier_disk_path.clone(),
            disk_bytes: self.tier_disk_bytes,
        }
    }

    /// Per-run work budget for the background tier pruner.
    pub fn prune_budget(&self) -> PruneBudget {
        PruneBudget {
            max_entries: self.tier_prune_entries.max(1),
            max_bytes: self.tier_prune_bytes.max(1),
        }
    }
}

/// Terminal states a request can reach besides completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Terminal {
    Failed,
    Canceled,
    Expired,
}

/// Lock a mutex, recovering from poisoning. The maps these guard
/// (cancellation flags, affinity routes, replica slots) hold plain data
/// that is valid at every instruction boundary, so a thread that
/// panicked while holding the lock cannot have left them torn — and one
/// panicked request must not cascade panics through submit/cancel.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Supervision state of one replica thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Engine up, serving its queue.
    Healthy,
    /// Engine poisoned by a panic; the supervisor is rebuilding it
    /// (backoff + factory). The queue stays open and drains on recovery.
    Restarting,
    /// Circuit breaker tripped (too many restarts in the window) or the
    /// factory can no longer produce an engine. The queue is closed,
    /// stranded jobs were redirected or failed, and `submit` no longer
    /// routes here. Terminal for the replica, not the pool.
    Dead,
}

impl ReplicaHealth {
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaHealth::Healthy => "healthy",
            ReplicaHealth::Restarting => "restarting",
            ReplicaHealth::Dead => "dead",
        }
    }

    pub(crate) fn from_u8(v: u8) -> ReplicaHealth {
        match v {
            1 => ReplicaHealth::Restarting,
            2 => ReplicaHealth::Dead,
            _ => ReplicaHealth::Healthy,
        }
    }
}

/// A peer replica's ingress, visible pool-wide so a poisoned replica
/// can redirect its stranded jobs without going through `submit`
/// (which would double-count them).
pub(crate) struct ReplicaSlot {
    pub queue: Arc<SchedulerQueue<Job>>,
    pub shared: Arc<ReplicaShared>,
}

/// Pool-wide counters (the conservation ledger) + cancellation flags.
#[derive(Default)]
pub(crate) struct PoolShared {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub canceled: AtomicU64,
    pub expired: AtomicU64,
    /// Requests re-enqueued after a replica poisoning (not a ledger
    /// term: a retried request is still exactly one submission).
    pub retried: AtomicU64,
    /// Streaming sessions currently open (created at submit, closed by
    /// `close_stream` on whichever terminal path retires the request).
    pub streams_active: AtomicU64,
    /// Streaming sessions currently parked on a slow consumer.
    pub streams_parked: AtomicU64,
    /// Streaming sessions that reached any terminal state.
    pub streams_completed: AtomicU64,
    pub cancels: Mutex<HashMap<u64, Arc<AtomicBool>>>,
    /// Every replica's queue + shared counters, registered before the
    /// replica threads spawn; the redirect path and the healthy-replica
    /// gauge read it.
    pub slots: Mutex<Vec<ReplicaSlot>>,
}

impl PoolShared {
    /// Refresh the `fastav_replicas_healthy` gauge from the slots.
    pub(crate) fn refresh_health_gauge(&self, metrics: &Registry) {
        let n = lock_clean(&self.slots)
            .iter()
            .filter(|s| s.shared.health() == ReplicaHealth::Healthy)
            .count();
        metrics.gauge("fastav_replicas_healthy").set(n as u64);
    }
}

/// Per-replica live counters, readable from any thread.
#[derive(Default)]
pub(crate) struct ReplicaShared {
    /// Requests popped from the queue and not yet terminal.
    pub active: AtomicUsize,
    pub kv_bytes: AtomicU64,
    pub steps_total: AtomicU64,
    pub steps_per_sec: AtomicU64,
    pub completed: AtomicU64,
    /// Decode quanta served (batched or not) and the requests they
    /// advanced; their ratio is the mean decode-batch occupancy.
    pub batch_quanta: AtomicU64,
    pub batch_tokens: AtomicU64,
    /// [`ReplicaHealth`] as a u8 (0 healthy / 1 restarting / 2 dead).
    pub health: AtomicU8,
    /// Successful engine respawns after a poisoning.
    pub restarts: AtomicU64,
    /// Engine panics caught by quantum isolation on this replica.
    pub panics: AtomicU64,
}

impl ReplicaShared {
    pub(crate) fn health(&self) -> ReplicaHealth {
        ReplicaHealth::from_u8(self.health.load(Ordering::SeqCst))
    }

    pub(crate) fn set_health(&self, h: ReplicaHealth) {
        self.health.store(h as u8, Ordering::SeqCst);
    }
}

/// Point-in-time view of one replica (the `/v1/pool` payload).
#[derive(Debug, Clone)]
pub struct ReplicaStatus {
    pub id: usize,
    pub queued: usize,
    pub active: usize,
    /// Mesh devices this replica's model is head-sharded over.
    pub tp_degree: usize,
    pub kv_bytes: u64,
    /// Pooled KV budget of the whole device group (per-device budget ×
    /// `tp_degree`; 0 = unlimited).
    pub kv_budget_bytes: usize,
    pub steps_total: u64,
    pub steps_per_sec: u64,
    pub completed: u64,
    /// Decode quanta this replica served and the requests they advanced
    /// (`decode_batch_tokens / decode_batch_quanta` = mean occupancy).
    pub decode_batch_quanta: u64,
    pub decode_batch_tokens: u64,
    /// Supervision state (`healthy` / `restarting` / `dead`).
    pub health: ReplicaHealth,
    /// Successful engine respawns after poisonings.
    pub restarts: u64,
    /// Engine panics caught by quantum isolation.
    pub panics: u64,
}

/// Pool-wide request accounting. At any quiescent point,
/// `submitted == rejected + terminal() + in_queue + in_flight`
/// (property-tested in `rust/tests/test_scheduling.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub canceled: u64,
    pub expired: u64,
    pub in_queue: u64,
    pub in_flight: u64,
    /// Requests re-enqueued after a replica poisoning. Not a ledger
    /// term: a retried request is still exactly one submission and
    /// reaches exactly one terminal state.
    pub retried: u64,
}

impl PoolStats {
    /// Requests that reached any terminal state.
    pub fn terminal(&self) -> u64 {
        self.completed + self.failed + self.canceled + self.expired
    }

    /// The conservation invariant (holds at quiescence).
    pub fn conserved(&self) -> bool {
        self.submitted == self.rejected + self.terminal() + self.in_queue + self.in_flight
    }
}

/// Why a submit failed, carrying the request back. `Full` is retryable
/// backpressure (HTTP 429); `Closed` means the pool is shutting down
/// (HTTP 503).
pub type SubmitError = PushError<GenRequest>;

struct ReplicaHandle {
    queue: Arc<SchedulerQueue<Job>>,
    shared: Arc<ReplicaShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// The background tier-pruner thread: a stop flag plus the join handle,
/// so shutdown can stop it *before* the [`TieredStore`] (and its disk
/// backing file) is dropped.
struct PrunerHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl PrunerHandle {
    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Body of the pruner thread: run budgeted [`TieredStore::prune_run`]s
/// back-to-back while a run reports `exhausted` (work left behind the
/// checkpointed cursor), and sleep `interval` once the backlog drains.
/// Each run is bounded by the configured entry/byte budget, so one run
/// can never monopolize the tier lock for long — demotion staging and
/// promotion interleave between runs.
fn pruner_loop(
    tier: Arc<TieredStore>,
    budget: PruneBudget,
    interval: Duration,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::SeqCst) {
        let report = tier.prune_run(budget);
        if report.exhausted {
            continue; // backlog remains; run again immediately
        }
        let t0 = Instant::now();
        while t0.elapsed() < interval && !stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(2).min(interval));
        }
    }
}

/// Per-tier flush accounting for `POST /v1/cache/flush`: the device
/// cache plus (when a tier is attached) every spill tier, with the
/// pruner checkpoint reset.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheFlushReport {
    pub device_entries: usize,
    pub device_bytes: usize,
    /// `None` when the pool runs without a spill tier.
    pub tier: Option<TierFlush>,
}

/// A pool of engine replicas with iteration-level scheduling and
/// prefix-affinity dispatch: requests sharing a cached AV prefix are
/// routed to the replica that built its entry (the entry itself lives in
/// the process-wide [`PrefixCache`], so any replica *can* serve a hit —
/// affinity just keeps warm buckets and queues aligned).
pub struct ReplicaPool {
    replicas: Vec<ReplicaHandle>,
    shared: Arc<PoolShared>,
    cfg: PoolConfig,
    next_id: AtomicU64,
    metrics: Arc<Registry>,
    prefix: Arc<PrefixCache>,
    /// Affinity key → replica that first served it (= owns the entry).
    router: Mutex<HashMap<u64, usize>>,
    /// Sampled request-lifecycle tracer (see the `trace` module).
    tracer: Arc<TraceRecorder>,
    /// Host-RAM + disk spill tier below the device prefix cache
    /// (`None` when the pool runs device-only).
    tier: Option<Arc<TieredStore>>,
    /// Background pruner servicing the tier's demotion backlog.
    pruner: Option<PrunerHandle>,
}

/// Bound on remembered affinity routes; the map resets when exceeded
/// (routing degrades to least-loaded, never breaks correctness).
const ROUTER_CAP: usize = 4096;

impl ReplicaPool {
    /// Start a pool of [`ModelEngine`] replicas over one artifact set.
    /// Each engine is constructed on its replica thread (PJRT handles
    /// never cross threads).
    pub fn start(
        artifact_root: std::path::PathBuf,
        model: String,
        cfg: PoolConfig,
        metrics: Arc<Registry>,
    ) -> Result<ReplicaPool> {
        let warmup = cfg.warmup;
        let tp = cfg.tp_degree.max(1);
        Self::start_with_factory(cfg, metrics, move |_replica| {
            // A replica is a device group: one engine head-sharded over
            // `tp` mesh devices (tp = 1 is the single-device case).
            let mut engine = ModelEngine::load_with_tp(&artifact_root, &model, tp)?;
            if warmup {
                engine.warmup()?;
            }
            Ok(engine)
        })
    }

    /// Start a pool over any [`ReplicaEngine`] implementation. The
    /// factory runs once per replica, *on* that replica's thread.
    pub fn start_with_factory<E, F>(
        cfg: PoolConfig,
        metrics: Arc<Registry>,
        factory: F,
    ) -> Result<ReplicaPool>
    where
        E: ReplicaEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        Self::start_with_factory_clocked(cfg, metrics, factory, Arc::new(MonotonicClock::new()))
    }

    /// [`Self::start_with_factory`] with an explicit trace clock — the
    /// mock-pool trace tests drive a [`crate::trace::MockClock`] so span
    /// timestamps (and the root-duration = `fastav_generate_seconds`
    /// identity) are exactly assertable.
    pub fn start_with_factory_clocked<E, F>(
        cfg: PoolConfig,
        metrics: Arc<Registry>,
        factory: F,
        clock: Arc<dyn Clock>,
    ) -> Result<ReplicaPool>
    where
        E: ReplicaEngine + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let cfg = cfg.normalized();
        register_metrics(&metrics);
        metrics.gauge("fastav_tp_degree").set(cfg.tp_degree as u64);
        let tracer = Arc::new(TraceRecorder::new(
            cfg.trace_sample,
            cfg.trace_ring,
            cfg.replicas,
            clock,
        ));
        let factory = Arc::new(factory);
        let shared = Arc::new(PoolShared::default());
        // One process-wide prefix cache shared by every replica; each
        // engine gets it via `ReplicaEngine::attach_prefix_cache`.
        let prefix = Arc::new(PrefixCache::new(cfg.prefix_cache_bytes));
        prefix.bind_metrics(&metrics);
        // Spill tier: device evictions demote into host RAM / disk and a
        // budgeted background pruner does all serialization + I/O, so the
        // replica quantum path never touches tier storage.
        let tier_cfg = cfg.tier_config();
        let (tier, pruner) = if tier_cfg.enabled() {
            let tier = Arc::new(TieredStore::new(tier_cfg));
            tier.bind_metrics(&metrics);
            prefix.attach_tier(Arc::clone(&tier));
            let stop = Arc::new(AtomicBool::new(false));
            let budget = cfg.prune_budget();
            let interval = cfg.tier_prune_interval;
            let thread = {
                let tier = Arc::clone(&tier);
                let stop = Arc::clone(&stop);
                std::thread::Builder::new()
                    .name("tier-pruner".into())
                    .spawn(move || pruner_loop(tier, budget, interval, stop))
                    .map_err(|e| anyhow!("spawn tier-pruner: {}", e))?
            };
            (Some(tier), Some(PrunerHandle { stop, thread: Some(thread) }))
        } else {
            (None, None)
        };
        // Create every replica's queue + shared counters and register
        // the slots *before* any thread spawns: a replica that poisons
        // during warm-up traffic must already see its peers to redirect
        // stranded jobs.
        let queues: Vec<Arc<SchedulerQueue<Job>>> = (0..cfg.replicas)
            .map(|_| Arc::new(SchedulerQueue::new(cfg.queue_cap)))
            .collect();
        let rshareds: Vec<Arc<ReplicaShared>> =
            (0..cfg.replicas).map(|_| Arc::new(ReplicaShared::default())).collect();
        *lock_clean(&shared.slots) = queues
            .iter()
            .zip(&rshareds)
            .map(|(q, s)| ReplicaSlot { queue: Arc::clone(q), shared: Arc::clone(s) })
            .collect();
        metrics.gauge("fastav_replicas_healthy").set(cfg.replicas as u64);
        let mut replicas: Vec<ReplicaHandle> = Vec::with_capacity(cfg.replicas);
        for i in 0..cfg.replicas {
            let queue = Arc::clone(&queues[i]);
            let rshared = Arc::clone(&rshareds[i]);
            let (ready_tx, ready_rx) = channel::<Result<(), String>>();
            let spawn = {
                let queue = Arc::clone(&queue);
                let rshared = Arc::clone(&rshared);
                let pshared = Arc::clone(&shared);
                let metrics = Arc::clone(&metrics);
                let factory = Arc::clone(&factory);
                let prefix = Arc::clone(&prefix);
                let tracer = Arc::clone(&tracer);
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("replica-{}", i))
                    .spawn(move || {
                        let engine = match factory(i) {
                            Ok(e) => e,
                            Err(e) => {
                                let _ = ready_tx.send(Err(format!("replica {}: {:#}", i, e)));
                                return;
                            }
                        };
                        let _ = ready_tx.send(Ok(()));
                        supervise(
                            i, engine, &factory, &cfg, &queue, &rshared, &pshared, &metrics,
                            &prefix, &tracer,
                        );
                    })
            };
            let thread = match spawn {
                Ok(t) => t,
                Err(e) => {
                    Self::close_handles(&mut replicas);
                    return Err(anyhow!("spawn replica {}: {}", i, e));
                }
            };
            let startup = match ready_rx.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(msg)) => Err(anyhow!(msg)),
                Err(_) => Err(anyhow!("replica {} died during startup", i)),
            };
            if let Err(e) = startup {
                let _ = thread.join();
                Self::close_handles(&mut replicas);
                return Err(e);
            }
            replicas.push(ReplicaHandle { queue, shared: rshared, thread: Some(thread) });
        }
        Ok(ReplicaPool {
            replicas,
            shared,
            cfg,
            next_id: AtomicU64::new(1),
            metrics,
            prefix,
            router: Mutex::new(HashMap::new()),
            tracer,
            tier,
            pruner,
        })
    }

    fn close_handles(handles: &mut [ReplicaHandle]) {
        for h in handles.iter() {
            h.queue.close();
        }
        for h in handles.iter_mut() {
            if let Some(t) = h.thread.take() {
                let _ = t.join();
            }
        }
    }

    /// Current dispatch load of a replica: queued + in-flight.
    fn load(&self, i: usize) -> usize {
        self.replicas[i].queue.len() + self.replicas[i].shared.active.load(Ordering::SeqCst)
    }

    /// Submit a request with prefix-affinity dispatch: if another request
    /// sharing this request's AV prefix was already routed, try the
    /// replica that owns the warm entry first; otherwise (and as
    /// fallover when that queue is full) walk replicas least-loaded
    /// first. Returns the request id (for [`cancel`](Self::cancel)) and
    /// the streaming event receiver.
    pub fn submit(&self, req: GenRequest) -> Result<(u64, Receiver<Event>), SubmitError> {
        let (tx, rx) = channel();
        let id = self.submit_with_sink(req, EventSink::Buffered(tx))?;
        Ok((id, rx))
    }

    /// Submit a request for *streamed* delivery: tokens are pushed into
    /// a bounded per-request [`TokenChannel`] as they decode, and the
    /// returned [`StreamReceiver`] is the client's subscription handle.
    /// Dropping the receiver mid-stream cancels the request within one
    /// scheduling quantum; a receiver that stops draining parks the
    /// request (see [`PoolConfig::stream_channel_cap`]) without
    /// stalling its batchmates.
    pub fn submit_streaming(
        &self,
        req: GenRequest,
    ) -> Result<(u64, StreamReceiver), SubmitError> {
        let (tx, rx) = TokenChannel::pair(self.cfg.stream_channel_cap);
        self.shared.streams_active.fetch_add(1, Ordering::Relaxed);
        match self.submit_with_sink(req, EventSink::Stream(tx)) {
            Ok(id) => Ok((id, rx)),
            Err(e) => {
                self.shared.streams_active.fetch_sub(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Shared submit path: identical dispatch for buffered and streaming
    /// sinks, so streamed and buffered runs of one request are
    /// byte-identical in everything but delivery.
    fn submit_with_sink(&self, req: GenRequest, sink: EventSink) -> Result<u64, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let cancel = Arc::new(AtomicBool::new(false));
        let deadline = req
            .deadline
            .or(self.cfg.default_deadline)
            .map(|d| Instant::now() + d);
        let prio = req.priority;
        // Affinity key = AV-prefix tokens × the spec's pruning-config
        // fingerprint: requests under different specs (different keep
        // sets) never alias the same warm entry.
        let affinity = request_prefix_affinity(&req.prompt, &req.segments, req.spec.plan());
        self.shared.submitted.fetch_add(1, Ordering::SeqCst);
        self.metrics.counter("fastav_requests_total").inc();
        // One sampling branch; on the untraced path `trace` is `None`
        // and nothing below allocates for it. A sampled request opens
        // its `queue` span here and carries the trace inside the Job
        // (a rejected push drops the Job — and the trace — with it).
        let mut trace = self.tracer.try_sample(id, req.profile.as_deref());
        if let Some(t) = trace.as_mut() {
            t.begin("queue");
        }
        let mut job = Job {
            id,
            req,
            enqueued: Instant::now(),
            deadline,
            cancel: Arc::clone(&cancel),
            events: sink,
            retries: 0,
            trace,
        };
        // Register the cancel flag *before* the push: the replica may
        // pop, finish, and clean up the entry before try_push returns.
        lock_clean(&self.shared.cancels).insert(id, cancel);
        // Dead replicas are excluded from routing outright (their queues
        // are closed anyway); restarting ones sort after healthy ones so
        // traffic prefers live engines but can still park in a
        // recovering replica's queue under pressure.
        let mut order: Vec<usize> = (0..self.replicas.len())
            .filter(|&i| self.replicas[i].shared.health() != ReplicaHealth::Dead)
            .collect();
        order.sort_by_key(|&i| {
            (self.replicas[i].shared.health() != ReplicaHealth::Healthy, self.load(i))
        });
        if let Some(aff) = affinity {
            let owner = lock_clean(&self.router).get(&aff).copied();
            if let Some(owner) = owner {
                if let Some(pos) = order.iter().position(|&i| i == owner) {
                    order.remove(pos);
                    order.insert(0, owner);
                }
            }
        }
        let mut all_closed = true;
        for &i in &order {
            match self.replicas[i].queue.try_push(job, prio) {
                Ok(()) => {
                    if let Some(aff) = affinity {
                        let mut router = lock_clean(&self.router);
                        if router.len() >= ROUTER_CAP {
                            router.clear();
                        }
                        // First dispatch wins: that replica builds (and
                        // therefore owns) the prefix entry.
                        router.entry(aff).or_insert(i);
                    }
                    self.metrics
                        .gauge("fastav_queue_depth")
                        .set(self.queue_depth() as u64);
                    return Ok(id);
                }
                Err(e) => {
                    all_closed &= e.is_closed();
                    job = e.into_inner();
                }
            }
        }
        lock_clean(&self.shared.cancels).remove(&id);
        self.shared.rejected.fetch_add(1, Ordering::SeqCst);
        self.metrics.counter("fastav_requests_rejected_total").inc();
        if all_closed {
            Err(SubmitError::Closed(job.req))
        } else {
            Err(SubmitError::Full(job.req))
        }
    }

    /// Request cooperative cancellation. Returns false when the id is
    /// unknown or already terminal. A queued request is dropped at pop;
    /// a running one stops within one scheduling quantum.
    pub fn cancel(&self, id: u64) -> bool {
        match lock_clean(&self.shared.cancels).get(&id) {
            Some(flag) => {
                flag.store(true, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    /// Total queued requests across replicas.
    pub fn queue_depth(&self) -> usize {
        self.replicas.iter().map(|r| r.queue.len()).sum()
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Aggregate queue counters across replicas (legacy surface).
    pub fn sched_stats(&self) -> SchedStats {
        let mut out = SchedStats::default();
        for r in &self.replicas {
            let s = r.queue.stats();
            out.admitted += s.admitted;
            out.rejected += s.rejected;
            out.dequeued += s.dequeued;
        }
        out
    }

    /// Pool-wide conservation ledger snapshot.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            submitted: self.shared.submitted.load(Ordering::SeqCst),
            rejected: self.shared.rejected.load(Ordering::SeqCst),
            completed: self.shared.completed.load(Ordering::SeqCst),
            failed: self.shared.failed.load(Ordering::SeqCst),
            canceled: self.shared.canceled.load(Ordering::SeqCst),
            expired: self.shared.expired.load(Ordering::SeqCst),
            in_queue: self.queue_depth() as u64,
            in_flight: self
                .replicas
                .iter()
                .map(|r| r.shared.active.load(Ordering::SeqCst) as u64)
                .sum(),
            retried: self.shared.retried.load(Ordering::SeqCst),
        }
    }

    /// Per-replica status snapshots (the `/v1/pool` payload).
    pub fn status(&self) -> Vec<ReplicaStatus> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(id, r)| ReplicaStatus {
                id,
                queued: r.queue.len(),
                active: r.shared.active.load(Ordering::SeqCst),
                tp_degree: self.cfg.tp_degree,
                kv_bytes: r.shared.kv_bytes.load(Ordering::Relaxed),
                kv_budget_bytes: self.cfg.group_kv_budget_bytes(),
                steps_total: r.shared.steps_total.load(Ordering::Relaxed),
                steps_per_sec: r.shared.steps_per_sec.load(Ordering::Relaxed),
                completed: r.shared.completed.load(Ordering::SeqCst),
                decode_batch_quanta: r.shared.batch_quanta.load(Ordering::Relaxed),
                decode_batch_tokens: r.shared.batch_tokens.load(Ordering::Relaxed),
                health: r.shared.health(),
                restarts: r.shared.restarts.load(Ordering::SeqCst),
                panics: r.shared.panics.load(Ordering::SeqCst),
            })
            .collect()
    }

    /// Replicas currently [`ReplicaHealth::Healthy`].
    pub fn healthy_count(&self) -> usize {
        self.replicas
            .iter()
            .filter(|r| r.shared.health() == ReplicaHealth::Healthy)
            .count()
    }

    /// Whether every replica is [`ReplicaHealth::Dead`] — the only
    /// condition under which `GET /v1/health` reports 503 (and `submit`
    /// returns `Closed` with no shutdown in progress).
    pub fn all_dead(&self) -> bool {
        self.replicas.iter().all(|r| r.shared.health() == ReplicaHealth::Dead)
    }

    /// The metric registry the pool reports into.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Pool-wide decode-batch accounting: `(quanta, tokens)` summed over
    /// replicas — `tokens / quanta` is the mean batch occupancy (the
    /// `decode_batch` block of `GET /v1/pool`).
    pub fn decode_batch_stats(&self) -> (u64, u64) {
        self.replicas.iter().fold((0, 0), |(q, t), r| {
            (
                q + r.shared.batch_quanta.load(Ordering::Relaxed),
                t + r.shared.batch_tokens.load(Ordering::Relaxed),
            )
        })
    }

    /// Streaming-session accounting snapshot (the `/v1/pool` `streams`
    /// block): sessions open, parked on a slow consumer, and completed.
    pub fn stream_stats(&self) -> StreamStats {
        StreamStats {
            active: self.shared.streams_active.load(Ordering::Relaxed),
            parked: self.shared.streams_parked.load(Ordering::Relaxed),
            completed: self.shared.streams_completed.load(Ordering::Relaxed),
        }
    }

    /// The process-wide AV-prefix cache backing every replica.
    pub fn prefix_cache(&self) -> &Arc<PrefixCache> {
        &self.prefix
    }

    /// The pool's request-lifecycle trace recorder.
    pub fn tracer(&self) -> &Arc<TraceRecorder> {
        &self.tracer
    }

    /// Prefix-cache accounting snapshot (the `/v1/pool` payload).
    pub fn prefix_stats(&self) -> PrefixCacheStats {
        self.prefix.stats()
    }

    /// Evict every lease-free prefix entry (`POST /v1/cache/flush`).
    /// Returns `(entries_evicted, bytes_freed)`.
    pub fn flush_prefix_cache(&self) -> (usize, usize) {
        self.prefix.flush()
    }

    /// The attached spill tier, when one is configured.
    pub fn tier(&self) -> Option<&Arc<TieredStore>> {
        self.tier.as_ref()
    }

    /// Spill-tier accounting snapshot (the `/v1/pool` tier block).
    pub fn tier_stats(&self) -> Option<TierStats> {
        self.tier.as_ref().map(|t| t.stats())
    }

    /// Drain *every* tier — device prefix cache plus RAM and disk spill
    /// tiers — and reset the pruner checkpoint. The device flush drops
    /// entries outright (it must not refill the tier being flushed).
    pub fn flush_all_tiers(&self) -> CacheFlushReport {
        let (device_entries, device_bytes) = self.prefix.flush();
        let tier = self.tier.as_ref().map(|t| t.flush());
        CacheFlushReport { device_entries, device_bytes, tier }
    }

    /// Close every queue, drain in-flight work, and join the replicas.
    pub fn shutdown(mut self) {
        Self::close_handles(&mut self.replicas);
        if let Some(p) = self.pruner.as_mut() {
            p.stop_and_join();
        }
    }
}

impl Drop for ReplicaPool {
    fn drop(&mut self) {
        Self::close_handles(&mut self.replicas);
        if let Some(p) = self.pruner.as_mut() {
            p.stop_and_join();
        }
    }
}

/// The replica thread body around [`replica::replica_loop`]: run the
/// engine until the queue drains, and on a poisoning (a caught engine
/// panic) rebuild the engine through the factory with exponential
/// backoff. A sliding-window circuit breaker bounds the blast radius:
/// more than `circuit_restarts` rebuilds inside `circuit_window` marks
/// the replica [`ReplicaHealth::Dead`], closes its queue, and redirects
/// or fails whatever was still queued. The supervisor runs *on* the
/// replica thread because engines are built on the thread that owns
/// them (PJRT handles are not `Send`).
#[allow(clippy::too_many_arguments)]
fn supervise<E, F>(
    id: usize,
    first: E,
    factory: &Arc<F>,
    cfg: &PoolConfig,
    queue: &Arc<SchedulerQueue<Job>>,
    rshared: &Arc<ReplicaShared>,
    pshared: &Arc<PoolShared>,
    metrics: &Arc<Registry>,
    prefix: &Arc<PrefixCache>,
    tracer: &Arc<TraceRecorder>,
) where
    E: ReplicaEngine + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let mut engine = Some(first);
    // Restart timestamps inside the sliding circuit window.
    let mut recent: Vec<Instant> = Vec::new();
    loop {
        let e = engine.take().expect("supervise refills the engine every iteration");
        let exit = replica::replica_loop(
            id,
            e,
            cfg,
            queue,
            rshared,
            pshared,
            metrics,
            Some(Arc::clone(prefix)),
            tracer,
        );
        let poison_msg = match exit {
            replica::ReplicaExit::Drained => return, // queue closed + drained
            replica::ReplicaExit::Poisoned(msg) => msg,
        };
        if trip_circuit(&mut recent, cfg) {
            go_dead(
                id,
                &format!("replica {}: circuit breaker open ({})", id, poison_msg),
                cfg,
                queue,
                rshared,
                pshared,
                metrics,
                tracer,
            );
            return;
        }
        rshared.set_health(ReplicaHealth::Restarting);
        pshared.refresh_health_gauge(metrics);
        // Rebuild with backoff; a failing factory consumes circuit
        // budget exactly like a panic does.
        loop {
            let attempt = recent.len().saturating_sub(1).min(16) as u32;
            let delay = cfg
                .restart_backoff
                .saturating_mul(1u32 << attempt)
                .min(cfg.restart_backoff_max.max(cfg.restart_backoff));
            if !sleep_unless_closed(queue, delay) {
                // Shutdown arrived mid-backoff: there is no engine to
                // drain with, so settle whatever is still queued.
                go_dead(
                    id,
                    &format!("replica {}: shut down while restarting ({})", id, poison_msg),
                    cfg,
                    queue,
                    rshared,
                    pshared,
                    metrics,
                    tracer,
                );
                return;
            }
            match factory(id) {
                Ok(e) => {
                    engine = Some(e);
                    break;
                }
                Err(err) => {
                    if trip_circuit(&mut recent, cfg) {
                        go_dead(
                            id,
                            &format!("replica {}: engine rebuild failed: {:#}", id, err),
                            cfg,
                            queue,
                            rshared,
                            pshared,
                            metrics,
                            tracer,
                        );
                        return;
                    }
                }
            }
        }
        rshared.restarts.fetch_add(1, Ordering::SeqCst);
        metrics.counter("fastav_replica_restarts_total").inc();
        rshared.set_health(ReplicaHealth::Healthy);
        pshared.refresh_health_gauge(metrics);
    }
}

/// Record one restart attempt in the sliding window; true = the
/// circuit breaker is now open.
fn trip_circuit(recent: &mut Vec<Instant>, cfg: &PoolConfig) -> bool {
    let now = Instant::now();
    recent.retain(|t| now.duration_since(*t) < cfg.circuit_window);
    recent.push(now);
    recent.len() > cfg.circuit_restarts
}

/// Sleep `delay` in small increments, returning false early if the
/// queue closes (pool shutdown) so a dying replica never delays drop.
fn sleep_unless_closed(queue: &SchedulerQueue<Job>, delay: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < delay {
        if queue.is_closed() {
            return false;
        }
        std::thread::sleep(Duration::from_millis(1).min(delay));
    }
    !queue.is_closed()
}

/// Terminal transition for one replica: mark it [`ReplicaHealth::Dead`],
/// close its queue, and redirect (bounded retries) or fail every job
/// still queued. The pool keeps serving on the surviving replicas;
/// `submit` returns `Closed` (HTTP 503) only when all are dead.
#[allow(clippy::too_many_arguments)]
fn go_dead(
    id: usize,
    reason: &str,
    cfg: &PoolConfig,
    queue: &SchedulerQueue<Job>,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    metrics: &Registry,
    tracer: &TraceRecorder,
) {
    rshared.set_health(ReplicaHealth::Dead);
    queue.close();
    while let Some(job) = queue.try_pop() {
        replica::strand_queued_job(job, id, reason, cfg, pshared, metrics, tracer);
    }
    pshared.refresh_health_gauge(metrics);
}

/// Pre-register the serving metric families so `/metrics` is complete
/// from the first scrape, before any traffic.
fn register_metrics(metrics: &Registry) {
    for c in [
        "fastav_requests_total",
        "fastav_requests_rejected_total",
        "fastav_requests_completed_total",
        "fastav_requests_failed_total",
        "fastav_requests_canceled_total",
        "fastav_requests_expired_total",
        "fastav_tokens_generated_total",
        "fastav_prefix_tokens_reused_total",
        "fastav_prefix_cache_hits_total",
        "fastav_prefix_cache_misses_total",
        "fastav_prefix_cache_evictions_total",
        "fastav_decode_batched_steps_total",
        "fastav_decode_batched_tokens_total",
        "fastav_replica_restarts_total",
        "fastav_replica_panics_total",
        "fastav_requests_retried_total",
        "fastav_requests_quarantined_total",
        "fastav_client_disconnects_total",
        "fastav_streams_parked_total",
        "fastav_stream_tokens_sent_total",
        "fastav_upload_ns_total",
        "fastav_upload_hidden_ns_total",
    ] {
        metrics.counter(c);
    }
    metrics.gauge("fastav_replicas_healthy");
    for sz in crate::metrics::OCCUPANCY_BUCKETS {
        metrics.counter(&crate::metrics::labeled(
            "fastav_decode_batch_occupancy",
            "size",
            sz,
        ));
    }
    metrics.histogram("fastav_ttft_seconds");
    metrics.histogram("fastav_generate_seconds");
    metrics.histogram("fastav_stream_duration_seconds");
    metrics.histogram("fastav_mesh_dispatch_seconds");
    metrics.gauge("fastav_upload_overlap_ratio");
    metrics.gauge("fastav_queue_depth");
    metrics.gauge("fastav_kv_peak_bytes");
    metrics.gauge("fastav_tp_degree");
    metrics.gauge("fastav_prefix_cache_entries");
    metrics.gauge("fastav_prefix_cache_bytes");
    metrics.gauge("fastav_kv_blocks_used");
    metrics.gauge("fastav_kv_blocks_shared");
    metrics.gauge("fastav_kv_blocks_free");
    // Spill-tier families (zero-valued unless a tier is attached).
    for tier in ["ram", "disk"] {
        for base in [
            "fastav_tier_demotions_total",
            "fastav_tier_promotions_total",
            "fastav_tier_drops_total",
        ] {
            metrics.counter(&crate::metrics::labeled(base, "tier", tier));
        }
        metrics.gauge(&crate::metrics::labeled("fastav_tier_bytes", "tier", tier));
    }
    metrics.gauge("fastav_tier_pending_entries");
    metrics.histogram("fastav_tier_promote_seconds");
}
