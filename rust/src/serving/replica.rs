//! One replica: an OS thread that owns one engine and interleaves many
//! in-flight generations over it.
//!
//! PJRT handles are not `Send`, so the engine is constructed *on* this
//! thread and never leaves it; the replica is therefore the sharding
//! unit of the pool. Inside the thread, scheduling is iteration-level:
//! the loop alternates between admitting queued jobs (under the
//! [`Admission`] KV-byte budget) and advancing exactly one generation
//! by one quantum, as chosen by the [`StepScheduler`]. Cancellation and
//! deadlines are checked at every admission and before every quantum,
//! so a canceled long generation stops within one step.

use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{Event, GenRequest, SchedulerQueue};
use crate::kvcache::PrefixCache;
use crate::metrics::{labeled, Registry};
use crate::model::{GenerateResult, Generation, ModelEngine, RequestInput, StepEvent};

use super::admission::{Admission, Admit, PrefixCharge};
use super::step_scheduler::StepScheduler;
use super::{PoolConfig, PoolShared, ReplicaShared, Terminal};

/// The engine surface a replica drives. [`ModelEngine`] is the real
/// implementation; tests swap in a mock so the pool's scheduling and
/// conservation properties run without AOT artifacts.
pub trait ReplicaEngine {
    type Gen;

    /// Start a generation (embed + fused front + global pruning — or a
    /// mid-sequence resume from the shared prefix cache on a hit).
    fn begin(&mut self, req: &GenRequest) -> Result<Self::Gen>;

    /// Advance one quantum (one prefill layer or one decode step).
    fn step(&mut self, gen: &mut Self::Gen) -> Result<StepEvent>;

    /// Whether the generation has emitted its final token.
    fn is_done(&self, gen: &Self::Gen) -> bool;

    /// Consume the generation into its result (partial on abort).
    fn finish(&mut self, gen: Self::Gen) -> GenerateResult;

    /// Current KV bytes pinned by this generation.
    fn kv_bytes(&self, gen: &Self::Gen) -> usize;

    /// Conservative pre-admission KV-byte estimate for a request.
    fn estimate_bytes(&self, req: &GenRequest) -> usize;

    /// Hook: the pool hands every engine the process-wide prefix cache
    /// at startup. Engines that can reuse AV prefixes store it; the
    /// default ignores it.
    fn attach_prefix_cache(&mut self, _cache: Arc<PrefixCache>, _replica: usize) {}

    /// The shareable (already-resident) portion of `estimate_bytes`, as
    /// a refcounted charge so admission counts shared prefix blocks once
    /// across concurrent borrowers. `None` = everything is unique.
    fn prefix_probe(&self, _req: &GenRequest) -> Option<PrefixCharge> {
        None
    }
}

impl ReplicaEngine for ModelEngine {
    type Gen = Generation;

    fn begin(&mut self, req: &GenRequest) -> Result<Generation> {
        let input = RequestInput {
            prompt: &req.prompt,
            segments: &req.segments,
            frame_of: &req.frame_of,
        };
        self.begin_generation(&input, &req.opts)
    }

    fn step(&mut self, gen: &mut Generation) -> Result<StepEvent> {
        self.step_generation(gen)
    }

    fn is_done(&self, gen: &Generation) -> bool {
        gen.is_done()
    }

    fn finish(&mut self, gen: Generation) -> GenerateResult {
        self.finish_generation(gen)
    }

    fn kv_bytes(&self, gen: &Generation) -> usize {
        gen.kv_bytes()
    }

    fn estimate_bytes(&self, req: &GenRequest) -> usize {
        self.estimate_kv_bytes(req.prompt.len(), req.opts.max_gen)
    }

    fn attach_prefix_cache(&mut self, cache: Arc<PrefixCache>, _replica: usize) {
        self.set_prefix_cache(cache);
    }

    fn prefix_probe(&self, req: &GenRequest) -> Option<PrefixCharge> {
        self.prefix_shared_estimate(&req.prompt, &req.segments, &req.frame_of, &req.opts.plan)
            .map(|(key, bytes)| PrefixCharge { key, bytes })
    }
}

/// A queued request (pool-internal).
pub(crate) struct Job {
    pub id: u64,
    pub req: GenRequest,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub cancel: Arc<std::sync::atomic::AtomicBool>,
    pub events: Sender<Event>,
}

/// One admitted, in-flight generation.
struct Active<G> {
    id: u64,
    gen: G,
    cancel: Arc<std::sync::atomic::AtomicBool>,
    deadline: Option<Instant>,
    events: Sender<Event>,
    started: Instant,
    /// Unique (non-shared) bytes reserved with the admission controller.
    est_bytes: usize,
    /// Shared-prefix charge reserved alongside (refcounted; see
    /// [`Admission::release_prefixed`]).
    prefix_charge: Option<PrefixCharge>,
}

/// Pre-resolved metric handles for one replica thread.
struct ReplicaMetrics {
    active_g: Arc<crate::metrics::Gauge>,
    kv_g: Arc<crate::metrics::Gauge>,
    sps_g: Arc<crate::metrics::Gauge>,
    steps_c: Arc<crate::metrics::Counter>,
    queue_hist: Arc<crate::metrics::Histogram>,
    gen_hist: Arc<crate::metrics::Histogram>,
    prefill_hist: Arc<crate::metrics::Histogram>,
    tok_hist: Arc<crate::metrics::Histogram>,
    completed_c: Arc<crate::metrics::Counter>,
    failed_c: Arc<crate::metrics::Counter>,
    canceled_c: Arc<crate::metrics::Counter>,
    expired_c: Arc<crate::metrics::Counter>,
    tokens_c: Arc<crate::metrics::Counter>,
    prefix_tokens_c: Arc<crate::metrics::Counter>,
    kv_peak: Arc<crate::metrics::Gauge>,
}

impl ReplicaMetrics {
    fn new(metrics: &Registry, replica: usize) -> ReplicaMetrics {
        let l = replica.to_string();
        ReplicaMetrics {
            active_g: metrics.gauge(&labeled("fastav_replica_active_requests", "replica", &l)),
            kv_g: metrics.gauge(&labeled("fastav_replica_kv_bytes", "replica", &l)),
            sps_g: metrics.gauge(&labeled("fastav_replica_steps_per_second", "replica", &l)),
            steps_c: metrics.counter(&labeled("fastav_replica_steps_total", "replica", &l)),
            queue_hist: metrics.histogram("fastav_queue_seconds"),
            gen_hist: metrics.histogram("fastav_generate_seconds"),
            prefill_hist: metrics.histogram("fastav_prefill_seconds"),
            tok_hist: metrics.histogram("fastav_decode_token_seconds"),
            completed_c: metrics.counter("fastav_requests_completed_total"),
            failed_c: metrics.counter("fastav_requests_failed_total"),
            canceled_c: metrics.counter("fastav_requests_canceled_total"),
            expired_c: metrics.counter("fastav_requests_expired_total"),
            tokens_c: metrics.counter("fastav_tokens_generated_total"),
            prefix_tokens_c: metrics.counter("fastav_prefix_tokens_reused_total"),
            kv_peak: metrics.gauge("fastav_kv_peak_bytes"),
        }
    }
}

/// How a generation left the replica.
enum Outcome {
    Completed,
    Terminal(Terminal, String),
}

/// The replica thread body: admit → step → account, until the queue is
/// closed and drained and no generation is in flight.
#[allow(clippy::too_many_arguments)]
pub(crate) fn replica_loop<E: ReplicaEngine>(
    replica_id: usize,
    mut engine: E,
    cfg: &PoolConfig,
    queue: &SchedulerQueue<Job>,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    metrics: &Registry,
    prefix: Option<Arc<PrefixCache>>,
) {
    let m = ReplicaMetrics::new(metrics, replica_id);
    if let Some(c) = prefix.clone() {
        engine.attach_prefix_cache(c, replica_id);
    }
    let mut admission = Admission::new(cfg.kv_budget_bytes, cfg.max_inflight);
    let mut sched = StepScheduler::new();
    let mut active: Vec<Active<E::Gen>> = Vec::new();
    let mut parked: Option<Job> = None;
    let mut rate_steps = 0u64;
    let mut rate_t0 = Instant::now();

    'outer: loop {
        // ---- Admission: pull queued jobs into the step scheduler. ----
        while admission.has_slot() {
            // A parked (budget-deferred) job is already counted as
            // in-flight; fresh pops are counted on arrival.
            let mut counted = false;
            let job = if let Some(j) = parked.take() {
                counted = true;
                Some(j)
            } else if active.is_empty() {
                match queue.pop_blocking() {
                    Some(j) => Some(j),
                    None => break 'outer, // closed + drained, nothing running
                }
            } else {
                queue.try_pop_fair()
            };
            let Some(job) = job else { break };
            if !counted {
                rshared.active.fetch_add(1, Ordering::SeqCst);
            }
            if job.cancel.load(Ordering::SeqCst) {
                settle_job(&job, Terminal::Canceled, "canceled before start", rshared, pshared, &m);
                continue;
            }
            if job.deadline.is_some_and(|d| Instant::now() >= d) {
                settle_job(&job, Terminal::Expired, "deadline exceeded in queue", rshared, pshared, &m);
                continue;
            }
            let est = engine.estimate_bytes(&job.req);
            // Split the estimate: bytes the request will borrow from a
            // resident prefix entry are charged once across borrowers.
            let charge = engine.prefix_probe(&job.req);
            let unique = est.saturating_sub(charge.map(|c| c.bytes).unwrap_or(0));
            match admission.check_prefixed(unique, charge) {
                Admit::Granted => {}
                Admit::Defer => {
                    // Re-examined once a running generation releases
                    // budget; stays counted as in-flight meanwhile.
                    parked = Some(job);
                    break;
                }
                Admit::Oversize => {
                    settle_job(
                        &job,
                        Terminal::Failed,
                        &format!(
                            "request needs ~{} KV bytes, over the replica budget {}",
                            est,
                            admission.budget_bytes()
                        ),
                        rshared,
                        pshared,
                        &m,
                    );
                    continue;
                }
            }
            m.queue_hist.observe(job.enqueued.elapsed().as_secs_f64());
            match engine.begin(&job.req) {
                Ok(gen) => {
                    sched.admit_with_affinity(
                        job.id,
                        job.req.priority,
                        job.deadline,
                        charge.map(|c| c.key),
                    );
                    active.push(Active {
                        id: job.id,
                        gen,
                        cancel: job.cancel,
                        deadline: job.deadline,
                        events: job.events,
                        started: Instant::now(),
                        est_bytes: unique,
                        prefix_charge: charge,
                    });
                }
                Err(e) => {
                    admission.release_prefixed(unique, charge);
                    settle_job(&job, Terminal::Failed, &format!("{:#}", e), rshared, pshared, &m);
                }
            }
        }
        m.active_g.set(active.len() as u64);
        if active.is_empty() {
            continue; // back to the blocking pop (or retry the parked job)
        }

        // ---- One scheduling quantum. ----
        let Some(idx) = sched.pick() else { continue };
        let now = Instant::now();
        let entry = &mut active[idx];
        let outcome: Option<Outcome> = if entry.cancel.load(Ordering::SeqCst) {
            Some(Outcome::Terminal(Terminal::Canceled, "canceled".into()))
        } else if entry.deadline.is_some_and(|d| now >= d) {
            Some(Outcome::Terminal(Terminal::Expired, "deadline exceeded".into()))
        } else {
            match engine.step(&mut entry.gen) {
                Ok(StepEvent::Token(t)) => {
                    let _ = entry.events.send(Event::Token(t));
                    m.steps_c.inc();
                    rshared.steps_total.fetch_add(1, Ordering::Relaxed);
                    rate_steps += 1;
                    if engine.is_done(&entry.gen) {
                        Some(Outcome::Completed)
                    } else {
                        None
                    }
                }
                Ok(StepEvent::Prefilled { .. }) => {
                    m.steps_c.inc();
                    rshared.steps_total.fetch_add(1, Ordering::Relaxed);
                    rate_steps += 1;
                    None
                }
                Ok(StepEvent::Done) => Some(Outcome::Completed),
                Err(e) => Some(Outcome::Terminal(Terminal::Failed, format!("{:#}", e))),
            }
        };

        if let Some(outcome) = outcome {
            let a = active.remove(idx);
            sched.remove(idx);
            match outcome {
                Outcome::Completed => {
                    let res = engine.finish(a.gen);
                    m.gen_hist.observe(a.started.elapsed().as_secs_f64());
                    m.prefill_hist.observe(res.prefill_seconds);
                    if res.decode_steps > 0 {
                        m.tok_hist.observe(res.decode_seconds / res.decode_steps as f64);
                    }
                    m.kv_peak.max(res.peak_kv_bytes as u64);
                    m.tokens_c.add(res.tokens.len() as u64);
                    m.prefix_tokens_c.add(res.prefix_tokens_reused as u64);
                    m.completed_c.inc();
                    pshared.completed.fetch_add(1, Ordering::SeqCst);
                    rshared.completed.fetch_add(1, Ordering::SeqCst);
                    let _ = a.events.send(Event::Done(Box::new(res)));
                }
                Outcome::Terminal(kind, msg) => {
                    // Abandon the generation; partial state is dropped.
                    drop(engine.finish(a.gen));
                    settle_terminal(kind, &msg, &a.events, rshared, pshared, &m, false);
                }
            }
            admission.release_prefixed(a.est_bytes, a.prefix_charge);
            pshared.cancels.lock().unwrap().remove(&a.id);
            rshared.active.fetch_sub(1, Ordering::SeqCst);
            m.active_g.set(active.len() as u64);
        }

        // ---- Gauges: KV footprint + steps/s. ----
        let kv_now: usize = active.iter().map(|a| engine.kv_bytes(&a.gen)).sum();
        rshared.kv_bytes.store(kv_now as u64, Ordering::Relaxed);
        m.kv_g.set(kv_now as u64);
        let dt = rate_t0.elapsed().as_secs_f64();
        if dt >= 0.5 {
            let sps = (rate_steps as f64 / dt).round() as u64;
            rshared.steps_per_sec.store(sps, Ordering::Relaxed);
            m.sps_g.set(sps);
            // Block-pool gauges drift with every append/compact, not only
            // with cache operations — refresh them on the rate tick.
            if let Some(c) = &prefix {
                c.refresh_gauges();
            }
            rate_steps = 0;
            rate_t0 = Instant::now();
        }
    }
}

/// Account a job that never entered the step scheduler (canceled,
/// expired, oversize, or failed at begin). The caller has already
/// counted it in `rshared.active`.
fn settle_job(
    job: &Job,
    kind: Terminal,
    msg: &str,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
) {
    settle_terminal(kind, msg, &job.events, rshared, pshared, m, true);
    pshared.cancels.lock().unwrap().remove(&job.id);
}

fn settle_terminal(
    kind: Terminal,
    msg: &str,
    events: &Sender<Event>,
    rshared: &ReplicaShared,
    pshared: &PoolShared,
    m: &ReplicaMetrics,
    decrement_active: bool,
) {
    match kind {
        Terminal::Canceled => {
            m.canceled_c.inc();
            pshared.canceled.fetch_add(1, Ordering::SeqCst);
        }
        Terminal::Expired => {
            m.expired_c.inc();
            pshared.expired.fetch_add(1, Ordering::SeqCst);
        }
        Terminal::Failed => {
            m.failed_c.inc();
            pshared.failed.fetch_add(1, Ordering::SeqCst);
        }
    }
    let _ = events.send(Event::Error(msg.to_string()));
    if decrement_active {
        rshared.active.fetch_sub(1, Ordering::SeqCst);
    }
}
